"""Live telemetry over a drifting stream — the observability front door.

A stream whose cluster centers drift over time is ingested through the
Session facade while the telemetry plane watches: after every batch the
process-wide metrics snapshot (``Session.stats()``) is rendered as a tiny
text dashboard — ingest/refresh/score phase latencies, tree shape, model
staleness, kernel-backend dispatch counts.  The same snapshot dict feeds
``repro.render_prometheus`` for a real scrape endpoint; the last section
prints the exposition-format text so you can see what Prometheus would.

Because the stream *drifts*, the online monitors eventually fire: the
dashboard's alerts pane shows the typed ``Alert`` records from the
snapshot's ``alerts`` section (outlier-rate EWMA vs the configured z/n
budget, model staleness, shed burn) as they appear.  ``--trace-out FILE``
additionally dumps the flight recorder's Chrome trace at the end — load
it in Perfetto or ``chrome://tracing`` to see every ingest request and
cadence refresh as a stitched span tree.

    PYTHONPATH=src python examples/metrics_dashboard.py
    PYTHONPATH=src python examples/metrics_dashboard.py --batches 30 --prom
    PYTHONPATH=src python examples/metrics_dashboard.py --trace-out t.json
"""
import argparse

import numpy as np

from repro import Session, pipeline_config, render_prometheus


def drifting_batches(rng, *, n_centers, per_batch, d, batches, drift=0.15):
    """Gaussian mixture whose centers random-walk between batches."""
    centers = rng.normal(size=(n_centers, d)).astype(np.float32) * 3.0
    for _ in range(batches):
        centers = centers + drift * rng.normal(size=centers.shape).astype(
            np.float32)
        which = rng.integers(0, n_centers, size=per_batch)
        yield (centers[which]
               + 0.1 * rng.normal(size=(per_batch, d)).astype(np.float32))


def _h(snap, key):
    """One-line summary of a histogram series, or '-' if absent."""
    e = snap["histograms"].get(key)
    if not e or not e["count"]:
        return "-"
    return (f"n={e['count']:<6d} p50={e['p50'] * 1e3:7.2f}ms "
            f"p99={e['p99'] * 1e3:7.2f}ms")


def dashboard(snap):
    c, g = snap["counters"], snap["gauges"]
    tree_records = next((v for k, v in g.items()
                         if k.startswith("tree.records")), None)
    tree_summaries = next((v for k, v in g.items()
                           if k.startswith("tree.summaries")), None)
    staleness = next((v for k, v in g.items()
                      if k.startswith("model.seconds_since_install")), None)
    lines = [
        f"  ingest     {_h(snap, 'phase.ingest{topology=stream}')}",
        f"  refresh    {_h(snap, 'phase.refresh.fit{topology=stream}')}",
        f"  score      {_h(snap, 'serve.latency{topology=stream}')}",
        f"  tree       records={tree_records} summaries={tree_summaries}",
        f"  refreshes  {c.get('refresh.count{topology=stream}', 0)}"
        f"  (model age "
        f"{'-' if staleness is None else f'{staleness:.2f}s'})",
        "  kernels    " + "  ".join(
            f"{k.split('{', 1)[1][:-1]}:{v}" for k, v in sorted(c.items())
            if k.startswith("kernels.dispatch{")),
    ]
    tr = snap.get("trace")
    if tr:
        lines.append(f"  trace      {tr['recorded']} spans / "
                     f"{tr['traces']} traces "
                     f"(sample={tr['sample_rate']:g} "
                     f"dropped={tr['dropped']})")
    alerts = snap.get("alerts", [])
    if alerts:
        lines.append("  alerts:")
        for a in alerts:
            labels = ",".join(f"{k}={v}" for k, v in a["labels"].items())
            lines.append(f"    [{a['severity']:<4s}] {a['name']}"
                         f"{{{labels}}}: {a['message']}")
    else:
        lines.append("  alerts     (none firing)")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=12)
    ap.add_argument("--per-batch", type=int, default=2048)
    ap.add_argument("--n-centers", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--every", type=int, default=4,
                    help="print the dashboard every N batches")
    ap.add_argument("--prom", action="store_true",
                    help="also print the Prometheus exposition text")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="dump the flight recorder as Chrome trace JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    cfg = pipeline_config(
        dim=args.dim, k=args.n_centers, t=50, topology="stream",
        leaf_size=1024, refresh_every=4 * args.per_batch, micro_batch=256,
        seed=args.seed)
    sess = Session(cfg)

    for i, batch in enumerate(drifting_batches(
            rng, n_centers=args.n_centers, per_batch=args.per_batch,
            d=args.dim, batches=args.batches), start=1):
        sess.ingest(batch)
        if sess.last_fit is not None:    # a model is installed — probe it
            sess.score(batch[:128])
        if i % args.every == 0 or i == args.batches:
            print(f"--- batch {i}/{args.batches} "
                  f"({i * args.per_batch} points ingested) ---")
            print(dashboard(sess.stats()))

    snap = sess.stats()
    n = sum(len(snap[s]) for s in ("counters", "gauges", "histograms"))
    print(f"\nfinal snapshot: {n} series "
          f"(counters={len(snap['counters'])}, "
          f"gauges={len(snap['gauges'])}, "
          f"histograms={len(snap['histograms'])})")
    alerts = snap.get("alerts", [])
    print(f"alerts firing: {len(alerts)}"
          + "".join(f"\n  [{a['severity']}] {a['name']}: {a['message']}"
                    for a in alerts))
    if args.trace_out:
        path = sess.dump_trace(args.trace_out)
        print(f"wrote Chrome trace to {path} "
              f"(load in Perfetto or chrome://tracing)")
    if args.prom:
        print("\n--- prometheus exposition (first 30 lines) ---")
        print("\n".join(render_prometheus(snap).splitlines()[:30]))


if __name__ == "__main__":
    main()
