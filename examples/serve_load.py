"""Serving under concurrent load: the async scheduler on one Session.

One fitted model, many client threads.  The config's ``serving`` section
turns on the continuous-batching scheduler (``repro.serve``) behind
``Session.score_stream``: requests from all threads coalesce into shared
jitted micro-batch ticks, the bounded queue admits or sheds, and the
scores coming back are bit-identical to synchronous ``Session.score``.
The demo then pushes offered load past capacity with the open-loop load
generator to show admission control at work — goodput holds, the excess
is shed as typed :class:`repro.ShedReject` results, p99 stays bounded.

    PYTHONPATH=src python examples/serve_load.py
"""
import argparse
import threading

import numpy as np

from repro import Session, ShedReject, pipeline_config
from repro.data.synthetic import gauss
from repro.serve import estimate_capacity, run_load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-centers", type=int, default=10)
    ap.add_argument("--per-center", type=int, default=1000)
    ap.add_argument("--t", type=int, default=80)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--load-seconds", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, out_ids = gauss(n_centers=args.n_centers, per_center=args.per_center,
                       t=args.t, sigma=0.1, seed=args.seed)
    n = x.shape[0]
    cfg = pipeline_config(
        dim=x.shape[1], k=args.n_centers, t=args.t, topology="stream",
        leaf_size=2048, refresh_every=max(n // 2, 2048), micro_batch=256,
        # the serving section travels with the config like every policy
        serving={"queue_bound": 512, "batch_window_ms": 1.0,
                 "shed_policy": "shed"},
        seed=args.seed)

    with Session(cfg) as sess:
        sess.fit(x)
        print(f"fitted model v{int(sess.model.version)} on {n} points; "
              f"serving spec: {cfg.serving}")

        # --- many threads, one model: concurrent == sequential, bitwise
        q = np.concatenate([x[:48], x[out_ids[:16]]])
        sync = sess.score(q)
        slots = [None] * 4

        def client(ci):
            rows = q[ci * 16:(ci + 1) * 16]
            slots[ci] = list(sess.score_stream(rows, timeout=60.0))

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        conc = [r for rs in slots for r in rs]
        for a, b in zip(sync, conc):
            assert a.distance == b.distance \
                and a.outlier_score == b.outlier_score, "paths diverged!"
        caught = sum(r.is_outlier for r in conc[-16:])
        print(f"  {len(conc)} rows scored from 4 threads, bit-identical "
              f"to score(); {caught}/16 planted outliers flagged")

        # --- push past capacity: admission control spends the excess
        sched = sess.serving
        rng = np.random.default_rng(args.seed + 7)
        queries = x[rng.choice(n, size=min(4096, n), replace=False)]
        cap = estimate_capacity(sched, queries, duration_s=0.3)
        print(f"  capacity ~{cap:,.0f} rows/s (closed-loop); offering 2x "
              f"from {args.clients} open-loop clients ...")
        rep = run_load(sched, queries, offered_rps=2.0 * cap,
                       clients=args.clients, duration_s=args.load_seconds,
                       seed=args.seed)
        print(f"  offered {rep['offered_rps']:,.0f} rows/s -> goodput "
              f"{rep['goodput_rps']:,.0f} rows/s, shed {rep['shed_rate']:.1%}"
              f" ({rep['shed']}/{rep['submitted']}), p99 "
              f"{rep['p99_ms']:.1f} ms")
        assert rep["completed"] > 0

        # a shed is a typed result, not an exception — clients branch on it
        demo = sess.submit_stream(queries[:4])
        kinds = {type(t.result(timeout=30.0)).__name__ for t in demo}
        assert kinds <= {"QueryResult", "ShedReject"}, kinds
        assert isinstance(ShedReject(0, "t", "queue_full", 0), tuple)

        stats = sess.stats()
        serve_keys = sorted(
            k for sec in ("counters", "gauges", "histograms")
            for k in stats.get(sec, {}) if k.startswith("serve."))
        print(f"  scheduler telemetry in repro.obs: {len(serve_keys)} "
              f"series (e.g. {serve_keys[0]}, serve.queue_depth, "
              f"serve.shed{{tenant=,reason=}})")
    print("ok")


if __name__ == "__main__":
    main()
