"""End-to-end training driver: LM training with the paper's outlier-based
data curation + checkpointing + straggler monitoring.

A Markov-chain token stream is polluted with a small fraction of uniform-
noise documents.  Sequence embeddings feed the DataCurator (Algorithm 3
with sites = DP shards); detected outlier sequences are dropped from the
loss.  The curated run reaches lower clean-set loss than the uncurated one.

Presets: --preset tiny (default, ~2M params, CPU-friendly) / 100m (the
"train a ~100M model" configuration — same code path, for real hardware).

    PYTHONPATH=src python examples/train_curated_lm.py --steps 200
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core.curation import CuratorConfig, DataCurator
from repro.data.tokens import PipelineConfig, TokenPipeline
from repro.launch.steps import make_train_step
from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx
from repro.models.transformer import init_params
from repro.optim import adamw
from repro.runtime.straggler import StragglerMonitor

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                 vocab=64, seq=64, batch=16),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=32000, seq=1024, batch=64),
}


def make_batch(pipe, step, rng, noise_frac):
    b = pipe.global_batch(step)["tokens"]
    n_noise = int(noise_frac * b.shape[0])
    noisy = rng.choice(b.shape[0], n_noise, replace=False)
    b = b.copy()
    b[noisy] = rng.integers(0, pipe.cfg.vocab, size=(n_noise, b.shape[1]))
    seq_ids = step * b.shape[0] + np.arange(b.shape[0])
    return b, seq_ids, noisy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--noise-frac", type=float, default=0.1)
    ap.add_argument("--no-curation", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"lm-{args.preset}", family="dense", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        d_ff=p["d_ff"], vocab=p["vocab"], dtype="float32",
        remat_policy="none")
    pipe = TokenPipeline(PipelineConfig(vocab=p["vocab"], seq_len=p["seq"],
                                        global_batch=p["batch"],
                                        seed=args.seed))
    rng = np.random.default_rng(args.seed)

    params = init_params(cfg, jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params | curation: "
          f"{'off' if args.no_curation else 'on'}")

    step_fn, optc = make_train_step(cfg, mesh=None)
    opt = adamw.init(params, optc)
    ctx = ShardCtx(mesh=None)

    @jax.jit
    def weighted_step(params, opt_state, tokens, w):
        def loss_fn(pp):
            from repro.models.layers import rmsnorm, unembed
            import repro.models.transformer as T
            x, _ = T._embed_inputs(pp, {"tokens": tokens}, cfg, ctx)
            S = x.shape[1]
            pos = jnp.arange(S, dtype=jnp.int32)
            def body(c, lp):
                y, _ = T._dense_layer_train(lp, c, cfg, ctx, pos)
                return y, None
            x, _ = jax.lax.scan(body, x, pp["layers"])
            xe = x  # embeddings for curation: mean-pooled last hidden
            x = rmsnorm(pp["final_norm"], x, cfg.norm_eps)
            logits = unembed(pp["lm_head"], x, ctx)
            tgt = tokens[:, 1:]
            lg = logits[:, :-1]
            nll = (jax.nn.logsumexp(lg, -1)
                   - jnp.take_along_axis(lg, tgt[..., None], -1)[..., 0])
            per_seq = nll.mean(-1)
            loss = (per_seq * w).sum() / jnp.maximum(w.sum(), 1.0)
            return loss, (per_seq, jax.lax.stop_gradient(xe.mean(1)))
        (loss, (per_seq, emb)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_o, om = adamw.apply(params, grads, opt_state, optc)
        return new_p, new_o, loss, per_seq, emb

    curator = DataCurator(n_sites=4, cfg=CuratorConfig(
        k=8, outlier_frac=args.noise_frac / 2, min_points=256,
        reservoir=2048, seed=args.seed))
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    monitor = StragglerMonitor(n_sites=4)
    flagged = None

    clean_losses = []
    for step in range(args.steps):
        tokens, seq_ids, noisy = make_batch(pipe, step, rng, args.noise_frac)
        w = (np.ones(len(seq_ids), np.float32) if args.no_curation
             else curator.sample_weights(seq_ids, flagged))
        t0 = time.perf_counter()
        params, opt, loss, per_seq, emb = weighted_step(
            params, opt, jnp.asarray(tokens), jnp.asarray(w))
        dt = time.perf_counter() - t0
        monitor.observe(np.full(4, dt, np.float32)
                        + rng.normal(0, dt * 0.02, 4).astype(np.float32))

        if not args.no_curation:
            emb_np = np.asarray(emb)
            per_site = np.array_split(np.arange(len(seq_ids)), 4)
            for s_i, idx in enumerate(per_site):
                curator.observe(s_i, emb_np[idx], seq_ids[idx])
            if step % 25 == 24:
                flagged, comm = curator.detect()
                if flagged is not None:
                    print(f"  [curation] step {step}: {len(flagged)} outlier "
                          f"sequences flagged, comm={comm:.0f} records")
        clean = np.asarray(per_seq)[np.setdiff1d(np.arange(len(seq_ids)), noisy)]
        clean_losses.append(float(clean.mean()))
        if step % 20 == 0:
            print(f"step {step:4d} loss={float(loss):.4f} "
                  f"clean={clean_losses[-1]:.4f} ({dt*1e3:.0f} ms)")
        if step % 50 == 49:
            ckpt.save(step, {"params": params, "opt": opt})
    ckpt.wait()
    print(f"final clean-set loss: {np.mean(clean_losses[-10:]):.4f} "
          f"(start {np.mean(clean_losses[:10]):.4f})")
    print(f"checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
