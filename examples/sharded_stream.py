"""Multi-host streaming demo on the Session facade: 4 sites, one config.

The same ``PipelineConfig`` shape as the single-host demo with
``topology="sharded"`` and a site count — that one-line change swaps the
engine for ``ShardedStreamService``: an interleaved stream is round-robined
over site-local merge-and-reduce trees, on the refresh cadence the sites
exchange only their packed tree roots (one all_gather — the comm cost is
printed per refresh), and the replicated second-level weighted k-means--
yields one global model every site serves from.  The demo then checkpoints
the whole topology through the facade (config embedded), restores it with
``Session.load``, and shows that restoring onto a different site count is
refused.

    PYTHONPATH=src python examples/sharded_stream.py [--sites 4]

With ``--async-refresh`` the cadence models are fitted on a worker thread:
ingest never blocks on a refresh, queries score against the previous model
until the new one lands.
"""
import argparse
import tempfile

import numpy as np

from repro import Session, ShardedStreamService, pipeline_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import gauss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sites", type=int, default=4)
    ap.add_argument("--n-centers", type=int, default=10)
    ap.add_argument("--per-center", type=int, default=1500)
    ap.add_argument("--t", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--async-refresh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, out_ids = gauss(n_centers=args.n_centers, per_center=args.per_center,
                       t=args.t, sigma=0.1, seed=args.seed)
    n = x.shape[0]
    cfg = pipeline_config(
        dim=x.shape[1], k=args.n_centers, t=args.t, topology="sharded",
        sites=args.sites, leaf_size=1024, refresh_every=max(n // 4, 2048),
        micro_batch=256, async_refresh=args.async_refresh, seed=args.seed)
    sess = Session(cfg)

    print(f"streaming {n} points over {args.sites} sites "
          f"in batches of {args.batch} ...")
    for i in range(0, n, args.batch):
        sess.ingest(x[i:i + args.batch])           # round-robin routed
    sess.engine.join_refresh()
    sess.refresh()
    st = sess.engine.last_refresh
    print(f"  model v{int(sess.model.version)} [{st.path}] from "
          f"{st.comm_records} gathered root records "
          f"({st.comm_bytes} bytes over one all_gather, "
          f"{st.root_rows} rows/site) — per-site trees: "
          f"{[tr.total_ingested for tr in sess.engine.trees]} points")

    # mixed queries: a few inliers and one planted outlier
    inliers = np.setdiff1d(np.arange(n), out_ids)[:4]
    q = np.concatenate([x[inliers], x[out_ids[:1]]])
    for r in sess.score(q):
        tag = "OUTLIER" if r.is_outlier else "inlier "
        print(f"  req {r.request_id}: center {r.center:2d} "
              f"score {r.outlier_score:8.3f}  {tag} "
              f"({r.latency_s * 1e3:.1f} ms)")

    ckpt_dir = tempfile.mkdtemp(prefix="sharded_stream_ckpt_")
    step = sess.save(ckpt_dir)
    print(f"checkpointed {args.sites} site trees to {ckpt_dir} @ step {step}; "
          f"restoring from the embedded config ...")
    restored = Session.load(ckpt_dir)
    a = sess.score(q)
    b = restored.score(q)
    assert all(p.distance == r.distance and p.center == r.center
               for p, r in zip(a, b)), "restore drifted!"
    print(f"  restored model v{int(restored.model.version)}: "
          f"{len(b)} post-restore scores identical")

    try:
        ShardedStreamService.restore(
            pipeline_config(
                dim=x.shape[1], k=args.n_centers, t=args.t,
                topology="sharded", sites=args.sites + 1).sharded_config(),
            CheckpointManager(ckpt_dir))
    except ValueError as e:
        print(f"  restore onto {args.sites + 1} sites refused: {e}")
    else:
        raise SystemExit("site-count guard did not fire!")
    print("ok")


if __name__ == "__main__":
    main()
