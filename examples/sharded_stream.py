"""Multi-host streaming demo: 4 sites -> trees -> all_gather roots -> model.

An interleaved stream of Gaussian-cluster points (plus planted outliers)
is round-robined over four site-local merge-and-reduce trees, exactly the
dispatcher model of the paper.  On the refresh cadence the sites exchange
only their packed tree roots (one all_gather — the comm cost is printed
per refresh) and the replicated second-level weighted k-means-- yields one
global model that every site serves from.  The demo then checkpoints the
whole topology (per-site trees + model + routing cursor), restores it, and
shows that restoring onto a different site count is refused.

    PYTHONPATH=src python examples/sharded_stream.py [--sites 4]

With ``--async-refresh`` the cadence models are fitted on a worker thread:
ingest never blocks on a refresh, queries score against the previous model
until the new one lands.
"""
import argparse
import tempfile

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import gauss
from repro.stream import ShardedServiceConfig, ShardedStreamService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sites", type=int, default=4)
    ap.add_argument("--n-centers", type=int, default=10)
    ap.add_argument("--per-center", type=int, default=1500)
    ap.add_argument("--t", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--async-refresh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, out_ids = gauss(n_centers=args.n_centers, per_center=args.per_center,
                       t=args.t, sigma=0.1, seed=args.seed)
    n = x.shape[0]
    cfg = ShardedServiceConfig(
        dim=x.shape[1], k=args.n_centers, t=args.t, n_sites=args.sites,
        leaf_size=1024, refresh_every=max(n // 4, 2048), micro_batch=256,
        async_refresh=args.async_refresh, seed=args.seed)
    svc = ShardedStreamService(cfg)

    print(f"streaming {n} points over {args.sites} sites "
          f"in batches of {args.batch} ...")
    for i in range(0, n, args.batch):
        svc.ingest(x[i:i + args.batch])           # round-robin routed
    svc.join_refresh()
    svc.refresh()
    st = svc.last_refresh
    print(f"  model v{int(svc.model.version)} [{st.path}] from "
          f"{st.comm_records} gathered root records "
          f"({st.comm_bytes} bytes over one all_gather, "
          f"{st.root_rows} rows/site) — per-site trees: "
          f"{[tr.total_ingested for tr in svc.trees]} points")

    # mixed queries: a few inliers and one planted outlier
    inliers = np.setdiff1d(np.arange(n), out_ids)[:4]
    q = np.concatenate([x[inliers], x[out_ids[:1]]])
    for r in svc.score(q):
        tag = "OUTLIER" if r.is_outlier else "inlier "
        print(f"  req {r.request_id}: center {r.center:2d} "
              f"score {r.outlier_score:8.3f}  {tag} "
              f"({r.latency_s * 1e3:.1f} ms)")

    ckpt_dir = tempfile.mkdtemp(prefix="sharded_stream_ckpt_")
    svc.save(CheckpointManager(ckpt_dir), step=1)
    print(f"checkpointed {args.sites} site trees to {ckpt_dir}; restoring ...")
    restored = ShardedStreamService.restore(cfg, CheckpointManager(ckpt_dir))
    a = svc.score(q)
    b = restored.score(q)
    assert all(p.distance == r.distance and p.center == r.center
               for p, r in zip(a, b)), "restore drifted!"
    print(f"  restored model v{int(restored.model.version)}: "
          f"{len(b)} post-restore scores identical")

    try:
        ShardedStreamService.restore(
            ShardedServiceConfig(dim=x.shape[1], k=args.n_centers, t=args.t,
                                 n_sites=args.sites + 1),
            CheckpointManager(ckpt_dir))
    except ValueError as e:
        print(f"  restore onto {args.sites + 1} sites refused: {e}")
    else:
        raise SystemExit("site-count guard did not fire!")
    print("ok")


if __name__ == "__main__":
    main()
