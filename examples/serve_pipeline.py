"""Batched serving demo: prefill + ring-buffer KV-cache decode with
request batching and per-step token streaming, on the smoke-scale Mistral
(llava backbone) config.

    PYTHONPATH=src python examples/serve_pipeline.py --batch 4 --gen 32
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models.layers import ShardCtx
from repro.models.transformer import forward_prefill, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    ctx = ShardCtx(mesh=None)
    params = init_params(cfg, jax.random.key(args.seed))

    # batched prompts (random tokens — a tokenizer would sit here)
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 2, cfg.vocab)
    t0 = time.perf_counter()
    logits, cache = forward_prefill(params, {"tokens": prompts}, cfg, ctx,
                                    max_len=args.prompt_len + args.gen)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.0f} ms")

    serve_step = jax.jit(make_serve_step(cfg, mesh=None))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = serve_step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decode: {args.gen - 1} steps x {args.batch} seqs in {dt*1e3:.0f} ms "
          f"({(args.gen - 1) * args.batch / dt:.0f} tok/s incl. first-step jit)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {toks[b, :16].tolist()}...")
    assert np.isfinite(np.asarray(logits)).all()
    print("ok")


if __name__ == "__main__":
    main()
