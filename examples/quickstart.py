"""Quickstart: distributed (k,t)-means with outliers on synthetic data.

Builds the paper's gauss-0.1 dataset, partitions it across 5 simulated
sites, runs Algorithm 3 (ball-grow summaries + k-means-- coordinator), and
prints clustering losses + outlier-detection quality vs ground truth.

    PYTHONPATH=src python examples/quickstart.py [--n-centers 20] [--sites 5]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import simulate_coordinator
from repro.core.metrics import clustering_losses, outlier_scores
from repro.data.synthetic import gauss, partition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-centers", type=int, default=20)
    ap.add_argument("--per-center", type=int, default=2000)
    ap.add_argument("--outliers", type=int, default=400)
    ap.add_argument("--sites", type=int, default=5)
    ap.add_argument("--sigma", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, out_ids = gauss(n_centers=args.n_centers, per_center=args.per_center,
                       sigma=args.sigma, t=args.outliers, seed=args.seed)
    print(f"dataset: {x.shape[0]} points in R^{x.shape[1]}, "
          f"{len(out_ids)} planted outliers")

    parts, gids = partition(x, args.sites, "random", seed=args.seed,
                            outlier_ids=out_ids)
    res = simulate_coordinator(parts, jax.random.key(args.seed),
                               k=args.n_centers, t=args.outliers)

    conc = np.concatenate(gids)
    reported = conc[res["outlier_ids"]]
    summary = conc[res["summary_ids"]]
    sc = outlier_scores(out_ids, summary, reported)
    mask = np.zeros(x.shape[0], bool)
    mask[reported] = True
    l1, l2 = clustering_losses(jnp.asarray(x), jnp.asarray(res["centers"]),
                               jnp.asarray(mask))

    print(f"summary records sent to coordinator: {res['comm_records']:.0f} "
          f"({100 * res['comm_records'] / x.shape[0]:.2f}% of the data)")
    print(f"l1-loss {float(l1):.4g}   l2-loss {float(l2):.4g}")
    print(f"outliers: preRec={sc.pre_recall:.4f} prec={sc.precision:.4f} "
          f"recall={sc.recall:.4f}")


if __name__ == "__main__":
    main()
