"""Multi-device Algorithm 3: one shard_map program over an 8-device mesh.

Each device is a site: it builds its Summary-Outliers summary locally, one
all_gather moves the summaries (the paper's single communication round),
and the replicated second level recovers centers + global outliers.

    PYTHONPATH=src python examples/distributed_outliers.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import distributed_cluster  # noqa: E402
from repro.core.metrics import outlier_scores  # noqa: E402
from repro.data.synthetic import gauss, partition  # noqa: E402


def main():
    s = len(jax.devices())
    print(f"running on {s} devices (sites)")
    x, out_ids = gauss(n_centers=16, per_center=1500, sigma=0.1, t=320, seed=1)
    parts, gids = partition(x, s, "random", seed=3, outlier_ids=out_ids)
    xs = jnp.asarray(np.stack(parts))

    mesh = jax.make_mesh((s,), ("sites",))
    res = distributed_cluster(xs, jax.random.key(0), mesh, k=16, t=320)

    conc = np.concatenate(gids)
    oi = np.asarray(res.outlier_ids)
    reported = conc[oi[oi >= 0]]
    si = np.asarray(res.summary_ids)
    sc = outlier_scores(out_ids, conc[si[si >= 0]], reported)
    print(f"one-round communication: {float(res.comm_records):.0f} records")
    print(f"second-level cost (on summary): {float(res.cost):.4g}")
    print(f"outliers: preRec={sc.pre_recall:.4f} prec={sc.precision:.4f} "
          f"recall={sc.recall:.4f}")


if __name__ == "__main__":
    main()
