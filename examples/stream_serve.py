"""End-to-end streaming demo: ingest -> serve -> checkpoint -> restore.

A stream of Gaussian-cluster points (plus planted outliers) flows into the
merge-and-reduce summary tree; the serving model refreshes on a cadence;
queries are answered from micro-batches; then the whole service state is
checkpointed, restored into a fresh process-equivalent service, and the
restored service is shown to return *identical* scores.

    PYTHONPATH=src python examples/stream_serve.py
"""
import argparse
import tempfile

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import gauss
from repro.stream import ServiceConfig, StreamService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-centers", type=int, default=10)
    ap.add_argument("--per-center", type=int, default=1500)
    ap.add_argument("--t", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir (tmp default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, out_ids = gauss(n_centers=args.n_centers, per_center=args.per_center,
                       t=args.t, sigma=0.1, seed=args.seed)
    n = x.shape[0]
    cfg = ServiceConfig(dim=x.shape[1], k=args.n_centers, t=args.t,
                        leaf_size=2048, refresh_every=max(n // 4, 2048),
                        micro_batch=256, seed=args.seed)
    svc = StreamService(cfg)

    print(f"streaming {n} points in batches of {args.batch} ...")
    for i in range(0, n, args.batch):
        svc.ingest(x[i:i + args.batch])
    svc.refresh()
    print(f"  model v{int(svc.model.version)} on "
          f"{svc.tree.num_records} summary records "
          f"({len(svc.tree.nodes)} tree nodes, "
          f"{svc.tree.total_weight:.0f} mass)")

    # mixed queries: a few inliers and one planted outlier
    inliers = np.setdiff1d(np.arange(n), out_ids)[:4]
    q = np.concatenate([x[inliers], x[out_ids[:1]]])
    results = svc.score(q)
    for r in results:
        tag = "OUTLIER" if r.is_outlier else "inlier "
        print(f"  req {r.request_id}: center {r.center:2d} "
              f"score {r.outlier_score:8.3f}  {tag} "
              f"({r.latency_s * 1e3:.1f} ms)")
    print(f"  latency: {svc.latency_stats()}")

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="stream_ckpt_")
    svc.save(CheckpointManager(ckpt_dir), step=1)
    print(f"checkpointed to {ckpt_dir}; restoring into a fresh service ...")
    restored = StreamService.restore(cfg, CheckpointManager(ckpt_dir))
    results2 = restored.score(q)
    for a, b in zip(results, results2):
        assert a.center == b.center and a.distance == b.distance \
            and a.outlier_score == b.outlier_score, "restore drifted!"
    print(f"  restored model v{int(restored.model.version)}: "
          f"{len(results2)} post-restore scores identical")

    restored.ingest(x[: args.batch])   # the restored service keeps serving
    print(f"  restored service ingested {args.batch} more points "
          f"(total {restored.tree.total_ingested})")
    print("ok")


if __name__ == "__main__":
    main()
