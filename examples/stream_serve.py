"""End-to-end streaming demo on the Session facade.

One ``PipelineConfig`` describes the whole run (problem, policies, stream
topology); ``Session`` drives it: points stream in, the serving model
refreshes on a cadence, queries are answered from micro-batches, then the
session is checkpointed (config embedded in the manifest), restored with
``Session.load`` — no caller-side state — and shown to return *identical*
scores.

    PYTHONPATH=src python examples/stream_serve.py
"""
import argparse
import tempfile

import numpy as np

from repro import Session, pipeline_config
from repro.data.synthetic import gauss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-centers", type=int, default=10)
    ap.add_argument("--per-center", type=int, default=1500)
    ap.add_argument("--t", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir (tmp default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, out_ids = gauss(n_centers=args.n_centers, per_center=args.per_center,
                       t=args.t, sigma=0.1, seed=args.seed)
    n = x.shape[0]
    cfg = pipeline_config(
        dim=x.shape[1], k=args.n_centers, t=args.t, topology="stream",
        leaf_size=2048, refresh_every=max(n // 4, 2048), micro_batch=256,
        seed=args.seed)
    sess = Session(cfg)

    print(f"streaming {n} points in batches of {args.batch} ...")
    for i in range(0, n, args.batch):
        sess.ingest(x[i:i + args.batch])
    sess.refresh()
    tree = sess.engine.tree
    print(f"  model v{int(sess.model.version)} on "
          f"{tree.num_records} summary records "
          f"({len(tree.nodes)} tree nodes, {tree.total_weight:.0f} mass)")

    # mixed queries: a few inliers and one planted outlier
    inliers = np.setdiff1d(np.arange(n), out_ids)[:4]
    q = np.concatenate([x[inliers], x[out_ids[:1]]])
    results = sess.score(q)
    for r in results:
        tag = "OUTLIER" if r.is_outlier else "inlier "
        print(f"  req {r.request_id}: center {r.center:2d} "
              f"score {r.outlier_score:8.3f}  {tag} "
              f"({r.latency_s * 1e3:.1f} ms)")
    print(f"  latency: {sess.latency_stats()}")

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="stream_ckpt_")
    step = sess.save(ckpt_dir)
    print(f"checkpointed to {ckpt_dir} @ step {step} (config embedded); "
          f"restoring from the checkpoint alone ...")
    restored = Session.load(ckpt_dir)
    assert restored.config == cfg, "embedded config drifted!"
    results2 = restored.score(q)
    for a, b in zip(results, results2):
        assert a.center == b.center and a.distance == b.distance \
            and a.outlier_score == b.outlier_score, "restore drifted!"
    print(f"  restored model v{int(restored.model.version)}: "
          f"{len(results2)} post-restore scores identical")

    restored.ingest(x[: args.batch])   # the restored session keeps serving
    print(f"  restored session ingested {args.batch} more points "
          f"(total {restored.engine.tree.total_ingested})")
    print("ok")


if __name__ == "__main__":
    main()
