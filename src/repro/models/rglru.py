"""RecurrentGemma / Griffin blocks (arXiv:2402.19427): RG-LRU recurrence +
local sliding-window attention, interleaved 2 recurrent : 1 attention.

The RG-LRU is a diagonal gated linear recurrence:

    r_t = sigmoid(x_t * w_r + b_r)          (diagonal gates — see DESIGN:
    i_t = sigmoid(x_t * w_i + b_i)           the paper uses block-diagonal;
    a_t = exp(-c * softplus(Lambda) * r_t)    diagonal keeps param count per
    h_t = a_t h_{t-1} + sqrt(1-a_t^2)(i_t x_t) the assigned 38L/4096 budget)

Training evaluates it with ``jax.lax.associative_scan`` (O(T log T) fully
parallel elementwise work — no MXU needed, which is precisely why this arch
is memory-term-dominated in the roofline table). Decode is a single fused
step with O(1) state, which is why recurrentgemma runs the long_500k shape.

A width-4 depthwise temporal conv precedes the recurrence (carried as 3
tokens of state at decode time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (Params, ShardCtx, dense_init, rmsnorm,
                                 rmsnorm_init)

_C = 8.0  # Griffin's fixed scale inside a_t


def rglru_layer_init(key, cfg: ModelConfig, dtype) -> Params:
    D = cfg.d_model
    W = cfg.lru_width or D
    ks = jax.random.split(key, 4)
    return {
        "ln": rmsnorm_init(D),
        "w_x": dense_init(ks[0], D, W, dtype),
        "w_y": dense_init(ks[1], D, W, dtype),
        "w_out": dense_init(ks[2], W, D, dtype),
        "conv": (jax.random.normal(ks[3], (4, W), jnp.float32) * 0.1).astype(dtype),
        "gate_r_w": jnp.zeros((W,), jnp.float32),
        "gate_r_b": jnp.zeros((W,), jnp.float32),
        "gate_i_w": jnp.zeros((W,), jnp.float32),
        "gate_i_b": jnp.zeros((W,), jnp.float32),
        # Lambda init so a ~ U[0.9, 0.999]^c at r=1 (Griffin appendix)
        "lam": jnp.linspace(0.3, 1.5, W, dtype=jnp.float32),
    }


def _conv4(x, w, carry):
    """Depthwise causal conv, width 4. x: (B,T,W); carry: (B,3,W)."""
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, 3 - j: xp.shape[1] - j, :] * w[3 - j] for j in range(4))
    return out, xp[:, -3:, :]


def rglru_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, ctx: ShardCtx,
                state: Params | None = None):
    """state = {"h": (B, W), "conv": (B, 3, W)} for decode; None for train."""
    B, T, D = x.shape
    W = cfg.lru_width or D
    if state is None:
        state = {"h": jnp.zeros((B, W), jnp.float32),
                 "conv": jnp.zeros((B, 3, W), x.dtype)}

    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    gate = jax.nn.gelu(xn @ p["w_y"])                       # (B,T,W)
    u = xn @ p["w_x"]
    if ctx.mesh is not None:
        gate = ctx.hint(gate, ctx.batch, None, ctx.model)
        u = ctx.hint(u, ctx.batch, None, ctx.model)
    u, conv_carry = _conv4(u, p["conv"], state["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["gate_r_w"] + p["gate_r_b"])
    i = jax.nn.sigmoid(uf * p["gate_i_w"] + p["gate_i_b"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r             # (B,T,W) <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)

    if T == 1:
        h = a[:, 0] * state["h"] + b[:, 0]
        hs = h[:, None, :]
    else:
        # h_t = a_t h_{t-1} + b_t via associative scan; fold the carried
        # state in as an extra leading element.
        a_ext = jnp.concatenate([jnp.ones((B, 1, W)), a], axis=1)
        b_ext = jnp.concatenate([state["h"][:, None, :], b], axis=1)

        def combine(lhs, rhs):
            (al, bl), (ar, br) = lhs, rhs
            return al * ar, bl * ar + br

        _, hs_all = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
        hs = hs_all[:, 1:, :]
        h = hs[:, -1, :]

    out = (hs.astype(x.dtype) * gate) @ p["w_out"]
    x = x + ctx.residual(out)
    return x, {"h": h, "conv": conv_carry}
