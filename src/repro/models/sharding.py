"""Parameter / optimizer-state / batch PartitionSpecs.

Scheme (DESIGN §6): TP over ``model`` for heads / ffn / vocab / experts,
ZeRO-3-style FSDP over the batch axes (``data``, plus ``pod`` multi-pod) on
the complementary dim.  Rules are name+rank based so the one function covers
all five families; stacked layer params get a leading None for the scan dim.

Optimizer moments inherit the param specs verbatim (same shapes).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    """Batch-like axes = everything that isn't the model axis."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _leaf_spec(flat_name: str, ndim: int, fsdp, model="model") -> P:
    """Spec for an UNSTACKED leaf (rank without the layer-stack dims)."""
    n = flat_name
    last = n.rsplit("/", 1)[-1]  # exact leaf name (endswith("u") would
    #                              otherwise swallow "mu" etc.)
    # --- embeddings / head ---
    if n.endswith("embed/table"):
        return P(model, fsdp)
    if n.endswith("lm_head"):
        return P(fsdp, model)
    if "frontend" in n:
        return P(None, fsdp)
    # --- norms / small vectors / scalars ---
    if ndim <= 1:
        return P(*([None] * ndim))
    # --- attention ---
    if n.endswith(("attn/wq", "attn/wk", "attn/wv", "xattn/wq", "xattn/wk", "xattn/wv")):
        return P(fsdp, model)
    if n.endswith(("attn/wo", "xattn/wo")):
        return P(model, fsdp)
    # --- moe experts: EP over model, FSDP over the expert-internal in-dim ---
    if n.endswith(("we_g", "we_i")):
        return P(model, fsdp, None)
    if n.endswith("we_o"):
        return P(model, None, fsdp)
    if n.endswith("router"):
        return P(fsdp, None)
    # --- mlp / rwkv cmix / rglru projections: in->hidden cols on model ---
    if n.endswith(("mlp/wi", "mlp/wg", "shared/wi", "shared/wg", "cmix/wk",
                   "w_x", "w_y", "tmix/wr", "tmix/wk", "tmix/wv", "tmix/wg",
                   "cmix/wr")):
        return P(fsdp, model)
    if n.endswith(("mlp/wo", "shared/wo", "cmix/wv", "w_out", "tmix/wo")):
        return P(model, fsdp)
    if n.endswith(("tmix/wa",)):
        return P(fsdp, None)
    if n.endswith(("tmix/wb",)):
        return P(None, fsdp)
    if last == "conv":
        return P(None, model)
    if last in ("w0", "u"):      # (H, hd)
        return P(model, None)
    if last == "mu":             # (5, D)
        return P(None, None)
    # fallback: FSDP on dim 0
    return P(*([fsdp] + [None] * (ndim - 1)))


def _stack_depth(path) -> int:
    """How many leading dims are layer-stack dims: one per vmap'd level.
    Heuristic: keys named 'layers'/'groups'/'tail'/'enc_layers'/'dec_layers'
    add one; a nested 'recs'/'dense' stack adds another."""
    names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
    depth = 0
    for nm in names:
        if nm in ("layers", "groups", "tail", "enc_layers", "dec_layers"):
            depth += 1
        if nm in ("recs", "dense"):
            depth += 1
    return depth


def fix_divisibility(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from any spec entry whose dim they don't divide
    (e.g. vocab=256206 on a 16-way axis, or batch=1 decode): jit input
    shardings require even tiling."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        prod = 1
        for a in axes:
            if shape[d] % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def _strip_axes(spec: P, axes: set) -> P:
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        es = entry if isinstance(entry, tuple) else (entry,)
        keep = tuple(a for a in es if a not in axes)
        out.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def param_specs(params_shape, mesh: Mesh, *, fsdp_params: bool = True):
    """Map a pytree of ShapeDtypeStructs (or arrays) -> pytree of
    NamedShardings.

    fsdp_params=False is ZeRO-2: weights stay TP-sharded-only (resident, no
    per-layer all-gather); optimizer moments keep the full FSDP sharding via
    a separate param_specs(..., fsdp_params=True) call (see dryrun)."""
    fsdp_t = fsdp_axes(mesh)
    fsdp = fsdp_t if len(fsdp_t) > 1 else fsdp_t[0]

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        flat = "/".join(names)
        depth = _stack_depth(path)
        nd = len(leaf.shape) - depth
        s = _leaf_spec(flat, nd, fsdp)
        if not fsdp_params:
            s = _strip_axes(s, set(fsdp_t))
        full = P(*([None] * depth + list(s)))
        return NamedSharding(mesh, fix_divisibility(full, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def batch_specs(batch_shape, mesh: Mesh):
    """Input batch: leading dim over all batch axes."""
    bd = fsdp_axes(mesh)

    def spec(path, leaf):
        if len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        full = P(bd, *([None] * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, fix_divisibility(full, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_specs(cache_shape, mesh: Mesh, cfg=None):
    """KV caches: batch dim over batch axes, head/width dims over model where
    profitable. Layer-stacked leading dims stay unsharded."""
    bd = fsdp_axes(mesh)

    def mk(pspec, shape):
        return NamedSharding(mesh, fix_divisibility(pspec, shape, mesh))

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        nm = names[-1] if names else ""
        shape = leaf.shape
        if nm in ("kpos", "pos") or len(shape) <= 1:
            return mk(P(), shape)
        if nm in ("k", "v", "ck", "cv"):
            # (L[, sub], B, W, Hkv, hd): shard B over batch axes; shard W
            # (the long dim) over model — decode attention reduces over W.
            lead = len(shape) - 4
            return mk(P(*([None] * lead), bd, "model", None, None), shape)
        if nm == "s":         # rwkv state (L,B,H,K,V)
            return mk(P(None, bd, "model", None, None), shape)
        if nm in ("ts_t", "ts_c"):           # (L, B, D)
            return mk(P(None, bd, None), shape)
        if nm == "h":                        # (G, rpa, B, W)
            return mk(P(None, None, bd, "model"), shape)
        if nm == "tail_h":                   # (tail, B, W)
            return mk(P(None, bd, "model"), shape)
        if nm == "conv":                     # (G, rpa, B, 3, W)
            return mk(P(None, None, bd, None, "model"), shape)
        if nm == "tail_conv":                # (tail, B, 3, W)
            return mk(P(None, bd, None, "model"), shape)
        return mk(P(*([None] * len(shape))), shape)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)
