"""Model configuration shared by every assigned architecture.

One dataclass covers the five families (dense / moe / rwkv6 / rglru_hybrid /
encdec) so the trainer, server, dry-run, and roofline code are
family-agnostic; family-specific blocks live in their own modules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv6 | rglru_hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_type: str = "swiglu"       # swiglu | gelu (gpt-bigcode style)
    sliding_window: int = 0        # 0 = full attention; >0 = SWA width
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert FFN width
    moe_every: int = 1             # MoE layer every N layers (others dense d_ff)
    shared_expert_d_ff: int = 0    # 0 = no shared expert
    capacity_factor: float = 1.25
    moe_group_tokens: int = 4096   # dispatch-group granularity (see moe.py)

    # enc-dec
    n_enc_layers: int = 0

    # hybrid (recurrentgemma): `pattern` repeats [R]*rec_per_attn + [A]
    rec_per_attn: int = 0
    local_window: int = 0
    lru_width: int = 0             # 0 -> d_model

    # rwkv6
    rwkv_head_dim: int = 64
    wkv_chunk: int = 16            # intra-chunk length of the chunked WKV scan
    wkv_compute_dtype: str = "float32"  # bf16: halve intra-chunk HBM traffic
    #   (decay cumsums + carried state stay f32 regardless)
    wkv_use_pallas: bool = False   # route WKV through the Pallas chunk kernel

    # modality frontend (stub: input_specs provides precomputed embeddings)
    frontend: str = "none"         # none | vlm_patches | audio_frames
    frontend_tokens: int = 0       # patches / frames prepended to text
    frontend_dim: int = 0          # raw patch/frame feature dim (stub proj in)

    # numerics & distribution knobs (perf levers — see EXPERIMENTS §Perf)
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat_policy: str = "nothing"  # nothing | dots | none(=no remat)
    seq_shard_activations: bool = True
    attn_q_chunk: int = 1024       # query-chunked attention block
    attn_chunk_remat: bool = False # re-materialize scores per q-chunk in bwd
    wkv_inner_remat: bool = False  # recompute WKV chunk internals in bwd
    zero_stage: int = 3            # 3 = params+moments FSDP; 2 = moments only

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        return self.family in ("rwkv6", "rglru_hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoder (none are encoder-only)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = D * hd * Hq + 2 * D * hd * Hkv + hd * Hq * D
        if self.qkv_bias:
            attn += hd * (Hq + 2 * Hkv)
        dense_ffn = (3 if self.mlp_type == "swiglu" else 2) * D * F
        norms = 2 * D

        if self.family == "rwkv6":
            hdim = self.rwkv_head_dim
            H = D // hdim
            tmix = 5 * D * D           # r,k,v,g,out projections (decay is LoRA-only)
            tmix += 2 * 64 * D         # decay LoRA (rank 64)
            tmix += 5 * D + H * hdim   # token-shift mus + bonus u
            cmix = D * F + F * D + D * D  # channel mix: key, value, receptance
            per_layer = tmix + cmix + norms
            body = self.n_layers * per_layer
        elif self.family == "rglru_hybrid":
            W = self.lru_width or D
            rec = 2 * D * W + W * D + 6 * W  # in/out projections + LRU gates/Lambda
            conv = 4 * W                     # depthwise temporal conv (width 4)
            rec_block = rec + conv + dense_ffn + norms
            attn_block = attn + dense_ffn + norms
            n_attn = self.n_layers // (self.rec_per_attn + 1)
            body = n_attn * attn_block + (self.n_layers - n_attn) * rec_block
        elif self.family == "moe":
            Fe = self.moe_d_ff
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            experts = self.n_experts * 3 * D * Fe
            shared = 3 * D * self.shared_expert_d_ff if self.shared_expert_d_ff else 0
            router = D * self.n_experts
            body = (self.n_layers * (attn + norms)
                    + n_moe * (experts + shared + router)
                    + n_dense * dense_ffn)
        elif self.family == "encdec":
            enc_layer = attn + dense_ffn + norms
            dec_layer = attn + attn + dense_ffn + 3 * D  # self + cross
            body = self.n_enc_layers * enc_layer + self.n_layers * dec_layer
        else:
            body = self.n_layers * (attn + dense_ffn + norms)

        embed = V * D
        head = 0 if self.tie_embeddings else V * D
        total = body + embed + head + D

        if active_only and self.family == "moe":
            Fe = self.moe_d_ff
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            active_experts = self.top_k * 3 * D * Fe
            shared = 3 * D * self.shared_expert_d_ff if self.shared_expert_d_ff else 0
            total = (self.n_layers * (attn + norms)
                     + n_moe * (active_experts + shared + D * self.n_experts)
                     + n_dense * dense_ffn
                     + embed + head + D)
        return int(total)
