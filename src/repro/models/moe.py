"""Token-choice top-k MoE with grouped, sort-based "dropped" dispatch.

Why not the classic one-hot dispatch: a (tokens, E, C) dispatch tensor at
train_4k scale (1M tokens, 128 experts) is ~10^13 elements.  Why not a
global argsort: under GSPMD a global sort over all tokens gathers the whole
batch onto every device.

Instead tokens are split into GROUPS (default 4096 tokens = one train
sequence; for decode, one group per data shard).  Dispatch happens
independently per group, entirely with group-local ops:

  router -> top-k -> per-group argsort by expert id -> position-in-expert
  via exclusive-cumsum of per-expert counts -> capacity clip (drop) ->
  scatter into a (G, E, C, D) buffer -> 3 grouped einsums (SwiGLU experts)
  -> gather back -> weighted combine (+ optional shared expert).

Sharding: the buffer is constrained to P(batch_axes on G, model on E) — the
group axis stays data-sharded while the expert axis is model-sharded (EP),
so GSPMD materializes exactly one dispatch reshard (the all-to-all
equivalent) per MoE layer in the lowered HLO.

Capacity C = ceil(group_tokens * K / E * capacity_factor): compiled expert
FLOPs are within capacity_factor of the ideal active-parameter FLOPs — this
shows up directly in the roofline's MODEL_FLOPS/HLO_FLOPs ratio and is a
§Perf lever.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, ShardCtx, mlp, mlp_init


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    E, D, Fe = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * std),
        "we_g": (jax.random.normal(ks[1], (E, D, Fe), jnp.float32) * std).astype(dtype),
        "we_i": (jax.random.normal(ks[2], (E, D, Fe), jnp.float32) * std).astype(dtype),
        "we_o": (jax.random.normal(ks[3], (E, Fe, D), jnp.float32) * (Fe ** -0.5)).astype(dtype),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = mlp_init(ks[4], D, cfg.shared_expert_d_ff, dtype)
    return p


def _group_tokens(cfg: ModelConfig, n_tokens: int, ctx: ShardCtx) -> int:
    bd = 1
    if ctx.mesh is not None:
        for a in (ctx.batch if isinstance(ctx.batch, tuple) else (ctx.batch,)):
            bd *= dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))[a]
    per_shard = max(1, n_tokens // bd)
    g = int(min(cfg.moe_group_tokens, per_shard))
    while n_tokens % g:  # largest divisor of n_tokens not above the target
        g -= 1
    return g


def moe_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig, ctx: ShardCtx):
    """x: (B, S, D) -> (same, aux_metrics)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    g = _group_tokens(cfg, N, ctx)
    assert N % g == 0, f"tokens {N} not divisible by group size {g}"
    G = N // g
    C = max(1, math.ceil(g * K / E * cfg.capacity_factor))

    xg = x.reshape(G, g, D)
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                     # (G, g, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = jnp.zeros((E,)).at[topi.reshape(-1)].add(1.0) / (G * g * K)
    aux_loss = E * jnp.sum(me * ce)

    ids_f = topi.reshape(G, g * K)
    w_f = topw.reshape(G, g * K).astype(x.dtype)
    tok_f = jnp.repeat(jnp.arange(g), K)[None].repeat(G, 0)  # (G, gK) token idx

    order = jnp.argsort(ids_f, axis=1)                       # stable
    se = jnp.take_along_axis(ids_f, order, axis=1)           # sorted expert ids
    st = jnp.take_along_axis(tok_f, order, axis=1)           # their token idx

    counts = jax.vmap(lambda i: jnp.zeros((E,), jnp.int32).at[i].add(1))(ids_f)
    starts = jnp.cumsum(counts, axis=1) - counts             # exclusive
    pos = jnp.arange(g * K)[None] - jnp.take_along_axis(starts, se, axis=1)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                          # C -> dropped slot

    def scatter_group(xg_g, se_g, st_g, pos_g):
        upd = xg_g[st_g]                                     # (gK, D)
        return jnp.zeros((E, C, D), x.dtype).at[se_g, pos_g].add(upd, mode="drop")

    buf = jax.vmap(scatter_group)(xg, se, st, pos_c)         # (G, E, C, D)
    if ctx.mesh is not None:
        buf = ctx.hint(buf, ctx.batch, ctx.model, None, None)

    hg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["we_g"]))
    hi = jnp.einsum("gecd,edf->gecf", buf, p["we_i"])
    ho = jnp.einsum("gecf,efd->gecd", hg * hi, p["we_o"])    # (G, E, C, D)
    if ctx.mesh is not None:
        ho = ctx.hint(ho, ctx.batch, ctx.model, None, None)

    def gather_group(ho_g, se_g, pos_g, keep_g, w_g, st_g):
        out = ho_g[se_g, jnp.minimum(pos_g, C - 1)]          # (gK, D)
        out = out * (keep_g[:, None] * w_g[:, None])
        return jnp.zeros((g, D), x.dtype).at[st_g].add(out)

    w_sorted = jnp.take_along_axis(w_f, order, axis=1)
    yg = jax.vmap(gather_group)(ho, se, pos_c, keep, w_sorted, st)  # (G, g, D)
    y = yg.reshape(B, S, D)

    if "shared" in p:
        y = y + mlp(p["shared"], x, ctx)

    drop_frac = 1.0 - keep.mean()
    return ctx.residual(y), {"aux_loss": aux_loss, "drop_frac": drop_frac}
