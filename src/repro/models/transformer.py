"""Unified model: parameter init, training forward, and cached decode for
all five assigned families (dense / moe / rwkv6 / rglru_hybrid / encdec).

Design rules that keep the 40-cell dry-run tractable:

* layers are STACKED and SCANNED (`lax.scan` over a (L, ...) parameter
  pytree) — one lowered layer body per family regardless of depth;
* remat (`jax.checkpoint`) wraps the scan body, policy from cfg.remat_policy;
* every activation that matters carries a sharding hint via ShardCtx so the
  same code lowers on 1 CPU device (smoke tests) and on the 512-chip mesh;
* decode uses absolute-position ring-buffer KV caches: slot = pos % W, a
  (W,) `kpos` table stores each slot's absolute position, and the attention
  mask is computed from absolute positions — windowed and full caches share
  one code path (this is what makes long_500k a W-sized cache for SWA).

The modality frontends are stubs per the assignment: `input_specs()`
supplies precomputed patch/frame embeddings; here they are linearly
projected and prepended (vlm / early fusion) or encoded (audio enc-dec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (Params, ShardCtx, attention, dense_init,
                                 embed, embed_init, mlp, mlp_init, rmsnorm,
                                 rmsnorm_init, unembed)
from repro.models.moe import moe_ffn, moe_init
from repro.models.rglru import rglru_block, rglru_layer_init
from repro.models.rwkv6 import rwkv_block, rwkv_layer_init

AUX_LOSS_COEF = 0.01


# =============================================================== initialization
def _attn_layer_init(key, cfg, dtype, cross: bool = False, moe_layer: bool | None = None):
    from repro.models.layers import attn_init
    if moe_layer is None:
        moe_layer = cfg.family == "moe"
    ks = jax.random.split(key, 4)
    p = {"ln1": rmsnorm_init(cfg.d_model), "attn": attn_init(ks[0], cfg, dtype),
         "ln2": rmsnorm_init(cfg.d_model)}
    if cross:
        p["ln_x"] = rmsnorm_init(cfg.d_model)
        p["xattn"] = attn_init(ks[1], cfg, dtype)
    if moe_layer:
        p["moe"] = moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype, cfg.mlp_type)
    return p


def _moe_group_init(key, cfg, dtype):
    """One scanned MoE super-layer: (moe_every - 1) dense layers + 1 MoE
    layer (llama4 interleaves MoE every other layer)."""
    ks = jax.random.split(key, cfg.moe_every)
    g = {"moe": _attn_layer_init(ks[-1], cfg, dtype, moe_layer=True)}
    if cfg.moe_every > 1:
        g["dense"] = jax.vmap(
            lambda k: _attn_layer_init(k, cfg, dtype, moe_layer=False))(ks[:-1])
    return g


def _rglru_group_init(key, cfg, dtype):
    """One scanned group: rec_per_attn recurrent layers + 1 attention layer,
    each followed by its own MLP."""
    ks = jax.random.split(key, cfg.rec_per_attn + 1)
    recs = jax.vmap(lambda k: _rec_layer_init(k, cfg, dtype))(ks[:-1])
    att = _attn_layer_init(ks[-1], cfg, dtype)
    return {"recs": recs, "attn": att}


def _rec_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"rec": rglru_layer_init(k1, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], V, D, dtype),
        "final_norm": rmsnorm_init(D),
        "lm_head": dense_init(keys[1], D, V, dtype),
    }
    if cfg.frontend != "none":
        params["frontend"] = {"proj": dense_init(keys[2], cfg.frontend_dim, D, dtype)}

    if cfg.family == "dense":
        lk = jax.random.split(keys[3], L)
        params["layers"] = jax.vmap(lambda k: _attn_layer_init(k, cfg, dtype))(lk)
    elif cfg.family == "moe":
        assert L % cfg.moe_every == 0
        gk = jax.random.split(keys[3], L // cfg.moe_every)
        params["layers"] = jax.vmap(lambda k: _moe_group_init(k, cfg, dtype))(gk)
    elif cfg.family == "rwkv6":
        lk = jax.random.split(keys[3], L)
        params["layers"] = jax.vmap(lambda k: rwkv_layer_init(k, cfg, dtype))(lk)
    elif cfg.family == "rglru_hybrid":
        group = cfg.rec_per_attn + 1
        n_groups, tail = divmod(L, group)
        gk = jax.random.split(keys[3], n_groups)
        params["groups"] = jax.vmap(lambda k: _rglru_group_init(k, cfg, dtype))(gk)
        if tail:
            tk = jax.random.split(keys[4], tail)
            params["tail"] = jax.vmap(lambda k: _rec_layer_init(k, cfg, dtype))(tk)
    elif cfg.family == "encdec":
        ek = jax.random.split(keys[3], cfg.n_enc_layers)
        dk = jax.random.split(keys[4], L)
        params["enc_layers"] = jax.vmap(lambda k: _attn_layer_init(k, cfg, dtype))(ek)
        params["dec_layers"] = jax.vmap(
            lambda k: _attn_layer_init(k, cfg, dtype, cross=True))(dk)
    else:
        raise ValueError(cfg.family)
    return params


# =============================================================== layer bodies
def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    pol = (jax.checkpoint_policies.nothing_saveable if cfg.remat_policy == "nothing"
           else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=pol)


def _sp_hint(x, ctx):
    """Megatron-SP boundary at norm outputs: forces forward all-gather /
    backward REDUCE-SCATTER in bf16 at this point. Without it GSPMD sums the
    TP partial grads with a full-tensor f32 all-reduce (~4x the wire bytes;
    EXPERIMENTS §Perf qwen2-72b iteration 3)."""
    if ctx.mesh is not None and x.shape[1] > 1:
        return ctx.residual(x)
    return x


def _ffn(p, x, cfg, ctx):
    """ln2 + (mlp | moe). Returns (x, aux_loss)."""
    xn = _sp_hint(rmsnorm(p["ln2"], x, cfg.norm_eps), ctx)
    if cfg.family == "moe" and "moe" in p:
        m, aux = moe_ffn(p["moe"], xn, cfg, ctx)
        return x + m, aux["aux_loss"]
    return x + mlp(p["mlp"], xn, ctx), jnp.float32(0.0)


def _dense_layer_train(p, x, cfg, ctx, positions, *, causal=True,
                       window=None, use_rope=True, enc_kv=None):
    xn = _sp_hint(rmsnorm(p["ln1"], x, cfg.norm_eps), ctx)
    h, _ = attention(p["attn"], xn, cfg, ctx, positions=positions,
                     causal=causal, window=cfg.sliding_window if window is None else window,
                     use_rope=use_rope)
    x = x + h
    if enc_kv is not None:  # cross attention (enc-dec decoder)
        xc = _sp_hint(rmsnorm(p["ln_x"], x, cfg.norm_eps), ctx)
        hx, _ = attention(p["xattn"], xc, cfg, ctx, kv=enc_kv,
                          positions=positions, causal=False, window=0,
                          use_rope=False)
        x = x + hx
    return _ffn(p, x, cfg, ctx)


# =============================================================== train forward
def _embed_inputs(params, batch, cfg, ctx):
    """Returns (x (B,S,D), loss_mask (B,S)) — mask True where next-token loss
    applies (text region, excluding the frontend prefix)."""
    tokens = batch["tokens"]
    x_txt = embed(params["embed"], tokens)
    if cfg.frontend == "none" or cfg.family == "encdec":
        # encdec consumes frames in the encoder, not as a decoder prefix
        return ctx.residual(x_txt), jnp.ones_like(tokens, bool)
    feats = batch["patches"] if cfg.frontend == "vlm_patches" else batch["frames"]
    x_pre = feats.astype(x_txt.dtype) @ params["frontend"]["proj"]
    x = jnp.concatenate([x_pre, x_txt], axis=1)
    mask = jnp.concatenate(
        [jnp.zeros(x_pre.shape[:2], bool), jnp.ones_like(tokens, bool)], axis=1)
    return ctx.residual(x), mask


def ce_loss(logits, tokens, mask):
    """Next-token CE. logits (B,S,V) f32; predict tokens[:, t+1] at t."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    m = (mask[:, 1:] & mask[:, :-1]).astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * m
    return nll.sum() / jnp.maximum(m.sum(), 1.0)


def forward_train(params: Params, batch: dict, cfg: ModelConfig, ctx: ShardCtx):
    """Returns (loss, metrics). Family-dispatched, scan-over-layers."""
    if cfg.family == "encdec":
        return _forward_train_encdec(params, batch, cfg, ctx)

    x, mask = _embed_inputs(params, batch, cfg, ctx)
    B, S, D = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)

    if cfg.family == "dense":
        def body(carry, lp):
            x, aux = carry
            x, a = _dense_layer_train(lp, x, cfg, ctx, positions)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, jnp.float32(0.0)),
                                   params["layers"])
    elif cfg.family == "moe":
        def body(carry, gp):
            x, aux = carry
            for j in range(cfg.moe_every - 1):      # static unroll (<= 1 here)
                lp = jax.tree.map(lambda a: a[j], gp["dense"])
                x, a = _dense_layer_train(lp, x, cfg, ctx, positions)
                aux = aux + a
            x, a = _dense_layer_train(gp["moe"], x, cfg, ctx, positions)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, jnp.float32(0.0)),
                                   params["layers"])
    elif cfg.family == "rwkv6":
        def body(carry, lp):
            x, aux = carry
            x, _ = rwkv_block(lp, x, cfg, ctx)
            return (x, aux), None
        (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, jnp.float32(0.0)),
                                   params["layers"])
    elif cfg.family == "rglru_hybrid":
        def rec_body(carry, lp):
            x, aux = carry
            x, _ = rglru_block(lp["rec"], x, cfg, ctx)
            x, a = _ffn(lp, x, cfg, ctx)
            return (x, aux + a), None

        def group_body(carry, gp):
            carry, _ = jax.lax.scan(rec_body, carry, gp["recs"])
            x, aux = carry
            x, a = _dense_layer_train(gp["attn"], x, cfg, ctx, positions,
                                      window=cfg.local_window)
            return ((x, aux + a), None)
        (x, aux), _ = jax.lax.scan(_remat(group_body, cfg),
                                   (x, jnp.float32(0.0)), params["groups"])
        if "tail" in params:
            (x, aux), _ = jax.lax.scan(_remat(rec_body, cfg), (x, aux),
                                       params["tail"])
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["lm_head"], x, ctx)
    # CE over the text region: frontends put text last, so slice it out.
    S_txt = batch["tokens"].shape[1]
    loss = ce_loss(logits[:, -S_txt:], batch["tokens"], mask[:, -S_txt:])
    total = loss + AUX_LOSS_COEF * aux
    return total, {"ce": loss, "aux": aux}


def _forward_train_encdec(params, batch, cfg, ctx):
    frames, tokens = batch["frames"], batch["tokens"]
    x_enc = ctx.residual(frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend"]["proj"])
    pos_e = jnp.arange(x_enc.shape[1], dtype=jnp.int32)

    def enc_body(carry, lp):
        x, aux = carry
        x, a = _dense_layer_train(lp, x, cfg, ctx, pos_e, causal=False)
        return (x, aux + a), None
    (x_enc, aux), _ = jax.lax.scan(_remat(enc_body, cfg),
                                   (x_enc, jnp.float32(0.0)),
                                   params["enc_layers"])
    x_enc = rmsnorm(params["final_norm"], x_enc, cfg.norm_eps)

    x = ctx.residual(embed(params["embed"], tokens))
    pos_d = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def dec_body(carry, lp):
        x, aux = carry
        # cross-attn keys from the encoder output (projected per layer)
        from repro.models.layers import kv_proj
        ck, cv = kv_proj(lp["xattn"], x_enc, cfg, pos_e, use_rope=False)
        x, a = _dense_layer_train(lp, x, cfg, ctx, pos_d,
                                  enc_kv=(ck, cv, pos_e, None))
        return (x, aux + a), None
    (x, aux), _ = jax.lax.scan(_remat(dec_body, cfg), (x, aux),
                               params["dec_layers"])

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["lm_head"], x, ctx)
    loss = ce_loss(logits, tokens, jnp.ones_like(tokens, bool))
    return loss + AUX_LOSS_COEF * aux, {"ce": loss, "aux": aux}


# =============================================================== prefill
def forward_prefill(params: Params, batch: dict, cfg: ModelConfig, ctx: ShardCtx,
                    max_len: int | None = None):
    """Process a full prompt, returning (last-token logits (B,V), cache).

    The cache layout matches init_cache/forward_decode: a ring buffer of
    width W = cache_window(cfg, max_len) where the key of absolute position
    p lives at slot p % W (kpos records each slot's absolute position, -1
    for empty).  Pass max_len > prompt length to leave generation head-room
    on full-attention archs; SWA archs cap W at their window."""
    x, _ = _embed_inputs(params, batch, cfg, ctx)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    W = cache_window(cfg, max_len if max_len is not None else S)
    m = min(W, S)
    slots = positions[-m:] % W

    def keep_last(k):  # (B,S,Hkv,hd) -> (B,W,Hkv,hd), slot = pos % W
        buf = jnp.zeros((B, W) + k.shape[2:], k.dtype)
        return buf.at[:, slots].set(k[:, -m:])

    kpos = jnp.full((W,), -1, jnp.int32).at[slots].set(positions[-m:])

    if cfg.family in ("dense", "moe"):
        def one_layer(lp, x):
            xn = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, (k, v) = attention(lp["attn"], xn, cfg, ctx, positions=positions,
                                  causal=True, window=cfg.sliding_window)
            x = x + h
            x, _ = _ffn(lp, x, cfg, ctx)
            return x, keep_last(k), keep_last(v)

        if cfg.family == "dense":
            def body(carry, lp):
                x, k, v = one_layer(lp, carry)
                return x, (k, v)
            x, (ks, vs) = jax.lax.scan(_remat(body, cfg), x, params["layers"])
        else:
            def body(carry, gp):
                x = carry
                kk, vv = [], []
                for j in range(cfg.moe_every - 1):
                    lp = jax.tree.map(lambda a: a[j], gp["dense"])
                    x, k, v = one_layer(lp, x)
                    kk.append(k)
                    vv.append(v)
                x, k, v = one_layer(gp["moe"], x)
                kk.append(k)
                vv.append(v)
                return x, (jnp.stack(kk), jnp.stack(vv))
            x, (ks, vs) = jax.lax.scan(_remat(body, cfg), x, params["layers"])
        cache = {"k": ks, "v": vs, "kpos": kpos, "pos": jnp.int32(S)}
    elif cfg.family == "rwkv6":
        def body(carry, lp):
            x = carry
            x, st = rwkv_block(lp, x, cfg, ctx)
            return x, (st["ts_t"], st["ts_c"], st["s"])
        x, (t1, t2, s) = jax.lax.scan(_remat(body, cfg), x, params["layers"])
        cache = {"ts_t": t1, "ts_c": t2, "s": s, "pos": jnp.int32(S)}
    elif cfg.family == "rglru_hybrid":
        def rec_body(carry, lp):
            x = carry
            x, st = rglru_block(lp["rec"], x, cfg, ctx)
            x, _ = _ffn(lp, x, cfg, ctx)
            return x, (st["h"], st["conv"])

        def group_body2(carry, gp):
            x = carry
            x, (hs, convs) = jax.lax.scan(rec_body, x, gp["recs"])
            xn = rmsnorm(gp["attn"]["ln1"], x, cfg.norm_eps)
            h, (k, v) = attention(gp["attn"]["attn"], xn, cfg, ctx,
                                  positions=positions, causal=True,
                                  window=cfg.local_window)
            x = x + h
            x, _ = _ffn(gp["attn"], x, cfg, ctx)
            return x, (keep_last(k), keep_last(v), hs, convs)
        x, (ks, vs, hs, convs) = jax.lax.scan(_remat(group_body2, cfg), x,
                                              params["groups"])
        cache = {"k": ks, "v": vs, "h": hs, "conv": convs,
                 "kpos": kpos, "pos": jnp.int32(S)}
        if "tail" in params:
            x, (th, tc) = jax.lax.scan(_remat(rec_body, cfg), x, params["tail"])
            cache["tail_h"], cache["tail_conv"] = th, tc
    elif cfg.family == "encdec":
        frames = batch["frames"]
        x_enc = ctx.residual(frames.astype(jnp.dtype(cfg.dtype))
                             @ params["frontend"]["proj"])
        pos_e = jnp.arange(x_enc.shape[1], dtype=jnp.int32)

        def enc_body(carry, lp):
            xe = carry
            xe, _ = _dense_layer_train(lp, xe, cfg, ctx, pos_e, causal=False)
            return xe, None
        x_enc, _ = jax.lax.scan(_remat(enc_body, cfg), x_enc, params["enc_layers"])
        x_enc = rmsnorm(params["final_norm"], x_enc, cfg.norm_eps)

        from repro.models.layers import kv_proj as _kvp

        def dec_body(carry, lp):
            xd = carry
            xn = rmsnorm(lp["ln1"], xd, cfg.norm_eps)
            h, (k, v) = attention(lp["attn"], xn, cfg, ctx, positions=positions,
                                  causal=True, window=0)
            xd = xd + h
            ck_l, cv_l = _kvp(lp["xattn"], x_enc, cfg, pos_e, use_rope=False)
            xc = rmsnorm(lp["ln_x"], xd, cfg.norm_eps)
            hx, _ = attention(lp["xattn"], xc, cfg, ctx,
                              kv=(ck_l, cv_l, pos_e, None),
                              positions=positions, causal=False, window=0,
                              use_rope=False)
            xd = xd + hx
            xd, _ = _ffn(lp, xd, cfg, ctx)
            return xd, (keep_last(k), keep_last(v), ck_l, cv_l)
        x, (ks, vs, cks, cvs) = jax.lax.scan(_remat(dec_body, cfg), x,
                                             params["dec_layers"])
        cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs,
                 "kpos": kpos, "pos": jnp.int32(S)}
    else:
        raise ValueError(f"prefill unsupported for {cfg.family}")

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["lm_head"], x[:, -1:, :], ctx)
    return logits[:, 0, :], cache


# =============================================================== decode
def cache_window(cfg: ModelConfig, max_len: int) -> int:
    if cfg.family == "rglru_hybrid":
        return min(cfg.local_window, max_len)
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Concrete zero cache (use jax.eval_shape(...) for the dry-run)."""
    dtype = jnp.dtype(cfg.dtype)
    B, hd, Hkv = batch, cfg.hd, cfg.n_kv_heads
    W = cache_window(cfg, max_len)
    if cfg.family in ("dense", "moe"):
        L = cfg.n_layers
        if cfg.family == "moe":
            shape = (L // cfg.moe_every, cfg.moe_every, B, W, Hkv, hd)
        else:
            shape = (L, B, W, Hkv, hd)
        return {"k": jnp.zeros(shape, dtype),
                "v": jnp.zeros(shape, dtype),
                "kpos": jnp.full((W,), -1, jnp.int32),
                "pos": jnp.int32(0)}
    if cfg.family == "rwkv6":
        L, D = cfg.n_layers, cfg.d_model
        H = D // cfg.rwkv_head_dim
        K = cfg.rwkv_head_dim
        return {"ts_t": jnp.zeros((L, B, D), dtype),
                "ts_c": jnp.zeros((L, B, D), dtype),
                "s": jnp.zeros((L, B, H, K, K), jnp.float32),
                "pos": jnp.int32(0)}
    if cfg.family == "rglru_hybrid":
        group = cfg.rec_per_attn + 1
        G, tail = divmod(cfg.n_layers, group)
        Wl = cfg.lru_width or cfg.d_model
        c = {"k": jnp.zeros((G, B, W, Hkv, hd), dtype),
             "v": jnp.zeros((G, B, W, Hkv, hd), dtype),
             "h": jnp.zeros((G, cfg.rec_per_attn, B, Wl), jnp.float32),
             "conv": jnp.zeros((G, cfg.rec_per_attn, B, 3, Wl), dtype),
             "kpos": jnp.full((W,), -1, jnp.int32),
             "pos": jnp.int32(0)}
        if tail:
            c["tail_h"] = jnp.zeros((tail, B, Wl), jnp.float32)
            c["tail_conv"] = jnp.zeros((tail, B, 3, Wl), dtype)
        return c
    if cfg.family == "encdec":
        L = cfg.n_layers
        S_enc = max(cfg.frontend_tokens, 1)
        return {"k": jnp.zeros((L, B, W, Hkv, hd), dtype),
                "v": jnp.zeros((L, B, W, Hkv, hd), dtype),
                "ck": jnp.zeros((L, B, S_enc, Hkv, hd), dtype),
                "cv": jnp.zeros((L, B, S_enc, Hkv, hd), dtype),
                "kpos": jnp.full((W,), -1, jnp.int32),
                "pos": jnp.int32(0)}
    raise ValueError(cfg.family)


def _decode_attn(p, xn, cfg, ctx, ck, cv, kpos, pos):
    """One-token attention against a ring-buffer cache slice (B,W,Hkv,hd).
    Returns (attn_out, new_ck, new_cv)."""
    from repro.models.layers import kv_proj
    W = ck.shape[1]
    slot = pos % W
    k_new, v_new = kv_proj(p["attn"], xn, cfg, jnp.full((1,), pos, jnp.int32))
    ck = jax.lax.dynamic_update_slice(ck, k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v_new, (0, slot, 0, 0))
    h, _ = attention(p["attn"], xn, cfg, ctx,
                     kv=(ck, cv, kpos, kpos >= 0),
                     positions=jnp.full((1,), pos, jnp.int32),
                     causal=True, window=cfg.sliding_window, use_rope=True)
    return h, ck, cv


def forward_decode(params: Params, cache: Params, tokens: jnp.ndarray,
                   cfg: ModelConfig, ctx: ShardCtx):
    """One decode step. tokens: (B, 1) int32. Returns (logits (B,V), cache)."""
    pos = cache["pos"]
    x = ctx.residual(embed(params["embed"], tokens))

    if cfg.family in ("dense", "encdec"):
        W = cache["k"].shape[2]
        slot = pos % W
        kpos = cache["kpos"].at[slot].set(pos)

        layer_params = params["layers" if cfg.family != "encdec" else "dec_layers"]

        def body(x, xs):
            lp, ck, cv = xs[0], xs[1], xs[2]
            xn = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, ck, cv = _decode_attn(lp, xn, cfg, ctx, ck, cv, kpos, pos)
            x = x + h
            if cfg.family == "encdec":
                cck, ccv = xs[3], xs[4]
                xc = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
                S_enc = cck.shape[1]
                hx, _ = attention(lp["xattn"], xc, cfg, ctx,
                                  kv=(cck, ccv, jnp.arange(S_enc, dtype=jnp.int32), None),
                                  positions=jnp.full((1,), pos, jnp.int32),
                                  causal=False, window=0, use_rope=False)
                x = x + hx
            x, _ = _ffn(lp, x, cfg, ctx)
            return x, (ck, cv)

        if cfg.family == "encdec":
            xs = (layer_params, cache["k"], cache["v"], cache["ck"], cache["cv"])
        else:
            xs = (layer_params, cache["k"], cache["v"])
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        cache = dict(cache, k=nk, v=nv, kpos=kpos, pos=pos + 1)

    elif cfg.family == "moe":
        W = cache["k"].shape[3]
        slot = pos % W
        kpos = cache["kpos"].at[slot].set(pos)

        def body(x, xs):
            gp, ck, cv = xs                       # ck: (moe_every, B, W, Hkv, hd)
            nk, nv = [], []
            for j in range(cfg.moe_every):        # static unroll
                is_moe = j == cfg.moe_every - 1
                lp = (gp["moe"] if is_moe
                      else jax.tree.map(lambda a: a[j], gp["dense"]))
                xn = rmsnorm(lp["ln1"], x, cfg.norm_eps)
                h, ckj, cvj = _decode_attn(lp, xn, cfg, ctx, ck[j], cv[j],
                                           kpos, pos)
                x = x + h
                x, _ = _ffn(lp, x, cfg, ctx)
                nk.append(ckj), nv.append(cvj)
            return x, (jnp.stack(nk), jnp.stack(nv))

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=nk, v=nv, kpos=kpos, pos=pos + 1)

    elif cfg.family == "rwkv6":
        def body(x, xs):
            lp, ts_t, ts_c, s = xs
            x, st = rwkv_block(lp, x, cfg, ctx,
                               state={"ts_t": ts_t, "ts_c": ts_c, "s": s})
            return x, (st["ts_t"], st["ts_c"], st["s"])
        x, (t1, t2, s) = jax.lax.scan(body, x, (params["layers"], cache["ts_t"],
                                                cache["ts_c"], cache["s"]))
        cache = dict(cache, ts_t=t1, ts_c=t2, s=s, pos=pos + 1)

    elif cfg.family == "rglru_hybrid":
        W = cache["k"].shape[2]
        slot = pos % W
        kpos = cache["kpos"].at[slot].set(pos)

        def rec_step(x, xs):
            lp, h, conv = xs
            x, st = rglru_block(lp["rec"], x, cfg, ctx,
                                state={"h": h, "conv": conv})
            x, _ = _ffn(lp, x, cfg, ctx)
            return x, (st["h"], st["conv"])

        def group_body(x, xs):
            gp, ck, cv, h, conv = xs
            x, (nh, nconv) = jax.lax.scan(rec_step, x, (gp["recs"], h, conv))
            xn = rmsnorm(gp["attn"]["ln1"], x, cfg.norm_eps)
            hh, ck, cv = _decode_attn(gp["attn"], xn,
                                      cfg.replace(sliding_window=cfg.local_window),
                                      ctx, ck, cv, kpos, pos)
            x = x + hh
            x, _ = _ffn(gp["attn"], x, cfg, ctx)
            return x, (ck, cv, nh, nconv)

        x, (nk, nv, nh, nconv) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["k"], cache["v"], cache["h"], cache["conv"]))
        cache = dict(cache, k=nk, v=nv, h=nh, conv=nconv, kpos=kpos, pos=pos + 1)
        if "tail" in params:
            x, (th, tc) = jax.lax.scan(
                rec_step, x,
                (params["tail"], cache["tail_h"], cache["tail_conv"]))
            cache = dict(cache, tail_h=th, tail_conv=tc)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["lm_head"], x, ctx)
    return logits[:, 0, :], cache
