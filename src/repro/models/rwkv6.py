"""RWKV6 "Finch" (arXiv:2404.05892): attention-free time mix with
data-dependent per-channel decay + squared-ReLU channel mix.

TPU adaptation (DESIGN §5): the GPU reference uses a custom CUDA recurrence;
here the WKV6 recurrence is evaluated CHUNKWISE so the bulk of the work is
batched einsums (MXU) instead of a length-T sequential loop:

  per chunk of c tokens (c = cfg.wkv_chunk, default 16):
    Lin  = cumsum(log w)                       (B,H,c,K)   f32, log-space
    A[t,tau] = exp(Lprev[t] - Lin[tau])        decay tau+1..t-1, masked tau<t
    o_intra  = ((r*A*k) summed over K) @ v     two einsums
    o_inter  = (r * exp(Lprev)) @ S            carried state (B,H,K,V)
    S'       = exp(Lin[-1]) * S + (k * exp(Lin[-1]-Lin)) @ v

Log-space keeps everything in (0,1] — no under/overflow for any decay
(the GLA-style q~/k~ factorization overflows for strong decays; the small-c
direct form does not, at the cost of a (c,c,K) intra tensor, which at c=16
is ~67MB transient for a 7B config — a deliberate trade recorded in
EXPERIMENTS §Perf).

``rwkv_recurrent`` is the step-by-step oracle used for decode (O(1) state —
this is why rwkv6 runs the long_500k shape) and for tests.

Simplification vs the full Finch block (noted in DESIGN): token-shift uses
static learned lerp (mu) rather than the data-dependent ddlerp LoRA; the
decay LoRA (the paper's headline data-dependence) IS implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, ShardCtx, dense_init, rmsnorm, rmsnorm_init


def rwkv_layer_init(key, cfg: ModelConfig, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    H = D // hd
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        "ln1": rmsnorm_init(D),
        "ln2": rmsnorm_init(D),
        "tmix": {
            "mu": jnp.full((5, D), 0.5, jnp.float32),   # r,k,v,g,w shifts
            "wr": dense_init(ks[0], D, D, dtype),
            "wk": dense_init(ks[1], D, D, dtype),
            "wv": dense_init(ks[2], D, D, dtype),
            "wg": dense_init(ks[3], D, D, dtype),
            "wo": dense_init(ks[4], D, D, dtype),
            "w0": jnp.full((H, hd), -1.0, jnp.float32),  # base log-log decay
            "wa": dense_init(ks[5], D, lora, jnp.float32, 0.1),
            "wb": dense_init(ks[6], lora, D, jnp.float32, 0.1),
            "u": jnp.zeros((H, hd), jnp.float32),        # bonus
            "ln_out": rmsnorm_init(D),
        },
        "cmix": {
            "mu": jnp.full((2, D), 0.5, jnp.float32),    # k,r shifts
            "wk": dense_init(ks[7], D, F, dtype),
            "wv": dense_init(ks[8], F, D, dtype),
            "wr": dense_init(ks[9], D, D, dtype),
        },
    }


def _shift(x, prev):
    """Token shift: x_{t-1} (prev carries the last token of the previous
    call; zeros for the first)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def wkv_chunked(r, k, v, lw, u, s0, chunk: int, inner_remat: bool = False,
                compute_dtype=jnp.float32):
    """r,k,v,lw: (B, T, H, K); u: (H, K); s0: (B, H, K, V). Returns (o, sT)."""
    B, T, H, K = r.shape
    c = min(chunk, T)
    if T % c:  # neutral padding: k=v=r=0 contribute nothing, lw=0 => decay 1
        pad = c - T % c
        r, k, v, lw = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                       for a in (r, k, v, lw))
        o, sT = wkv_chunked(r, k, v, lw, u, s0, chunk, inner_remat,
                            compute_dtype)
        return o[:, :T], sT
    nc = T // c
    cdt = jnp.dtype(compute_dtype)

    def to_chunks(a):
        return a.reshape(B, nc, c, H, K).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,K)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)                  # tau < t

    def body(s, inp):
        rr, kk, vv, ll = (a.astype(jnp.float32) for a in inp)      # (B,H,c,K)
        lin = jnp.cumsum(ll, axis=2)                               # f32 always
        lprev = lin - ll
        # intra-chunk: A[t,tau,i] = exp(lprev[t,i] - lin[tau,i]), tau < t
        a = jnp.exp(lprev[:, :, :, None, :] - lin[:, :, None, :, :])
        a = jnp.where(mask[None, None, :, :, None], a, 0.0).astype(cdt)
        # the big-operand einsums run in compute_dtype (f32 accumulate)
        w_ts = jnp.einsum("bhti,bhtsi,bhsi->bhts", rr.astype(cdt), a,
                          kk.astype(cdt), preferred_element_type=jnp.float32)
        o = jnp.einsum("bhts,bhsv->bhtv", w_ts.astype(cdt), vv.astype(cdt),
                       preferred_element_type=jnp.float32)
        # bonus (current token)
        o += (rr * u[None, :, None, :] * kk).sum(-1, keepdims=True) * vv
        # inter-chunk from carried state
        o += jnp.einsum("bhti,bhiv->bhtv", rr * jnp.exp(lprev), s)
        # state update (f32: carried accuracy)
        dec_all = jnp.exp(lin[:, :, -1:, :])                       # (B,H,1,K)
        s = s * dec_all.squeeze(2)[..., None] + jnp.einsum(
            "bhsi,bhsv->bhiv", kk * jnp.exp(lin[:, :, -1:, :] - lin), vv)
        return s, o

    if inner_remat:
        # recompute the (c, c, K) intra-chunk tensors in backward instead of
        # saving them for all nc chunks (§Perf rwkv memory lever)
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    sT, oc = jax.lax.scan(body, s0.astype(jnp.float32), (rc, kc, vc, lwc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(B, T, H, K)
    return o.astype(r.dtype), sT


def wkv_recurrent(r, k, v, lw, u, s0):
    """Step-by-step oracle / decode path. Same shapes as wkv_chunked."""
    def step(s, inp):
        rr, kk, vv, ll = (a.astype(jnp.float32) for a in inp)      # (B,H,K)
        o = jnp.einsum("bhi,bhiv->bhv", rr, s + u[None, :, :, None] * kk[..., None] * vv[:, :, None, :])
        s = s * jnp.exp(ll)[..., None] + kk[..., None] * vv[:, :, None, :]
        return s, o

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, lw))     # (T,B,H,K)
    sT, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return o.transpose(1, 0, 2, 3).astype(r.dtype), sT


def rwkv_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, ctx: ShardCtx,
               state: Params | None = None):
    """One RWKV6 block. state = {"ts_t","ts_c": (B,D), "s": (B,H,K,V)} for
    decode; None for training (zero-init, discarded)."""
    B, T, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    if state is None:
        state = {
            "ts_t": jnp.zeros((B, D), x.dtype),
            "ts_c": jnp.zeros((B, D), x.dtype),
            "s": jnp.zeros((B, H, hd, hd), jnp.float32),
        }

    # ---- time mix ----
    tm = p["tmix"]
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    xs = _shift(xn, state["ts_t"])
    mu = tm["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (xn + mu[i] * (xs - xn) for i in range(5))
    r = (xr @ tm["wr"]).reshape(B, T, H, hd)
    kk = (xk @ tm["wk"]).reshape(B, T, H, hd)
    vv = (xv @ tm["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ tm["wg"])
    # data-dependent decay (the Finch signature): log w = -exp(w0 + lora(x))
    lora = jnp.tanh(xw.astype(jnp.float32) @ tm["wa"]) @ tm["wb"]
    lw = -jnp.exp(tm["w0"].reshape(1, 1, D) + lora).reshape(B, T, H, hd)
    if ctx.mesh is not None:
        r, kk, vv = (ctx.hint(a, ctx.batch, None, ctx.model, None) for a in (r, kk, vv))
        lw = ctx.hint(lw, ctx.batch, None, ctx.model, None)
    if T == 1:
        o, sT = wkv_recurrent(r, kk, vv, lw, tm["u"], state["s"])
    elif cfg.wkv_use_pallas:
        # Pallas chunk kernel (VMEM-resident intra tensors, custom VJP);
        # flatten (B, H) -> BH rows, per-row u
        from repro.kernels.wkv.ops import wkv_forward
        def fl(a):
            return a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
        u_bh = jnp.tile(tm["u"].reshape(H, hd), (B, 1))
        o_f, s_f = wkv_forward(fl(r), fl(kk), fl(vv), fl(lw), u_bh,
                               state["s"].reshape(B * H, hd, hd),
                               cfg.wkv_chunk)
        o = o_f.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
        sT = s_f.reshape(B, H, hd, hd)
    else:
        o, sT = wkv_chunked(r, kk, vv, lw, tm["u"], state["s"], cfg.wkv_chunk,
                            cfg.wkv_inner_remat,
                            jnp.dtype(cfg.wkv_compute_dtype))
    o = rmsnorm(tm["ln_out"], o.reshape(B, T, D), cfg.norm_eps) * g
    x = x + ctx.residual(o @ tm["wo"])

    # ---- channel mix ----
    cm = p["cmix"]
    xn2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    xs2 = _shift(xn2, state["ts_c"])
    cmu = cm["mu"].astype(x.dtype)
    xk2 = xn2 + cmu[0] * (xs2 - xn2)
    xr2 = xn2 + cmu[1] * (xs2 - xn2)
    kk2 = jnp.square(jax.nn.relu(xk2 @ cm["wk"]))
    if ctx.mesh is not None:
        kk2 = ctx.hint(kk2, ctx.batch, None, ctx.model)
    ffn = jax.nn.sigmoid(xr2 @ cm["wr"]) * (kk2 @ cm["wv"])
    x = x + ctx.residual(ffn)

    new_state = {"ts_t": xn[:, -1, :], "ts_c": xn2[:, -1, :], "s": sT}
    return x, new_state
