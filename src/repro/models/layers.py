"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full / sliding
window / KV-cache decode), SwiGLU MLP, embeddings.

Everything is functional: ``init_*`` builds parameter dicts, ``*_apply``
consumes them.  Parameters are stacked per layer by the model modules and
scanned (one lowered layer body regardless of depth — essential for the
40-cell dry-run compile budget).

Sharding is threaded through ``ShardCtx``: a thin helper that applies
``with_sharding_constraint`` only when a mesh is active, so the same code
runs in single-device smoke tests and in the 512-chip dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Params = dict


# ---------------------------------------------------------------- sharding
@dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding hints. ``batch`` axes shard the batch dim,
    ``model`` shards heads / ffn / vocab / (optionally) sequence."""

    mesh: Mesh | None = None
    batch: tuple = ("data",)
    model: str = "model"
    seq_shard: bool = True  # Megatron-style sequence parallelism on residuals

    def hint(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def residual(self, x):
        """(B, S, D) residual stream: batch over dp, optionally seq over tp."""
        if self.mesh is None:
            return x
        seq = self.model if self.seq_shard else None
        return self.hint(x, self.batch, seq, None)

    def heads(self, x):
        """(B, S, H, hd): heads over tp."""
        return self.hint(x, self.batch, None, self.model, None)


# ---------------------------------------------------------------- basics
def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32 absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale * (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------- attention
def attn_init(key, cfg: ModelConfig, dtype) -> Params:
    hd, Hq, Hkv, D = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, Hq * hd, dtype),
        "wk": dense_init(ks[1], D, Hkv * hd, dtype),
        "wv": dense_init(ks[2], D, Hkv * hd, dtype),
        "wo": dense_init(ks[3], Hq * hd, D, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def kv_proj(p: Params, x: jnp.ndarray, cfg: ModelConfig,
            positions: jnp.ndarray, use_rope: bool = True):
    """Project x to (k, v) heads, applying RoPE at absolute ``positions`` —
    the cache stores post-RoPE keys so decode never re-rotates history."""
    B, S, _ = x.shape
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    k = (x @ p["wk"] + p.get("bk", 0.0)).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"] + p.get("bv", 0.0)).reshape(B, S, Hkv, hd)
    if use_rope:
        k = rope(k, positions[None], cfg.rope_theta)
    return k, v


def _scores_mask(qpos, kpos, *, causal: bool, window: int):
    """(Sq, Sk) boolean mask: True = attend."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= kpos[None, :] > (qpos[:, None] - window)
    return ok


def _sdpa(q, k, v, qpos, kpos, kv_valid, *, causal, window):
    """q: (B, Sq, H, hd); k/v: (B, Sk, H, hd) — kv already head-expanded.
    f32 softmax."""
    hd = q.shape[-1]
    scale = hd ** -0.5
    s = jnp.einsum("bqhd,bthd->bhqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = _scores_mask(qpos, kpos, causal=causal, window=window)
    mask = mask & kv_valid[None, :] if kv_valid is not None else mask
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", w.astype(v.dtype), v)
    return o


def attention(
    p: Params,
    x: jnp.ndarray,                  # (B, Sq, D)
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    kv: tuple | None = None,         # (k, v, kpos, kv_valid) for decode/cross
    positions: jnp.ndarray | None = None,  # (Sq,) absolute positions
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
):
    B, Sq, D = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = Hq // Hkv
    if positions is None:
        positions = jnp.arange(Sq, dtype=jnp.int32)

    q = x @ p["wq"] + (p.get("bq", 0.0))
    q = q.reshape(B, Sq, Hq, hd)
    if kv is None:
        k, v = kv_proj(p, x, cfg, positions, use_rope)
        kpos, kv_valid = positions, None
    else:
        k, v, kpos, kv_valid = kv
    if use_rope:
        q = rope(q, positions[None], cfg.rope_theta)

    # GQA -> flat heads with kv replication (Megatron-style): expand kv to
    # Hq heads so the head axis shards cleanly over `model` with no padded
    # kv-head shards (kv<tp would pad 8->16 and all-gather f32 scores — see
    # EXPERIMENTS §Perf iteration 1).
    ke, ve = k, v
    if G > 1:
        ke = jnp.repeat(k, G, axis=2)
        ve = jnp.repeat(v, G, axis=2)
    if ctx.mesh is not None and Sq > 1:
        # train/prefill: shard the flat head axis. Decode (Sq==1) instead
        # keeps the cache W-sharded and lets the score/out einsums reduce
        # over the sharded length (flash-decode-style), so no hint here.
        q = ctx.hint(q, ctx.batch, None, ctx.model, None)
        ke = ctx.hint(ke, ctx.batch, None, ctx.model, None)
        ve = ctx.hint(ve, ctx.batch, None, ctx.model, None)

    qc = cfg.attn_q_chunk
    if Sq > qc and Sq % qc == 0:
        nq = Sq // qc

        def one_chunk(i):
            sl = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
            pp = jax.lax.dynamic_slice_in_dim(positions, i * qc, qc, axis=0)
            return _sdpa(sl, ke, ve, pp, kpos, kv_valid, causal=causal, window=window)

        if cfg.attn_chunk_remat:
            # flash-style backward: recompute each chunk's f32 scores instead
            # of stacking (nq, B, H, qc, Sk) buffers across the whole map
            # (the EXPERIMENTS §Perf memory lever for train cells)
            one_chunk = jax.checkpoint(
                one_chunk, policy=jax.checkpoint_policies.nothing_saveable)
        o = jax.lax.map(one_chunk, jnp.arange(nq))      # (nq, B, qc, Hq, hd)
        o = jnp.moveaxis(o, 0, 1).reshape(B, Sq, Hq, hd)
    else:
        o = _sdpa(q, ke, ve, positions, kpos, kv_valid, causal=causal, window=window)

    o = o.reshape(B, Sq, Hq * hd)
    out = o @ p["wo"]
    return ctx.residual(out), (k, v)


# ---------------------------------------------------------------- MLP
def mlp_init(key, d: int, f: int, dtype, mlp_type: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d, f, dtype),
        "wo": dense_init(ks[2], f, d, dtype),
    }
    if mlp_type == "swiglu":
        p["wg"] = dense_init(ks[1], d, f, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, ctx: ShardCtx) -> jnp.ndarray:
    if "wg" in p:   # SwiGLU
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:           # GELU (gpt-bigcode / granite)
        h = jax.nn.gelu(x @ p["wi"])
    h = ctx.hint(h, ctx.batch, None, ctx.model) if ctx.mesh else h
    return ctx.residual(h @ p["wo"])


# ---------------------------------------------------------------- embeddings
def embed_init(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def unembed(w: jnp.ndarray, x: jnp.ndarray, ctx: ShardCtx) -> jnp.ndarray:
    """Logits in f32 from lm_head w (D, V), sequence-sharded (DESIGN §6: the
    (B,S,V) tensor is the single largest activation for 150k vocabs; keeping
    it seq-sharded over the model axis makes the CE fully parallel)."""
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if ctx.mesh is not None:
        seq = ctx.model if ctx.seq_shard else None
        logits = ctx.hint(logits, ctx.batch, seq, None)
    return logits
