"""``python -m repro`` — execute a pipeline described by a config file.

A run artifact is JSON or TOML with two sections::

    {
      "pipeline": { ... PipelineConfig.to_dict() ... },
      "data":     {"kind": "gauss", "n_centers": 5, "per_center": 400,
                   "d": 5, "t": 25, "sigma": 0.1, "seed": 0}
    }

(A file that is itself a bare ``PipelineConfig`` dict — has a ``problem``
key — also works; data then defaults to a small gauss set matched to the
problem.)  ``data.kind`` names a ``repro.data.synthetic`` generator
(``gauss`` / ``drifting_gauss`` / ``kdd_like`` / ``susy_like``); the other
keys are its keyword arguments.

Subcommands:

* ``run``         — fit the pipeline on the data, report model / comm /
                    outlier quality, optionally ``--save`` the session;
* ``serve``       — stream the data in batches through a stream/sharded
                    session (cadence refreshes), score sample queries,
                    report latency, optionally ``--checkpoint``; with
                    ``--clients N`` it then saturates the async serving
                    scheduler (``repro.serve``) with N open-loop client
                    threads and reports goodput / shed rate / p99; configs
                    with a ``store`` section additionally report tiered
                    spill / page-in and skipped-refresh activity;
* ``bench-score`` — fit, then measure the query path (p50/p99 latency and
                    throughput over ``--repeat`` rounds of ``--queries``);
* ``stats``       — fit + score like ``run``, then emit the full metrics
                    snapshot (``repro.obs``) as JSON or Prometheus text;
* ``trace``       — fit + score through the *async serving* path, then
                    export the flight recorder as Chrome trace-event JSON
                    (Perfetto / ``chrome://tracing``) or JSON-lines.

``serve --trace-out FILE`` dumps the same Chrome trace after streaming.

``serve --metrics-interval N`` additionally emits the live snapshot as one
JSON line every ~N seconds while streaming (``--metrics-out`` to redirect
the lines to a file; default stdout).

Every benchmark and example is expressible as such an artifact — the
configuration travels with the result instead of living in flag soup.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.api.config import PipelineConfig
from repro.api.session import Session

_DATA_KINDS = ("gauss", "drifting_gauss", "kdd_like", "susy_like")


def load_config_file(path) -> tuple[PipelineConfig, dict]:
    """Read a JSON/TOML run artifact -> (PipelineConfig, data spec)."""
    path = Path(path)
    text = path.read_text()
    if path.suffix.lower() == ".toml":
        try:
            import tomllib
        except ImportError:  # py3.10: tomllib landed in 3.11
            raise SystemExit(
                f"{path}: TOML configs need Python >= 3.11 (tomllib); "
                f"convert to JSON or upgrade")
        raw = tomllib.loads(text)
    else:
        raw = json.loads(text)
    if not isinstance(raw, dict):
        raise SystemExit(f"{path}: expected a config object at top level")
    if "pipeline" in raw:
        pipeline = PipelineConfig.from_dict(raw["pipeline"])
        data = raw.get("data", {})
        unknown = {k for k in raw if not k.startswith("$")} - {"pipeline",
                                                               "data"}
        if unknown:
            raise SystemExit(f"{path}: unknown top-level keys "
                             f"{sorted(unknown)}")
    elif "problem" in raw:
        pipeline = PipelineConfig.from_dict(raw)
        data = {}
    else:
        raise SystemExit(f"{path}: config needs a 'pipeline' (or bare "
                         f"'problem') section")
    return pipeline, data


def make_data(pipeline: PipelineConfig, spec: dict):
    """data spec -> (x (n,d) f32, outlier_ids or None)."""
    from repro.data import synthetic

    spec = dict(spec)
    kind = spec.pop("kind", "gauss")
    if kind not in _DATA_KINDS:
        raise SystemExit(f"data.kind must be one of {_DATA_KINDS}, "
                         f"got {kind!r}")
    if kind == "gauss" and not spec:
        # bare-pipeline default: a small set matched to the problem
        p = pipeline.problem
        spec = dict(n_centers=p.k, per_center=400, d=p.dim, t=p.t,
                    seed=pipeline.seed)
    out = getattr(synthetic, kind)(**spec)
    if kind == "drifting_gauss":
        x, _phases, _centers = out
        out_ids = None
    else:
        x, out_ids = out
    if x.shape[1] != pipeline.problem.dim:
        raise SystemExit(
            f"data is {x.shape[1]}-dimensional but problem.dim="
            f"{pipeline.problem.dim}; make the config sections agree")
    return np.asarray(x, np.float32), out_ids


def _sample_queries(x, out_ids, n_queries: int, seed: int):
    """Up to ``n_queries`` rows: planted outliers first, inliers after."""
    rng = np.random.default_rng(seed)
    picks = []
    if out_ids is not None and len(out_ids):
        picks.append(out_ids[: n_queries // 2])
    inliers = (np.setdiff1d(np.arange(x.shape[0]), out_ids)
               if out_ids is not None else np.arange(x.shape[0]))
    want = n_queries - sum(len(p) for p in picks)
    picks.append(rng.choice(inliers, size=min(want, len(inliers)),
                            replace=False))
    ids = np.concatenate(picks)
    flags = (np.isin(ids, out_ids) if out_ids is not None
             else np.zeros(len(ids), bool))
    return x[ids], flags


def _report_scores(results, truth) -> None:
    flagged = np.array([r.is_outlier for r in results])
    print(f"  scored {len(results)} queries: {int(flagged.sum())} flagged "
          f"as outliers (score > 1)")
    if truth is not None and truth.any():
        tp = int((flagged & truth).sum())
        print(f"  planted outliers among queries: {int(truth.sum())}, "
              f"caught: {tp}, false alarms: {int((flagged & ~truth).sum())}")


def cmd_run(args) -> None:
    pipeline, data_spec = load_config_file(args.config)
    x, out_ids = make_data(pipeline, data_spec)
    topo = pipeline.topology
    print(f"pipeline: {topo.kind} topology, k={pipeline.problem.k} "
          f"t={pipeline.problem.t} metric={pipeline.problem.metric} "
          f"summarizer={pipeline.summarizer.name!r} "
          f"kernels={pipeline.kernels.backend!r}")
    print(f"data: {x.shape[0]} points in R^{x.shape[1]}"
          + (f", {len(out_ids)} planted outliers" if out_ids is not None
             else ""))
    t0 = time.perf_counter()
    session = Session(pipeline)
    model = session.fit(x)
    fit_s = time.perf_counter() - t0
    print(f"fit: model v{int(model.version)} in {fit_s:.2f}s "
          f"(cost {float(model.cost):.4g}, threshold "
          f"{float(model.threshold):.4g})")
    res = session.result
    if res is not None:
        print(f"  coordinator saw {res['comm_records']:.0f} summary records "
              f"({100 * res['comm_records'] / x.shape[0]:.2f}% of the data)")
        if out_ids is not None:
            from repro.core.metrics import outlier_scores
            sc = outlier_scores(out_ids, res["summary_ids"],
                                res["outlier_ids"])
            print(f"  outliers: preRec={sc.pre_recall:.3f} "
                  f"prec={sc.precision:.3f} recall={sc.recall:.3f}")
    q, truth = _sample_queries(x, out_ids, args.queries, pipeline.seed)
    _report_scores(session.score(q), truth)
    if args.save:
        step = session.save(args.save)
        print(f"saved session (config embedded) to {args.save} @ step {step}")
    print("ok")


class _MetricsEmitter:
    """Periodic JSON-lines snapshots: one ``json.dumps(session.stats())``
    line per ~interval seconds, checked at batch boundaries (the serve
    loop is synchronous).  ``interval=None`` disables; path "-" = stdout."""

    def __init__(self, interval, path):
        self.interval = interval
        self._fh = None
        self._last = time.perf_counter()
        if interval is not None and path not in (None, "-"):
            self._fh = open(path, "a")

    def emit(self, session, *, force: bool = False) -> None:
        if self.interval is None:
            return
        now = time.perf_counter()
        if not force and now - self._last < self.interval:
            return
        self._last = now
        line = json.dumps({"ts": time.time(), **session.stats()},
                          sort_keys=True)
        print(line, file=self._fh or sys.stdout, flush=True)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()


def _report_store(session) -> None:
    """One line of tiered-store + incremental-refresh activity, printed
    only when the config has a store section (quiet otherwise)."""
    if session.config.store is None:
        return
    counters = session.stats().get("counters", {})
    skipped = sum(v for k, v in counters.items()
                  if k.startswith("refresh.skipped{"))
    warm = sum(v for k, v in counters.items()
               if k.startswith("refresh.warm_starts{"))
    st = session.store_stats()
    if st is not None:
        print(f"  store: {st['spills']} spills "
              f"({st['spill_bytes'] / 2**20:.2f} MiB out), "
              f"{st['page_ins']} page-ins "
              f"({st['page_in_bytes'] / 2**20:.2f} MiB back)")
    print(f"  refresh: {int(skipped)} skipped (root unchanged), "
          f"{int(warm)} warm-started")


def cmd_serve(args) -> None:
    pipeline, data_spec = load_config_file(args.config)
    if pipeline.topology.kind == "oneshot":
        raise SystemExit("serve needs a stream or sharded topology; "
                         "use `run` for oneshot configs")
    x, out_ids = make_data(pipeline, data_spec)
    session = Session(pipeline)
    emitter = _MetricsEmitter(args.metrics_interval, args.metrics_out)
    n = x.shape[0]
    print(f"serving {pipeline.topology.kind} topology: streaming {n} points "
          f"in batches of {args.batch} "
          f"(refresh every {pipeline.topology.refresh_every})")
    t0 = time.perf_counter()
    for i in range(0, n, args.batch):
        session.ingest(x[i:i + args.batch])
        emitter.emit(session)
    if session.model is None or not session.model.version:
        session.refresh()
    ingest_s = time.perf_counter() - t0
    print(f"  ingested at {n / ingest_s:.0f} pts/s; model "
          f"v{int(session.model.version)}")
    q, truth = _sample_queries(x, out_ids, args.queries, pipeline.seed)
    _report_scores(session.score(q), truth)
    stats = session.latency_stats()
    print(f"  query latency: p50 {stats['p50_ms']:.2f} ms, "
          f"p99 {stats['p99_ms']:.2f} ms over {stats['count']} requests")
    _report_store(session)
    if args.clients:
        _serve_load_phase(session, x, args)
        emitter.emit(session)
    if session.last_fit is not None:
        print(f"  last refresh: v{session.last_fit.version} fit in "
              f"{session.last_fit.fit_s * 1e3:.1f} ms on "
              f"{session.last_fit.records_folded} records; model age "
              f"{session.engine.seconds_since_install():.2f}s")
    if args.checkpoint:
        step = session.save(args.checkpoint)
        print(f"checkpointed to {args.checkpoint} @ step {step}; "
              f"Session.load() restores topology + policies from it alone")
    if args.trace_out:
        path = session.dump_trace(args.trace_out)
        print(f"wrote Chrome trace to {path} "
              f"(load in Perfetto or chrome://tracing)")
    # final snapshot after everything (incl. checkpoint metrics) happened
    emitter.emit(session, force=True)
    emitter.close()
    print("ok")


def _serve_load_phase(session, x, args) -> None:
    """``serve --clients N``: saturate the async scheduler with an
    open-loop multi-client load phase and report goodput / shed / p99."""
    from repro.serve import estimate_capacity, run_load

    sched = session.serve()
    spec = sched.spec
    rng = np.random.default_rng(session.config.seed + 7)
    queries = x[rng.choice(x.shape[0], size=min(4096, x.shape[0]),
                           replace=False)]
    offered = args.offered_rps
    if offered is None:
        cap = estimate_capacity(sched, queries, duration_s=0.3)
        offered = 1.5 * cap   # past saturation: show admission control work
        print(f"  load: capacity ~{cap:.0f} rows/s (closed-loop); "
              f"offering 1.5x = {offered:.0f} rows/s")
    print(f"  load: {args.clients} clients, {args.load_seconds}s, "
          f"queue_bound={spec.queue_bound} shed_policy={spec.shed_policy} "
          f"batch_window={spec.batch_window_ms}ms")
    rep = run_load(sched, queries, offered_rps=offered,
                   clients=args.clients, duration_s=args.load_seconds,
                   seed=session.config.seed)
    print(f"  load: offered {rep['offered_rps']:.0f} rows/s -> goodput "
          f"{rep['goodput_rps']:.0f} rows/s, shed rate "
          f"{rep['shed_rate']:.1%} ({rep['shed']}/{rep['submitted']})")
    if rep["p99_ms"] is not None:
        print(f"  load: completed-request latency p50 {rep['p50_ms']:.2f} ms"
              f", p99 {rep['p99_ms']:.2f} ms")
    session.close()


def cmd_bench_score(args) -> None:
    pipeline, data_spec = load_config_file(args.config)
    x, _ = make_data(pipeline, data_spec)
    session = Session(pipeline)
    session.fit(x)
    rng = np.random.default_rng(pipeline.seed)
    lat = []
    scored = 0
    t0 = time.perf_counter()
    for _ in range(args.repeat):
        q = x[rng.choice(x.shape[0], size=args.queries, replace=True)]
        t1 = time.perf_counter()
        results = session.score(q)
        lat.append(time.perf_counter() - t1)
        scored += len(results)
    wall = time.perf_counter() - t0
    per_batch = np.asarray(lat)
    print(f"bench-score [{pipeline.topology.kind}]: {scored} queries in "
          f"{wall:.2f}s = {scored / wall:.0f} q/s")
    print(f"  batch({args.queries}) p50 {np.percentile(per_batch, 50) * 1e3:.2f} ms, "
          f"p99 {np.percentile(per_batch, 99) * 1e3:.2f} ms")
    stats = session.latency_stats()
    print(f"  per-request p50 {stats['p50_ms']:.2f} ms, "
          f"p99 {stats['p99_ms']:.2f} ms")
    print("ok")


def cmd_stats(args) -> None:
    """Exercise the pipeline end to end, then emit the telemetry snapshot
    — the quickest way to see every metric the layers report."""
    from repro import obs

    pipeline, data_spec = load_config_file(args.config)
    x, out_ids = make_data(pipeline, data_spec)
    session = Session(pipeline)
    session.fit(x)
    q, _ = _sample_queries(x, out_ids, args.queries, pipeline.seed)
    session.score(q)
    snap = session.stats()
    if args.format == "prom":
        out = obs.render_prometheus(snap)
    else:
        out = json.dumps(snap, indent=2, sort_keys=True) + "\n"
    if args.out in (None, "-"):
        sys.stdout.write(out)
    else:
        Path(args.out).write_text(out)
        print(f"wrote {args.format} snapshot to {args.out}")


def cmd_trace(args) -> None:
    """Exercise the pipeline end to end *through the async serving
    scheduler*, then export the flight recorder — the quickest way to a
    Perfetto-loadable timeline of ingest -> refresh -> stitched serve
    requests (admission / queue wait / tick / fused score / drain)."""
    from repro import obs
    from repro.serve import ShedReject

    pipeline, data_spec = load_config_file(args.config)
    x, out_ids = make_data(pipeline, data_spec)
    session = Session(pipeline)
    if args.sample_rate is not None:
        # CLI override wins over the artifact's tracing section
        obs.configure_tracing(sample_rate=args.sample_rate)
    session.fit(x)
    q, truth = _sample_queries(x, out_ids, args.queries, pipeline.seed)
    results = list(session.score_stream(q, timeout=120.0))
    session.close()
    scored = [r for r in results if not isinstance(r, ShedReject)]
    _report_scores(scored, truth if len(scored) == len(results) else None)
    stats = obs.get_default_recorder().snapshot_section()
    print(f"  flight recorder: {stats['recorded']} spans across "
          f"{stats['traces']} traces (sample_rate={stats['sample_rate']}, "
          f"dropped={stats['dropped']})")
    path = session.dump_trace(args.out, fmt=args.format)
    print(f"wrote {args.format} trace to {path}"
          + (" (load in Perfetto or chrome://tracing)"
             if args.format == "chrome" else ""))
    print("ok")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Execute a declarative clustering pipeline config.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="fit a config on its data and report")
    p_run.add_argument("--config", required=True, help="JSON/TOML artifact")
    p_run.add_argument("--queries", type=int, default=64,
                       help="sample queries to score after the fit")
    p_run.add_argument("--save", default=None,
                       help="directory to checkpoint the fitted session")
    p_run.set_defaults(fn=cmd_run)

    p_srv = sub.add_parser("serve",
                           help="stream the data through a stream/sharded "
                                "session and report latency")
    p_srv.add_argument("--config", required=True)
    p_srv.add_argument("--batch", type=int, default=2048,
                       help="ingest batch size (cadence refreshes apply)")
    p_srv.add_argument("--queries", type=int, default=64)
    p_srv.add_argument("--checkpoint", default=None,
                       help="directory to checkpoint the serving session")
    p_srv.add_argument("--metrics-interval", type=float, default=None,
                       metavar="SECONDS",
                       help="emit the live metrics snapshot as one JSON "
                            "line every ~N seconds while streaming")
    p_srv.add_argument("--metrics-out", default="-",
                       help="destination for --metrics-interval lines "
                            "(file path, or '-' for stdout)")
    p_srv.add_argument("--clients", type=int, default=0,
                       help="after streaming, drive the async serving "
                            "scheduler with N open-loop client threads and "
                            "report goodput / shed rate / p99 (0 = skip)")
    p_srv.add_argument("--load-seconds", type=float, default=2.0,
                       help="duration of the --clients load phase")
    p_srv.add_argument("--offered-rps", type=float, default=None,
                       help="offered load (rows/s) for the --clients phase; "
                            "default: 1.5x a measured capacity estimate")
    p_srv.add_argument("--trace-out", default=None, metavar="FILE",
                       help="after streaming, dump the flight recorder as "
                            "Chrome trace-event JSON to FILE")
    p_srv.set_defaults(fn=cmd_serve)

    p_bs = sub.add_parser("bench-score", help="measure the query path")
    p_bs.add_argument("--config", required=True)
    p_bs.add_argument("--queries", type=int, default=256,
                      help="queries per round")
    p_bs.add_argument("--repeat", type=int, default=20, help="rounds")
    p_bs.set_defaults(fn=cmd_bench_score)

    p_st = sub.add_parser("stats",
                          help="fit + score a config, then emit the full "
                               "repro.obs metrics snapshot")
    p_st.add_argument("--config", required=True)
    p_st.add_argument("--queries", type=int, default=64,
                      help="sample queries to score before the snapshot")
    p_st.add_argument("--format", choices=("json", "prom"), default="json",
                      help="snapshot encoding (plain JSON or Prometheus "
                           "exposition text)")
    p_st.add_argument("--out", default="-",
                      help="file path, or '-' for stdout")
    p_st.set_defaults(fn=cmd_stats)

    p_tr = sub.add_parser("trace",
                          help="fit + score a config through the async "
                               "serving path, then export the flight "
                               "recorder (Chrome trace / JSONL)")
    p_tr.add_argument("--config", required=True)
    p_tr.add_argument("--queries", type=int, default=64,
                      help="sample queries to score through score_stream")
    p_tr.add_argument("--format", choices=("chrome", "jsonl"),
                      default="chrome", help="trace encoding")
    p_tr.add_argument("--sample-rate", type=float, default=None,
                      help="head-sampling rate override (default: the "
                           "config's tracing section, else 1.0)")
    p_tr.add_argument("--out", default="trace.json",
                      help="output file path")
    p_tr.set_defaults(fn=cmd_trace)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main(sys.argv[1:])
