"""Declarative pipeline configuration: one artifact describing a whole run.

After PRs 1-4 the repo had four parallel configuration surfaces —
``distributed_cluster(...)`` kwargs, ``ServiceConfig`` /
``ShardedServiceConfig`` / ``TreeConfig`` dataclasses, and the
process-global ``KernelPolicy`` / ``SummarizerPolicy`` defaults — with
overlapping fields and no way to persist or reproduce a full setup.
``PipelineConfig`` is the one front door:

* **problem** — what is being clustered: ``dim`` / ``k`` / ``t`` (the
  paper's z, the outlier budget) / ``metric``;
* **summarizer** — the :class:`repro.summarize.SummarizerPolicy` selecting
  the per-site / per-leaf summary algorithm;
* **kernels** — the :class:`repro.kernels.dispatch.KernelPolicy` selecting
  compute backends and tile sizes;
* **topology** — how the data reaches the coordinator: ``oneshot``
  (Algorithm 3 over a partitioned dataset), ``stream`` (single-host
  merge-and-reduce tree), or ``sharded`` (one tree per site, gathered
  roots), with the sites / window / cadence knobs that shape each.

Everything is a frozen dataclass of JSON-scalar fields, validated at
construction, with an exact ``to_dict`` / ``from_dict`` / JSON round-trip
(``from_dict(to_dict(c)) == c``, including through ``json.dumps``), so a
configuration is a reproducible artifact: checkpoint manifests embed it,
``python -m repro`` executes it from a file, and swapping the summarizer,
metric or topology is a one-line change to the artifact — not a rewrite
against a different API.

The existing layer configs are *derived views*: :meth:`service_config` /
:meth:`sharded_config` project a ``PipelineConfig`` onto the stream-layer
dataclasses (which share one ``BaseServiceConfig``), and the oneshot
topology maps onto ``distributed_cluster`` / ``simulate_coordinator``
kwargs — bit-identical to calling those layers directly.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Callable, Optional

from repro.kernels.dispatch import (KernelPolicy, get_default_policy,
                                    BACKENDS)
from repro.kernels.pdist.ref import METRICS
from repro.obs.tracing import TraceSpec
from repro.serve.spec import SHED_POLICIES, ServingSpec
from repro.store.spec import StoreSpec
from repro.stream.service import ServiceConfig
from repro.stream.sharded import ShardedServiceConfig
from repro.summarize.base import (SummarizerPolicy, get_default_summarizer,
                                  select_summarizer)

TOPOLOGIES = ("oneshot", "stream", "sharded")
PARTITIONS = ("random", "adversarial")
SITE_BUDGETS = ("full", "paper")

_CONFIG_VERSION = 2

# version N -> migration upgrading a version-N payload dict to N+1; the
# from_dict loop walks these until the payload reaches _CONFIG_VERSION.
# A version with no registered migration (older than any we still read,
# or newer than this build) is a hard error, exactly as before.
_MIGRATIONS: dict[int, Callable[[dict], dict]] = {}


def register_config_migration(from_version: int):
    """Decorator registering ``fn(payload) -> payload`` that upgrades a
    version-``from_version`` config payload (the ``to_dict`` image minus
    the ``version`` key) to version ``from_version + 1``.  Migrations
    chain: a v1 artifact read by a v3 build runs v1->v2 then v2->v3."""
    def deco(fn: Callable[[dict], dict]) -> Callable[[dict], dict]:
        _MIGRATIONS[from_version] = fn
        return fn
    return deco


@register_config_migration(1)
def _migrate_v1_to_v2(d: dict) -> dict:
    # v2 added the optional "store" section (tiered summary store +
    # incremental refresh).  A v1 payload is already a valid v2 payload —
    # absent "store" means no store, same semantics the v1 build had.
    warnings.warn(
        "reading a version-1 pipeline config; upgrading to version 2 "
        "(re-serialize with to_dict()/to_json() to persist the upgrade)",
        UserWarning, stacklevel=4)
    return d


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _int_field(name: str, v, lo: int) -> None:
    _require(isinstance(v, int) and not isinstance(v, bool) and v >= lo,
             f"{name} must be an int >= {lo}, got {v!r}")


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """What is being clustered: (k, t)-means/median with outliers in R^dim."""

    dim: int
    k: int
    t: int                  # outlier budget (the paper's z)
    metric: str = "l2sq"

    def __post_init__(self):
        _int_field("problem.dim", self.dim, 1)
        _int_field("problem.k", self.k, 1)
        _int_field("problem.t", self.t, 0)
        _require(self.metric in METRICS,
                 f"problem.metric must be one of {METRICS}, "
                 f"got {self.metric!r}")


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """How data reaches the coordinator; knobs outside a kind's column must
    stay at their defaults (a windowed oneshot or a 3-site stream is a
    configuration error, not a silently-ignored field)."""

    kind: str = "oneshot"            # oneshot | stream | sharded
    sites: int = 1                   # oneshot partitions / sharded sites
    window: Optional[int] = None     # stream/sharded sliding window (raw pts)
    refresh_every: int = 8192        # stream/sharded model cadence (raw pts)
    leaf_size: int = 2048            # stream/sharded tree leaf
    micro_batch: int = 256           # scoring batch shape (all kinds)
    async_refresh: bool = False      # stream/sharded double-buffered refresh
    partition: str = "random"        # oneshot per-site budget mode
    site_budget: str = "full"        # sharded per-site root budget
    use_shard_map: bool = False      # oneshot/sharded: real collective

    def __post_init__(self):
        _require(self.kind in TOPOLOGIES,
                 f"topology.kind must be one of {TOPOLOGIES}, "
                 f"got {self.kind!r}")
        _int_field("topology.sites", self.sites, 1)
        _int_field("topology.refresh_every", self.refresh_every, 1)
        _int_field("topology.leaf_size", self.leaf_size, 1)
        _int_field("topology.micro_batch", self.micro_batch, 1)
        if self.window is not None:
            _int_field("topology.window", self.window, 1)
        _require(self.partition in PARTITIONS,
                 f"topology.partition must be one of {PARTITIONS}, "
                 f"got {self.partition!r}")
        _require(self.site_budget in SITE_BUDGETS,
                 f"topology.site_budget must be one of {SITE_BUDGETS}, "
                 f"got {self.site_budget!r}")
        if self.kind == "oneshot":
            _require(self.window is None,
                     "topology.window is a stream/sharded knob; a oneshot "
                     "run has no stream to window")
            _require(not self.async_refresh,
                     "topology.async_refresh is a stream/sharded knob")
            for name in ("refresh_every", "leaf_size"):
                default = type(self).__dataclass_fields__[name].default
                _require(getattr(self, name) == default,
                         f"topology.{name} is a stream/sharded tree knob; "
                         f"a oneshot run clusters everything in one pass "
                         f"(leave it at the default, {default})")
        if self.kind == "stream":
            _require(self.sites == 1,
                     "topology.sites > 1 needs kind='sharded' "
                     "(a single-host stream has exactly one site)")
            _require(not self.use_shard_map,
                     "topology.use_shard_map is a oneshot/sharded knob")
        if self.kind != "oneshot":
            _require(self.partition == "random",
                     "topology.partition is a oneshot knob")
        if self.kind != "sharded":
            _require(self.site_budget == "full",
                     "topology.site_budget is a sharded knob")


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """The one declarative description of a clustering pipeline.

    ``summarizer`` / ``kernels`` default to the process-wide policies
    *captured at construction* (same rule as the stream configs), so a
    serialized config is always concrete — ``to_dict`` never emits a
    "whatever the process default happens to be" placeholder.
    """

    problem: ProblemSpec
    topology: TopologySpec = TopologySpec()
    summarizer: Optional[SummarizerPolicy] = None
    kernels: Optional[KernelPolicy] = None
    second_iters: int = 25           # second-level k-means-- iterations
    seed: int = 0
    # None = serve with ServingSpec() defaults when score_stream is used;
    # set explicitly to pin admission control / batching in the artifact
    serving: Optional[ServingSpec] = None
    # None = process-default flight recorder (env knobs); set explicitly
    # to pin sampling / ring size in the artifact — applied to the
    # telemetry plane when a Session is constructed from this config
    tracing: Optional[TraceSpec] = None
    # None = keep every tree level resident and refit on every refresh
    # (the pre-v2 behavior, bit for bit); set a StoreSpec to bound hot
    # memory (spill cold levels, demand-page them back) and/or skip /
    # warm-start refreshes whose root did not change (stream/sharded only)
    store: Optional[StoreSpec] = None

    def __post_init__(self):
        _require(isinstance(self.problem, ProblemSpec),
                 f"problem must be a ProblemSpec, got {self.problem!r}")
        _require(isinstance(self.topology, TopologySpec),
                 f"topology must be a TopologySpec, got {self.topology!r}")
        _require(self.serving is None
                 or isinstance(self.serving, ServingSpec),
                 f"serving must be a ServingSpec or None, "
                 f"got {self.serving!r}")
        _require(self.tracing is None
                 or isinstance(self.tracing, TraceSpec),
                 f"tracing must be a TraceSpec or None, "
                 f"got {self.tracing!r}")
        _require(self.store is None or isinstance(self.store, StoreSpec),
                 f"store must be a StoreSpec or None, got {self.store!r}")
        if self.store is not None:
            _require(self.topology.kind != "oneshot",
                     "store is a stream/sharded knob: a oneshot run keeps "
                     "no tree to tier and refits from raw points every "
                     "time, so a store section would be silently inert")
        if self.summarizer is None:
            object.__setattr__(self, "summarizer", get_default_summarizer())
        if self.kernels is None:
            object.__setattr__(self, "kernels", get_default_policy())
        _int_field("second_iters", self.second_iters, 1)
        _require(isinstance(self.seed, int) and not isinstance(self.seed, bool),
                 f"seed must be an int, got {self.seed!r}")
        # the summarizer must actually serve this problem (an explicit name
        # that cannot is a config error now, not a runtime surprise later) ...
        p = self.problem
        spec = select_summarizer(self.summarizer, metric=p.metric,
                                 k=p.k, t=p.t)
        # ... and a shard_map oneshot additionally needs its fixed-shape
        # site path (host-driven summarizers only run host-simulated)
        if self.topology.kind == "oneshot" and self.topology.use_shard_map:
            _require(spec.site_summary is not None,
                     f"summarizer {spec.name!r} is host-driven (no "
                     f"fixed-shape site path) and cannot run under "
                     f"topology.use_shard_map; drop use_shard_map to run "
                     f"it host-simulated")

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """Exact, JSON-scalar dict image (``from_dict`` inverts it).  The
        ``serving`` section appears only when set — configs written before
        it existed stay byte-identical."""
        d = {
            "version": _CONFIG_VERSION,
            "problem": dataclasses.asdict(self.problem),
            "topology": dataclasses.asdict(self.topology),
            "summarizer": {
                "name": self.summarizer.name,
                "params": [[k, v] for k, v in self.summarizer.params],
            },
            "kernels": dataclasses.asdict(self.kernels),
            "second_iters": self.second_iters,
            "seed": self.seed,
        }
        if self.serving is not None:
            d["serving"] = dataclasses.asdict(self.serving)
        if self.tracing is not None:
            d["tracing"] = dataclasses.asdict(self.tracing)
        if self.store is not None:
            d["store"] = dataclasses.asdict(self.store)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineConfig":
        """Inverse of :meth:`to_dict`; unknown or missing keys raise.

        Older serialized configs are upgraded in place through the
        registered migration chain (with a warning per hop); a version
        with no migration path to this build's still raises."""
        if not isinstance(d, dict):
            raise ValueError(f"expected a config dict, got {type(d).__name__}")
        d = dict(d)
        version = d.pop("version", _CONFIG_VERSION)
        while version != _CONFIG_VERSION:
            migrate = _MIGRATIONS.get(version)
            if migrate is None:
                raise ValueError(
                    f"config version {version!r} is not supported "
                    f"(this build reads version {_CONFIG_VERSION}"
                    + (f"; migrations exist from versions "
                       f"{sorted(_MIGRATIONS)}" if _MIGRATIONS else "")
                    + ")")
            d = migrate(dict(d))
            version += 1
        try:
            problem = d.pop("problem")
            topology = d.pop("topology", {})
            summarizer = d.pop("summarizer", None)
            kernels = d.pop("kernels", None)
            second_iters = d.pop("second_iters", 25)
            seed = d.pop("seed", 0)
            serving = d.pop("serving", None)
            tracing = d.pop("tracing", None)
            store = d.pop("store", None)
        except KeyError as e:
            raise ValueError(f"config is missing required section {e}")
        if d:
            raise ValueError(f"unknown config keys {sorted(d)}; expected "
                             f"problem/topology/summarizer/kernels/"
                             f"second_iters/seed/serving/tracing/store")
        return cls(
            problem=_spec_from(ProblemSpec, "problem", problem),
            topology=_spec_from(TopologySpec, "topology", topology),
            summarizer=_summarizer_from(summarizer),
            kernels=_kernels_from(kernels),
            second_iters=second_iters,
            seed=seed,
            serving=_serving_from(serving),
            tracing=_tracing_from(tracing),
            store=_store_from(store),
        )

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "PipelineConfig":
        return cls.from_dict(json.loads(text))

    # --------------------------------------------------------- derived views
    def service_config(self) -> ServiceConfig:
        """Project onto the single-host stream layer (kind == 'stream')."""
        _require(self.topology.kind == "stream",
                 f"service_config() needs topology.kind='stream', "
                 f"got {self.topology.kind!r}")
        return ServiceConfig(**self._base_service_kwargs())

    def sharded_config(self) -> ShardedServiceConfig:
        """Project onto the multi-host stream layer (kind == 'sharded')."""
        _require(self.topology.kind == "sharded",
                 f"sharded_config() needs topology.kind='sharded', "
                 f"got {self.topology.kind!r}")
        return ShardedServiceConfig(
            **self._base_service_kwargs(),
            n_sites=self.topology.sites,
            site_budget=self.topology.site_budget,
            use_shard_map=self.topology.use_shard_map,
        )

    def _base_service_kwargs(self) -> dict:
        p, topo = self.problem, self.topology
        return dict(
            dim=p.dim, k=p.k, t=p.t, metric=p.metric,
            leaf_size=topo.leaf_size, refresh_every=topo.refresh_every,
            micro_batch=topo.micro_batch, second_iters=self.second_iters,
            policy=self.kernels, summarizer=self.summarizer,
            window=topo.window, async_refresh=topo.async_refresh,
            seed=self.seed, store=self.store)


def _spec_from(cls, section: str, d) -> object:
    if not isinstance(d, dict):
        raise ValueError(f"config section {section!r} must be a dict, "
                         f"got {d!r}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown {section} keys {sorted(unknown)}; "
                         f"expected a subset of {sorted(known)}")
    return cls(**d)


def _summarizer_from(d) -> Optional[SummarizerPolicy]:
    if d is None or isinstance(d, SummarizerPolicy):
        return d
    if isinstance(d, str):
        return SummarizerPolicy(d)
    if not isinstance(d, dict) or set(d) - {"name", "params"}:
        raise ValueError(f"summarizer must be a name or a "
                         f"{{name, params}} dict, got {d!r}")
    params = d.get("params", ())
    try:
        pairs = tuple((str(k), v) for k, v in params)
    except (TypeError, ValueError):
        raise ValueError(f"summarizer params must be [key, value] pairs, "
                         f"got {params!r}")
    return SummarizerPolicy(d.get("name", "auto"), pairs)


def _serving_from(d) -> Optional[ServingSpec]:
    if d is None or isinstance(d, ServingSpec):
        return d
    if isinstance(d, str):
        # bare policy name: "shed" / "wait" with default bounds
        if d not in SHED_POLICIES:
            raise ValueError(f"serving must be a shed policy in "
                             f"{SHED_POLICIES} or a ServingSpec dict, "
                             f"got {d!r}")
        return ServingSpec(shed_policy=d)
    return _spec_from(ServingSpec, "serving", d)


def _store_from(d) -> Optional[StoreSpec]:
    if d is None or isinstance(d, StoreSpec):
        return d
    if isinstance(d, bool):
        # bare flag: store=True enables incremental refresh with no
        # tiering (everything stays resident); store=False is no store
        return StoreSpec() if d else None
    if isinstance(d, int):
        # bare int: hot-level budget with the other knobs defaulted
        return StoreSpec(hot_levels=d)
    return _spec_from(StoreSpec, "store", d)


def _tracing_from(d) -> Optional[TraceSpec]:
    if d is None or isinstance(d, TraceSpec):
        return d
    if isinstance(d, bool):
        # bare flag: tracing=False turns the flight recorder off
        return TraceSpec(enabled=d)
    if isinstance(d, (int, float)):
        # bare number: head-sampling rate with default ring/seed
        return TraceSpec(sample_rate=float(d))
    return _spec_from(TraceSpec, "tracing", d)


def _kernels_from(d) -> Optional[KernelPolicy]:
    if d is None or isinstance(d, KernelPolicy):
        return d
    if isinstance(d, str):
        return KernelPolicy(backend=d)
    if not isinstance(d, dict) or set(d) - {"backend", "block_n", "autotune"}:
        raise ValueError(f"kernels must be a backend name in {BACKENDS} or a "
                         f"{{backend, block_n, autotune}} dict, got {d!r}")
    return KernelPolicy(backend=d.get("backend", "auto"),
                        block_n=d.get("block_n"),
                        autotune=bool(d.get("autotune", False)))


def pipeline_config(
    *,
    dim: int,
    k: int,
    t: int,
    metric: str = "l2sq",
    topology: str = "oneshot",
    summarizer=None,
    kernels=None,
    second_iters: int = 25,
    seed: int = 0,
    serving=None,
    tracing=None,
    store=None,
    **topology_kwargs,
) -> PipelineConfig:
    """Flat-keyword constructor — the ergonomic front door.

    ``topology`` is the kind; any remaining keywords are ``TopologySpec``
    fields (``sites=``, ``window=``, ``refresh_every=``, ...).
    ``summarizer`` / ``kernels`` also accept bare names
    (``summarizer="coreset"``, ``kernels="pallas"``); ``serving`` accepts
    a :class:`repro.serve.ServingSpec`, a ``{queue_bound, ...}`` dict, or
    a bare shed policy name (``serving="wait"``); ``tracing`` accepts a
    :class:`repro.obs.TraceSpec`, a ``{sample_rate, ...}`` dict, a bare
    sampling rate (``tracing=0.1``) or flag (``tracing=False``);
    ``store`` accepts a :class:`repro.store.StoreSpec`, a
    ``{hot_levels, ...}`` dict, a bare hot-level budget (``store=2``) or
    flag (``store=True`` = incremental refresh without tiering).

        cfg = pipeline_config(dim=5, k=20, t=500, topology="sharded",
                              sites=4, window=100_000, store=2)
    """
    return PipelineConfig(
        problem=ProblemSpec(dim=dim, k=k, t=t, metric=metric),
        topology=_spec_from(TopologySpec, "topology",
                            {"kind": topology, **topology_kwargs}),
        summarizer=_summarizer_from(summarizer),
        kernels=_kernels_from(kernels),
        second_iters=second_iters,
        seed=seed,
        serving=_serving_from(serving),
        tracing=_tracing_from(tracing),
        store=_store_from(store),
    )
