"""One front door over the pipeline: declarative config + session facade.

``PipelineConfig`` (``config.py``) is the single serializable description
of a run — problem, summarizer policy, kernel policy, topology — and
``Session`` (``session.py``) is the single verb set (``fit`` / ``ingest``
/ ``refresh`` / ``score`` / ``save`` / ``load``) driving
``distributed_cluster``, ``StreamService`` or ``ShardedStreamService``
behind it, bit-identical to calling those layers directly.
``Session.score_stream`` adds the async serving path (``repro.serve``:
continuous batching + admission control, configured by the config's
optional ``serving`` section); the optional ``tracing`` section
(``repro.obs.TraceSpec``) pins the flight recorder's sampling knobs, and
``Session.dump_trace`` exports it.  ``python -m repro`` (``cli.py``)
executes a config file.
"""
from repro.api.config import (  # noqa: F401
    PARTITIONS, PipelineConfig, ProblemSpec, SITE_BUDGETS, TOPOLOGIES,
    TopologySpec, pipeline_config, register_config_migration,
)
from repro.obs.tracing import TraceSpec  # noqa: F401
from repro.store import StoreSpec, TieredStore  # noqa: F401
from repro.api.session import OneshotEngine, Session  # noqa: F401
from repro.serve import (  # noqa: F401
    ScoreTicket, ServingScheduler, ServingSpec, ShedReject,
)
