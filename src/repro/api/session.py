"""One verb set over every topology: the ``Session`` facade.

``Session(config)`` builds and drives the layer the config's topology
names — ``simulate_coordinator`` / ``distributed_cluster`` (oneshot),
``StreamService`` (stream) or ``ShardedStreamService`` (sharded) — behind
one interface:

    fit(points)      ingest + refresh in one call; returns the ModelState
    ingest(points)   feed raw points (stream topologies refresh on cadence)
    refresh()        (re)fit the serving model on everything ingested
    score(queries)   nearest-center distance / outlier score per query row
    score_stream(queries)  the same scores through the async serving path
                     (continuous batching + admission control, repro.serve)
    save(dir)        checkpoint everything, config embedded in the manifest
    Session.load(dir)  rebuild topology + policies from the manifest alone

The facade adds **no math of its own**: stream topologies delegate verbs
verbatim to the services, and the oneshot engine calls the same
coordinator entry points a direct caller would, with the same key
(``jax.random.key(config.seed)``) — so Session results are bit-identical
to driving those layers directly with equivalent settings (asserted in
``tests/test_api.py``).

Oneshot scoring: the coordinator layers return centers and outlier ids
but no serving model, so after the fit the engine derives one with the
same rule the stream services use (threshold = the largest inlier
distance among summary records); queries then flow through the shared
micro-batched read path of ``ServingFrontEnd``, giving every topology the
same ``QueryResult`` surface and latency accounting.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Iterator, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import PipelineConfig
from repro.checkpoint.manager import CheckpointManager
from repro.core.collective import sites_mesh
from repro.core.distributed import distributed_cluster, simulate_coordinator
from repro.kernels.pdist.ops import min_argmin
from repro.serve.scheduler import ScoreTicket, ServingScheduler, ShedReject
from repro.stream.service import (ModelState, QueryResult, ServiceConfig,
                                  ServingFrontEnd, StreamService)
from repro.stream.sharded import ShardedStreamService


class OneshotEngine(ServingFrontEnd):
    """Algorithm 3 behind the serving-front-end verb set.

    ``ingest`` accumulates raw rows; ``refresh`` runs the coordinator on
    everything accumulated (a pure function of the ingested points and the
    config seed — refreshing twice with no new data reproduces the same
    model bit for bit); the inherited read path serves queries.  The full
    coordinator result (outlier ids, summary ids, communication) stays
    available as ``.result``.
    """

    _topology = "oneshot"

    def __init__(self, pipeline: PipelineConfig):
        topo = pipeline.topology
        if topo.kind != "oneshot":
            raise ValueError(f"OneshotEngine needs topology.kind='oneshot', "
                             f"got {topo.kind!r}")
        p = pipeline.problem
        # ServingFrontEnd only needs the shared serving knobs; reusing the
        # stream dataclass keeps the read/checkpoint glue identical
        super().__init__(ServiceConfig(
            dim=p.dim, k=p.k, t=p.t, metric=p.metric,
            micro_batch=topo.micro_batch, second_iters=pipeline.second_iters,
            policy=pipeline.kernels, summarizer=pipeline.summarizer,
            seed=pipeline.seed))
        self.pipeline = pipeline
        self._rows: list[np.ndarray] = []
        self.result: Optional[dict] = None

    # ------------------------------------------------------------ write path
    def ingest(self, points, weights=None) -> None:
        self.poll_refresh()
        x, w = self._validate_points(points, weights)
        if w is not None:
            raise ValueError("oneshot topology clusters raw (unit-weight) "
                             "points; weighted records are a stream concept")
        self._rows.append(x)

    @property
    def total_ingested(self) -> int:
        return int(sum(r.shape[0] for r in self._rows))

    def _root_records(self) -> int:
        # the oneshot "root" is every raw row the coordinator will see
        return self.total_ingested

    # ------------------------------------------------------------ refresh fit
    def _fit_closure(self, version: int):
        if not self._rows:
            raise RuntimeError("refresh() before any point was ingested")
        x = np.concatenate(self._rows)
        self._rows = [x]          # compact the buffer while we have it
        return functools.partial(self._fit, x, version)

    def _fit(self, x: np.ndarray, version: int) -> ModelState:
        res = _run_oneshot(x, self.pipeline)
        self.result = res
        return _model_from_result(x, res, self.pipeline, version)

    # ------------------------------------------------------------ checkpoint
    def _result_arrays(self) -> dict:
        r = self.result or {}
        return {
            "summary_ids": np.asarray(
                r.get("summary_ids", np.zeros(0)), np.int64),
            "summary_weights": np.asarray(
                r.get("summary_weights", np.zeros(0)), np.float32),
            "outlier_ids": np.asarray(
                r.get("outlier_ids", np.zeros(0)), np.int64),
            "comm_records": np.float64(r.get("comm_records", 0.0)),
        }

    def save(self, manager: CheckpointManager, step: int, *,
             blocking: bool = True, extra_meta: Optional[dict] = None) -> None:
        self.join_refresh()
        x = (np.concatenate(self._rows) if self._rows
             else np.zeros((0, self.cfg.dim), np.float32))
        r = self.result
        n_sum = 0 if r is None else len(r["summary_ids"])
        n_out = 0 if r is None else len(r["outlier_ids"])
        state = {"x": x, "model": self._model_arrays(),
                 "result": self._result_arrays(),
                 "counters": {"next_id": np.int64(self._next_id)}}
        manager.save(step, state, blocking=blocking,
                     meta={**(extra_meta or {}),
                           "format": "oneshot-session-v1",
                           "n_rows": int(x.shape[0]),
                           "n_summary": n_sum, "n_outliers": n_out})

    @classmethod
    def restore(cls, pipeline: PipelineConfig, manager: CheckpointManager,
                step: int | None = None) -> "OneshotEngine":
        meta = manager.read_meta(step)
        fmt = meta.get("format")
        if fmt != "oneshot-session-v1":
            raise ValueError(
                f"checkpoint format {fmt!r} is not a oneshot session "
                f"checkpoint — restore it with the layer that wrote it")
        eng = cls(pipeline)
        n_sum, n_out = int(meta["n_summary"]), int(meta["n_outliers"])
        skel = {"x": np.zeros((int(meta["n_rows"]), pipeline.problem.dim),
                              np.float32),
                "model": eng._model_skeleton(eng.cfg),
                "result": {"summary_ids": np.zeros(n_sum, np.int64),
                           "summary_weights": np.zeros(n_sum, np.float32),
                           "outlier_ids": np.zeros(n_out, np.int64),
                           "comm_records": np.float64(0)},
                "counters": {"next_id": np.int64(0)}}
        state, _ = manager.restore(skel, step)
        x = np.asarray(state["x"], np.float32)
        eng._rows = [x] if x.shape[0] else []
        eng._next_id = int(state["counters"]["next_id"])
        eng._install_model_arrays(state["model"])
        if eng.model is not None:   # a fit happened: rebuild .result from
            r = state["result"]     # the persisted arrays + the model
            eng.result = {
                "centers": np.asarray(eng.model.centers),
                "outlier_ids": np.asarray(r["outlier_ids"]),
                "summary_ids": np.asarray(r["summary_ids"]),
                "summary_weights": np.asarray(r["summary_weights"]),
                "comm_records": float(r["comm_records"]),
                "cost": float(eng.model.cost),
            }
        return eng


def _run_oneshot(x: np.ndarray, pipeline: PipelineConfig) -> dict:
    """Drive the coordinator layer a direct caller would, same key."""
    p, topo = pipeline.problem, pipeline.topology
    s = topo.sites
    key = jax.random.key(pipeline.seed)
    common = dict(k=p.k, t=p.t, partition=topo.partition,
                  summarizer=pipeline.summarizer,
                  second_iters=pipeline.second_iters, metric=p.metric,
                  policy=pipeline.kernels)
    if not topo.use_shard_map:
        parts = np.array_split(x, s)
        res = simulate_coordinator(parts, key, **common)
        # both execution paths expose the same result keys (they are also
        # what the checkpoint persists, so .result survives Session.load)
        return {k: res[k] for k in ("centers", "outlier_ids", "summary_ids",
                                    "summary_weights", "comm_records",
                                    "cost")}
    if x.shape[0] % s:
        raise ValueError(
            f"topology.use_shard_map needs len(points) divisible by "
            f"sites={s}, got {x.shape[0]} rows; pad or drop the remainder")
    if len(jax.devices()) < s:
        raise RuntimeError(
            f"topology.use_shard_map needs >= {s} devices for "
            f"{s} sites, have {len(jax.devices())}; drop use_shard_map "
            f"to run host-simulated")
    res = distributed_cluster(
        jnp.asarray(x, jnp.float32).reshape(s, -1, x.shape[1]), key,
        sites_mesh(s), **common)
    out = np.asarray(res.outlier_ids)
    sid = np.asarray(res.summary_ids)
    keep = sid >= 0
    return {
        "centers": np.asarray(res.centers),
        "outlier_ids": out[out >= 0],
        "summary_ids": sid[keep],
        "summary_weights": np.asarray(res.summary_weights)[keep],
        "comm_records": float(res.comm_records),
        "cost": float(res.cost),
    }


def _model_from_result(x: np.ndarray, res: dict, pipeline: PipelineConfig,
                       version: int) -> ModelState:
    """Serving model from a coordinator result — same threshold rule as
    ``repro.stream.service.fit_model`` (largest inlier distance among the
    summary records the second level was fit on)."""
    p = pipeline.problem
    centers = jnp.asarray(res["centers"], jnp.float32)
    pts = jnp.asarray(x[res["summary_ids"]], jnp.float32)
    dist, _ = min_argmin(pts, centers, metric=p.metric,
                         policy=pipeline.kernels)
    inlier = ~np.isin(res["summary_ids"], res["outlier_ids"])
    dist = np.asarray(dist)
    threshold = float(dist[inlier].max()) if inlier.any() else 0.0
    return ModelState(
        centers=centers,
        threshold=jnp.float32(max(threshold, 1e-12)),
        cost=jnp.float32(res["cost"]),
        version=jnp.int32(version),
        trained_weight=jnp.float32(x.shape[0]))


class Session:
    """The one front door: construct from a :class:`PipelineConfig`, then
    ``fit`` / ``ingest`` / ``refresh`` / ``score`` / ``save`` regardless of
    topology.  ``session.engine`` exposes the underlying layer
    (``StreamService``, ``ShardedStreamService`` or ``OneshotEngine``) as
    the escape hatch for layer-specific surface."""

    def __init__(self, config: PipelineConfig, *, _engine=None):
        self.config = config
        self._serving: Optional[ServingScheduler] = None
        self._attach_lock = threading.Lock()
        if config.tracing is not None:
            # pin the process flight recorder to the artifact's knobs
            # (sampling, ring, seed) before the engine captures handles
            from repro import obs
            obs.apply_trace_spec(config.tracing)
        if _engine is not None:
            self.engine = _engine
        else:
            kind = config.topology.kind
            if kind == "stream":
                self.engine = StreamService(config.service_config())
            elif kind == "sharded":
                self.engine = ShardedStreamService(config.sharded_config())
            else:
                self.engine = OneshotEngine(config)

    # ------------------------------------------------------------ serving
    @property
    def serving(self) -> Optional[ServingScheduler]:
        """The attached async scheduler — None until the first
        :meth:`score_stream` call (or explicit :meth:`serve`)."""
        return self._serving

    def serve(self) -> ServingScheduler:
        """Attach (and return) the continuous-batching scheduler for this
        session's engine, configured by ``config.serving`` (defaults apply
        when the config has no serving section).  Idempotent; once a
        scheduler is attached, the synchronous verbs route through its
        ``engine_lock`` so direct ``score``/``refresh`` calls and worker
        ticks never interleave on the engine.  Safe to race: concurrent
        first callers attach exactly one scheduler."""
        if self._serving is None:
            with self._attach_lock:
                if self._serving is None:
                    self._serving = ServingScheduler(self.engine,
                                                     self.config.serving)
        return self._serving

    def score_stream(self, queries, *, tenant: str = "default",
                     timeout: Optional[float] = None,
                     ) -> Iterator[Union[QueryResult, ShedReject]]:
        """Score rows through the async serving path.

        Rows are admitted (and possibly shed) *now*, on the caller's
        thread — many threads calling ``score_stream`` concurrently share
        one scheduler, and their rows coalesce into common worker ticks.
        Returns an iterator yielding, per row in order, the engine's
        ``QueryResult`` or a typed :class:`ShedReject`; iterate to block
        on completion.  Scores are bit-identical to :meth:`score`.
        """
        tickets = self.serve().submit(queries, tenant=tenant)
        return (t.result(timeout) for t in tickets)

    def submit_stream(self, queries, *, tenant: str = "default",
                      ) -> "list[ScoreTicket]":
        """Like :meth:`score_stream` but returns the raw tickets, for
        callers that want ``done()`` polling or per-ticket latency."""
        return self.serve().submit(queries, tenant=tenant)

    def close(self) -> None:
        """Drain and stop the serving scheduler, if one is attached.
        The session's synchronous verbs keep working afterwards."""
        with self._attach_lock:
            serving, self._serving = self._serving, None
        if serving is not None:
            serving.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def _engine_guard(self):
        """The scheduler's engine lock when serving is attached (direct
        verbs must not interleave with worker ticks), else a no-op."""
        if self._serving is not None:
            return self._serving.engine_lock
        return contextlib.nullcontext()

    # ------------------------------------------------------------ verbs
    def ingest(self, points, weights=None, *, site: int | None = None) -> None:
        """Feed raw points.  ``site=`` pins a batch to one site (sharded
        topology only — elsewhere routing is not a concept)."""
        if site is not None:
            if self.config.topology.kind != "sharded":
                raise ValueError(
                    f"site= routing needs topology.kind='sharded', this "
                    f"session is {self.config.topology.kind!r}")
            with self._engine_guard():
                self.engine.ingest(points, weights, site=site)
        else:
            with self._engine_guard():
                self.engine.ingest(points, weights)

    def refresh(self, *, blocking: bool = True) -> Optional[ModelState]:
        """(Re)fit the serving model on everything ingested so far."""
        with self._engine_guard():
            return self.engine.refresh(blocking=blocking)

    def fit(self, points=None, weights=None) -> ModelState:
        """``ingest`` (optional) + blocking ``refresh`` in one call."""
        if points is not None:
            self.ingest(points, weights)
        with self._engine_guard():
            return self.engine.refresh(blocking=True)

    def score(self, queries) -> list:
        """Score query rows against the current model; returns the same
        ``QueryResult`` records every topology's read path produces."""
        with self._engine_guard():
            return self.engine.score(queries)

    def latency_stats(self) -> dict:
        return self.engine.latency_stats()

    def store_stats(self) -> Optional[dict]:
        """Aggregate tiered-store movement tallies across this session's
        trees — ``{"spills", "page_ins", "spill_bytes", "page_in_bytes"}``
        summed over sites — or None when the config has no tiered store
        (oneshot topology, no ``store`` section, or an untiered spec).
        Per-series detail lives in :meth:`stats` under ``store.*``."""
        trees = []
        if hasattr(self.engine, "tree"):
            trees = [self.engine.tree]
        elif hasattr(self.engine, "trees"):
            trees = list(self.engine.trees)
        stores = [t._store for t in trees if t._store is not None]
        if not stores:
            return None
        totals: dict = {}
        for s in stores:
            for k, v in s.stats().items():
                totals[k] = totals.get(k, 0) + v
        return totals

    def stats(self) -> dict:
        """The process metrics snapshot (``repro.obs``): one plain dict of
        every counter, gauge and latency/phase histogram the layers under
        this session reported — serve latency, ingest/refresh/score phase
        timings, tree activity, comm records+bytes per site, kernel-backend
        dispatch counts, checkpoint durations.  JSON-serializable as-is;
        render for Prometheus with ``repro.obs.render_prometheus``.

        The snapshot is process-wide by design (one registry, like any
        exporter) — two sessions of the same topology share series.
        """
        from repro import obs
        return obs.snapshot()

    def dump_trace(self, path, fmt: str = "chrome"):
        """Write the flight recorder's buffered spans to ``path``.

        ``fmt="chrome"`` (default) writes Chrome trace-event JSON — load
        it in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``
        to see each request/refresh as one stitched timeline.
        ``fmt="jsonl"`` writes one JSON record per span/event.  Returns
        the path written.  The recorder is process-wide, like
        :meth:`stats`.
        """
        from repro import obs
        return obs.dump_trace(path, fmt=fmt)

    @property
    def last_fit(self):
        """:class:`repro.stream.service.FitStats` of the most recent
        installed refresh (duration, records folded) — None before the
        first fit.  Staleness is ``engine.seconds_since_install()``."""
        return self.engine.last_fit

    @property
    def model(self) -> Optional[ModelState]:
        return self.engine.model

    @property
    def result(self) -> Optional[dict]:
        """Oneshot coordinator detail (outlier/summary ids, comm records);
        None for stream topologies, whose model is the serving state."""
        return getattr(self.engine, "result", None)

    # ------------------------------------------------------------ persistence
    def save(self, directory, *, step: int | None = None,
             blocking: bool = True) -> int:
        """Checkpoint the full session under ``directory``.

        The serialized ``PipelineConfig`` is embedded in the checkpoint
        manifest, so :meth:`load` reconstructs topology and policies with
        no caller-side state.  Returns the step written."""
        manager = CheckpointManager(directory)
        if step is None:
            latest = manager.latest_step()
            step = (latest + 1) if latest is not None else 1
        with self._engine_guard():
            self.engine.save(
                manager, step, blocking=blocking,
                extra_meta={"pipeline_config": self.config.to_dict()})
        return step

    @classmethod
    def load(cls, directory, *, step: int | None = None) -> "Session":
        """Rebuild a session from a checkpoint alone: the manifest's
        embedded config selects the topology and policies, then the
        matching layer restores its state (post-restore scores are
        bit-identical to the saved session's)."""
        manager = CheckpointManager(directory)
        meta = manager.read_meta(step)
        cfg_dict = meta.get("pipeline_config")
        if cfg_dict is None:
            raise ValueError(
                f"checkpoint in {directory} has no embedded pipeline config "
                f"(was it written by Session.save?); restore it with the "
                f"layer-specific restore() it was written by")
        config = PipelineConfig.from_dict(cfg_dict)
        kind = config.topology.kind
        if kind == "stream":
            engine = StreamService.restore(config.service_config(),
                                           manager, step)
        elif kind == "sharded":
            engine = ShardedStreamService.restore(config.sharded_config(),
                                                  manager, step)
        else:
            engine = OneshotEngine.restore(config, manager, step)
        return cls(config, _engine=engine)
