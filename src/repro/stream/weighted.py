"""Weighted Summary-Outliers: Algorithm 1 generalized to weighted inputs.

A record (x, w) stands for w coincident unit points.  Two changes from the
unit-weight algorithm in ``repro.core.summary``:

* Line 6 samples the m round-samples with probability proportional to
  weight (a record of weight w is w times as likely as a unit record);
* Line 8 grows the ball to the smallest radius rho_i whose captured
  *weight mass* reaches beta * W_i (W_i = total remaining weight), and the
  stopping rule |X_i| <= 8t becomes W_i <= 8t.

With unit weights both rules reduce exactly to the paper's.  The progress
guarantee is unchanged and deterministic: every round removes at least a
beta fraction of the remaining *mass*, so the loop runs at most
ceil(log(W/8t) / -log(1-beta)) rounds regardless of how the mass is
distributed over records.

Why this makes a summary-of-summaries well defined: a weighted summary Q of
X conserves mass (sum of Q's weights == total weight of X) and each output
record is an input point carrying the mass of the inputs mapped to it.
Summarizing the concatenation of two summaries Q1 u Q2 therefore produces a
summary of X1 u X2 whose information loss telescopes — each level of
re-summarization adds at most one Algorithm-1 loss term on top of the loss
already incurred below (triangle inequality through the intermediate
representative).  That is the merge-and-reduce composition the stream tree
(``repro.stream.tree``) relies on.

Host-driven like ``summary_outliers_compact``: set logic in numpy, the
distance inner loop stays jitted (``min_argmin``, backend-selected via
``KernelPolicy``).  Stream leaves and merges are small (10^3..10^4 records),
so the host loop is never the bottleneck; the latency-critical query path
in ``repro.stream.service`` is fully jitted.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.pdist.ops import min_argmin

_FAR = 1e30  # sentinel coordinate for rows padded into a jit bucket


def _bucket(n: int, lo: int = 256) -> int:
    """Next power-of-two >= n (min lo): bounds the number of jit shapes.
    Shared by the summarize and scoring paths (repro.stream.service)."""
    b = lo
    while b < n:
        b <<= 1
    return b


def categorical_by_weight(key: jax.Array, w: np.ndarray, shape) -> np.ndarray:
    """Sample ids (with replacement) with probability ∝ ``w`` (all > 0).

    Logits are -inf-padded to the shared power-of-two bucket so the jitted
    categorical compiles once per bucket, not once per distinct row count —
    the same idiom as the distance calls.  Shared by every host-driven
    summarizer (weighted Algorithm 1, ball_cover, coreset seeding).
    """
    logits = np.full((_bucket(w.size),), -np.inf, np.float32)
    logits[:w.size] = np.log(w)
    return np.asarray(jax.random.categorical(key, jnp.asarray(logits),
                                             shape=shape))


def _min_argmin_bucketed(xr: np.ndarray, c: np.ndarray, *, metric: str,
                         policy: Optional[KernelPolicy]):
    """min_argmin with the row count padded to a power-of-two bucket, so the
    jitted kernel compiles once per bucket instead of once per round (the
    remaining set shrinks every round and would otherwise retrace)."""
    nr = xr.shape[0]
    nb = _bucket(nr)
    if nb > nr:
        xr = np.concatenate(
            [xr, np.full((nb - nr, xr.shape[1]), _FAR, np.float32)])
    mind, amin = min_argmin(xr, c, metric=metric, policy=policy)
    return np.asarray(mind)[:nr], np.asarray(amin)[:nr]


class WeightedSummary(NamedTuple):
    """Compact (no padding) weighted summary of a weighted point set.

    points       (s, d) f32  — summary points (subset of the input rows)
    weights      (s,) f32    — mass mapped to each point; conserves input mass
    is_candidate (s,) bool   — True for survivors X_r (outlier candidates)
    n_rounds     int         — rounds the ball-growing loop ran
    total_weight float       — input mass (== weights.sum() up to fp error)
    indices      (s,) i64 | None — row ids of the summary points in the
                 summarizer's *input* (after zero-weight rows are dropped the
                 ids still refer to the original input rows).  None once the
                 provenance is lost (merges, checkpoint restores).
    """

    points: np.ndarray
    weights: np.ndarray
    is_candidate: np.ndarray
    n_rounds: int
    total_weight: float
    indices: Optional[np.ndarray] = None


def max_rounds(total_weight: float, t: int, beta: float) -> int:
    """Deterministic round bound: each round captures >= beta of the mass."""
    stop = max(8 * t, 1)
    if total_weight <= stop:
        return 0
    return max(1, int(math.ceil(math.log(total_weight / stop)
                                / -math.log1p(-beta))))


def weighted_summary_outliers(
    points,
    weights,
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    block_n: Optional[int] = None,      # removed alias: raises TypeError
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
) -> WeightedSummary:
    """Weighted Summary-Outliers over records (points[i], weights[i])."""
    from repro.summarize.base import clean_weighted_input, empty_summary

    policy = resolve_policy(policy, use_pallas=use_pallas, block_n=block_n,
                            caller="weighted_summary_outliers")
    x, w, orig_ids, total = clean_weighted_input(points, weights)
    n = x.shape[0]
    if n == 0:
        return empty_summary(x.shape[1])

    kappa = max(k, max(1, math.ceil(math.log(max(n, 2)))))
    m = max(1, int(math.ceil(alpha * kappa)))
    stop = max(8 * t, 1)
    bound = max_rounds(total, t, beta) + 4  # +4: fp slack on the mass sums

    remaining = np.arange(n, dtype=np.int64)
    acc_w = np.zeros(n, np.float32)          # mass captured per center
    center_ids: list[np.ndarray] = []
    rounds = 0
    while remaining.size and float(w[remaining].sum()) > stop and rounds < bound:
        key, sk = jax.random.split(key)
        wr = w[remaining]
        # Line 6 (weighted): sample m records with replacement, p ∝ weight.
        pick = categorical_by_weight(sk, wr, (m,))
        idx = remaining[pick]                 # global ids of this round's S_i
        mind, amin = _min_argmin_bucketed(x[remaining], x[idx], metric=metric,
                                          policy=policy)
        # Line 8 (weighted): smallest rho capturing >= beta * W_i of mass.
        order = np.argsort(mind, kind="stable")
        cumw = np.cumsum(wr[order])
        kpos = int(np.searchsorted(cumw, beta * float(wr.sum())))
        kpos = min(kpos, order.size - 1)
        rho = mind[order[kpos]]
        captured = mind <= rho                # samples sit at rho=0: always in
        # Line 9: each captured record's full mass goes to its nearest sample.
        np.add.at(acc_w, idx[amin[captured]], wr[captured])
        center_ids.append(np.unique(idx))
        remaining = remaining[~captured]
        rounds += 1

    centers = (np.unique(np.concatenate(center_ids)) if center_ids
               else np.empty(0, np.int64))
    # coincident sampled points can tie on argmin so one of them captures
    # all the mass; drop the zero-mass twins to keep the weights>0 invariant
    centers = centers[acc_w[centers] > 0]
    pts = np.concatenate([x[centers], x[remaining]])
    wts = np.concatenate([acc_w[centers], w[remaining]])
    cand = np.concatenate([np.zeros(centers.size, bool),
                           np.ones(remaining.size, bool)])
    return WeightedSummary(points=pts.astype(np.float32),
                           weights=wts.astype(np.float32),
                           is_candidate=cand,
                           n_rounds=rounds,
                           total_weight=total,
                           indices=orig_ids[np.concatenate([centers, remaining])])


def merge_summaries(summaries: Sequence[WeightedSummary]) -> WeightedSummary:
    """Concatenate weighted summaries (the 'merge' half of merge-and-reduce).

    Pure union — no information is lost; mass is conserved exactly.
    """
    live = [s for s in summaries if s.points.shape[0]]
    if not live:
        return WeightedSummary(np.zeros((0, 0), np.float32),
                               np.zeros((0,), np.float32),
                               np.zeros((0,), bool), 0, 0.0)
    return WeightedSummary(
        points=np.concatenate([s.points for s in live]),
        weights=np.concatenate([s.weights for s in live]),
        is_candidate=np.concatenate([s.is_candidate for s in live]),
        n_rounds=max(s.n_rounds for s in live),
        total_weight=float(sum(s.total_weight for s in live)),
    )


def resummarize(
    summaries: Sequence[WeightedSummary],
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
) -> WeightedSummary:
    """The 'reduce' half: weighted Summary-Outliers on the merged union.

    Keeps the full outlier budget t at every level so that up to t true
    outliers survive as candidates through any number of merges.
    """
    merged = merge_summaries(summaries)
    if merged.points.shape[0] == 0:
        return merged
    return weighted_summary_outliers(
        merged.points, merged.weights, key, k=k, t=t, alpha=alpha, beta=beta,
        metric=metric, policy=policy)
