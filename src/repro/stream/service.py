"""Online scoring front end over the stream tree.

Write path: ``ingest`` feeds raw points into the merge-and-reduce tree;
every ``refresh_every`` ingested points (or on demand) the tree root —
the union of all live weighted summaries — is re-clustered with weighted
k-means-- (the paper's coordinator step) into a versioned ``ModelState``.

Read path: ``submit`` enqueues assign/score requests; ``drain`` serves the
queue in fixed-size micro-batches through ONE fused kernel dispatch
(``repro.kernels.score``: min-distance → argmin → dist/threshold in a
single pass; backend/tile selection via ``ServiceConfig.policy``).  The
queue holds whole submitted *blocks*, not per-row tuples, so enqueue and
batch assembly are O(blocks) array copies instead of O(rows) Python
iterations.  Padding every micro-batch to the same static shape means
exactly one compile per (batch, model) shape — the hot path never
retraces.  Per-request latency (enqueue -> scored) is recorded for
p50/p99 reporting.

Double-buffered refresh (``async_refresh=True``): a cadence refresh
snapshots the tree root on the ingest thread, then fits the next
``ModelState`` on a worker thread while ingest keeps running and queries
keep scoring against the *old* model; the new model is installed at the
next ingest/drain boundary (``poll_refresh``).  The fit is a pure function
of (root snapshot, version, model key), so the async model is bit-identical
to what a blocking refresh at the same boundary would have produced — only
the install time moves.

Outlier scoring: a request's score is d(x, nearest center) / threshold,
where threshold is the largest inlier distance seen when the model was
fit; score > 1 flags the point as an outlier under the current model.

Restart story: ``save``/``restore`` round-trip the tree + model + service
counters through ``CheckpointManager`` (fixed-shape pytree, crc-verified,
atomic publish), so a restored service returns bit-identical scores.

The read path, model double-buffering and checkpoint glue live in
``ServingFrontEnd`` and are shared with the multi-host
``repro.stream.sharded.ShardedStreamService``; ``StreamService`` adds the
single-host tree write path.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections import deque
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core.kmeans_mm import kmeans_minus_minus
from repro.kernels.dispatch import KernelPolicy, get_default_policy
from repro.kernels.score.ops import score as fused_score
from repro.store.spec import StoreSpec
from repro.stream.tree import StreamTree, TreeConfig
from repro.summarize.base import SummarizerPolicy, get_default_summarizer


@dataclasses.dataclass(frozen=True)
class BaseServiceConfig:
    """Fields shared by every serving front end (single-host and sharded).

    ``ShardedServiceConfig`` extends this with its topology-only knobs
    (site count, per-site budget, collective path) instead of repeating
    the common fields — ``tests/test_api.py`` asserts the two configs
    stay field-compatible through this base.
    """

    dim: int
    k: int
    t: int
    leaf_size: int = 2048
    refresh_every: int = 8192        # raw points between model refreshes
    micro_batch: int = 256           # static query-batch shape
    second_iters: int = 25
    metric: str = "l2sq"
    # None = capture the process default (set_default_policy) at construction
    policy: Optional[KernelPolicy] = None
    # None = capture the process default (set_default_summarizer); selects
    # the tree's summary algorithm (leaf reduction + merge-reduce)
    summarizer: Optional[SummarizerPolicy] = None
    window: Optional[int] = None
    async_refresh: bool = False      # fit cadence models off the ingest path
    seed: int = 0
    # None = classic behavior (all-resident tree, every refresh refits).
    # A StoreSpec adds disk tiering and/or incremental refresh; model keys
    # are then derived from the tree's root epoch instead of the version,
    # so an unchanged root provably refits to the identical model — which
    # is what makes skipping it safe (see _fit_closure).
    store: Optional[StoreSpec] = None

    def __post_init__(self):
        if self.policy is None:
            object.__setattr__(self, "policy", get_default_policy())
        if self.summarizer is None:
            object.__setattr__(self, "summarizer", get_default_summarizer())


@dataclasses.dataclass(frozen=True)
class ServiceConfig(BaseServiceConfig):
    def tree_config(self) -> TreeConfig:
        return TreeConfig(
            dim=self.dim, k=self.k, t=self.t, leaf_size=self.leaf_size,
            metric=self.metric, policy=self.policy,
            summarizer=self.summarizer,
            window=self.window, seed=self.seed, store=self.store)


class ModelState(NamedTuple):
    centers: jnp.ndarray     # (k, d) f32
    threshold: jnp.ndarray   # () f32 — max inlier distance at fit time
    cost: jnp.ndarray        # () f32 — weighted second-level objective
    version: jnp.ndarray     # () i32 — 0 means "no model yet"
    trained_weight: jnp.ndarray  # () f32 — mass the model was fit on


class FitStats(NamedTuple):
    """Telemetry for the most recent installed refresh (every topology —
    the sharded service's ``RefreshStats`` adds the comm accounting on
    top of this).  ``installed_at`` is a ``time.perf_counter`` stamp;
    compare against it, don't interpret it as wall-clock."""
    version: int
    records_folded: int      # live root records the model was fit on
    fit_s: float             # wall time of the second-level fit
    installed_at: float


class QueryResult(NamedTuple):
    request_id: int
    center: int              # nearest-center index
    distance: float
    outlier_score: float     # distance / threshold; > 1 -> outlier
    is_outlier: bool
    latency_s: float


@functools.partial(jax.jit, static_argnames=("metric", "policy"))
def _score_batch(x, centers, threshold, *, metric, policy):
    # one registry dispatch for the whole read path (pdist + argmin +
    # threshold divide); for the non-quantized backends the fused op is
    # bit-identical to the composed min_argmin + divide it replaced
    # (tests/test_serving.py::test_fused_score_bit_identical_to_composed)
    return fused_score(x, centers, threshold, metric=metric, policy=policy)


def fit_model(pts, wts, valid, key, version, *, k, t, iters, metric,
              policy, init_centers=None) -> ModelState:
    """Second-level weighted k-means-- on a (padded) root -> ModelState.

    Pure function of its inputs — the one coordinator step every serving
    path (single-host, sharded, sync or async refresh) funnels through.
    ``init_centers`` warm-starts the Lloyd loop from the previous model's
    centers (the incremental-refresh path); None seeds as always.
    """
    sol = kmeans_minus_minus(
        pts, wts, valid, key, k=k, t=float(t), iters=iters, metric=metric,
        policy=policy, init_centers=init_centers)
    inlier = valid & ~sol.outlier
    threshold = jnp.where(inlier, sol.distances, -jnp.inf).max()
    threshold = jnp.maximum(threshold, 1e-12).astype(jnp.float32)
    trained = jnp.sum(wts * valid).astype(jnp.float32)
    return ModelState(
        centers=sol.centers, threshold=threshold,
        cost=sol.cost.astype(jnp.float32),
        version=jnp.int32(version),
        trained_weight=trained)


class ServingFrontEnd:
    """Micro-batched read path + double-buffered model state.

    Subclasses own the write path and provide ``_fit_closure(version)``: a
    zero-arg callable, with all inputs already snapshotted on the calling
    thread, that computes the next ``ModelState``.  The front end decides
    *when* it runs (inline for blocking refreshes, on a worker thread for
    async ones) and installs the result.

    Telemetry: per-request latency goes to the bounded
    ``serve.latency{topology=...}`` histogram in the process metrics
    registry (fixed buckets + recent-sample ring — a long-running service
    holds O(1) latency state, unlike the unbounded list this replaced);
    refresh phases are traced (``phase.refresh.gather|fit|install``); the
    last installed refresh is summarized in ``last_fit`` (:class:`FitStats`)
    with a live ``model.seconds_since_install`` staleness gauge.  Metrics
    are keyed per *topology*, so two services of the same class in one
    process share series — the registry is process-level, like any
    Prometheus exporter.
    """

    _topology = "serve"   # subclasses: "stream" | "sharded" | "oneshot"

    def __init__(self, cfg):
        self.cfg = cfg
        self.model: Optional[ModelState] = None
        # block-granular: (first_id, rows (b, d) f32, t_enqueue) per submit
        # call — request ids are consecutive within a block
        self._queue: deque = deque()
        self._queued_rows = 0
        self._next_id = 0
        self._lat = obs.histogram("serve.latency", topology=self._topology)
        self._worker: Optional[threading.Thread] = None
        self._worker_box: list = []
        self._backlog = False
        self._next_version = 0
        self._since_refresh = 0
        # incremental refresh: the root epoch(s) the serving model was fit
        # on (None = no epoch-tracked fit yet) and the epoch of the fit in
        # flight, handed from _fit_closure to _install
        self._last_fit_epoch = None
        self._pending_fit_epoch = None
        self.last_fit: Optional[FitStats] = None
        # (recorder, ctx, t_start) of the in-flight async refresh trace
        self._refresh_trace: tuple = (None, None, 0.0)
        self._monitors = obs.get_default_registry().monitors
        obs.gauge("model.seconds_since_install",
                  topology=self._topology).set_fn(self.seconds_since_install)

    # ------------------------------------------------------------ write path
    def _validate_points(self, points, weights):
        x = np.asarray(points, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self.cfg.dim:
            raise ValueError(f"expected (n, {self.cfg.dim}) points, "
                             f"got {x.shape}")
        w = None if weights is None else np.asarray(weights,
                                                    np.float32).reshape(-1)
        if w is not None and w.shape[0] != x.shape[0]:
            raise ValueError(f"{w.shape[0]} weights for {x.shape[0]} points")
        return x, w

    def _ingest_cadenced(self, x, w, sink) -> None:
        """Feed (x, w) to ``sink(chunk_x, chunk_w)`` in chunks bounded by
        the refresh cadence, so one huge call still refreshes on schedule
        rather than once at the end."""
        i, n = 0, x.shape[0]
        # one trace per ingest call: chunk + tree spans nest under it,
        # while any cadence refresh it triggers opens its own trace
        with obs.root_trace("ingest.request", topology=self._topology,
                            points=n):
            while i < n:
                take = min(self.cfg.refresh_every - self._since_refresh,
                           n - i)
                if take <= 0:   # e.g. restored with a smaller refresh_every
                    self._cadence_refresh()
                    continue
                with obs.trace("ingest", topology=self._topology):
                    sink(x[i:i + take], None if w is None else w[i:i + take])
                obs.counter("ingest.points",
                            topology=self._topology).inc(take)
                self._since_refresh += take
                i += take
                if self._since_refresh >= self.cfg.refresh_every:
                    self._cadence_refresh()

    def _cadence_refresh(self) -> None:
        self.refresh(blocking=not self.cfg.async_refresh)

    # ------------------------------------------------------------ refresh
    def _fit_closure(self, version: int) -> Optional[Callable[[], ModelState]]:
        """Snapshot the root and return the deferred fit — or None to skip
        (incremental refresh proved the installed model is already it)."""
        raise NotImplementedError

    def _root_records(self) -> int:
        """Live root records a refresh fits on (telemetry only)."""
        return 0

    def _timed_fit(self, fit: Callable[[], ModelState]):
        """Run the fit, fully materialized, under the fit-phase span.
        Returns (model, fit wall seconds)."""
        t0 = time.perf_counter()
        with obs.trace("refresh.fit", topology=self._topology):
            model = fit()
            jax.block_until_ready(model)
        return model, time.perf_counter() - t0

    def _install(self, model: ModelState, fit_s: float,
                 records: int) -> None:
        with obs.trace("refresh.install", topology=self._topology):
            self.model = model
            if self._pending_fit_epoch is not None:
                self._last_fit_epoch = self._pending_fit_epoch
                self._pending_fit_epoch = None
            self.last_fit = FitStats(
                version=int(model.version), records_folded=int(records),
                fit_s=float(fit_s), installed_at=time.perf_counter())
        obs.counter("refresh.count", topology=self._topology).inc()
        obs.counter("refresh.records_folded",
                    topology=self._topology).inc(int(records))
        # re-anchor the drift monitors to the newly installed model: the
        # healthy outlier fraction is the paper's z/n budget — the share
        # of the trained mass the fit was allowed to discard
        t = getattr(self.cfg, "t", None)
        if t is not None:
            self._monitors.set_outlier_budget(
                self._topology,
                float(t) / max(float(model.trained_weight), 1.0))
        self._monitors.set_staleness_source(self._topology,
                                            self.seconds_since_install)

    def refresh(self, *, blocking: bool = True) -> Optional[ModelState]:
        """Fit a new model on the current root.

        blocking=True (default) installs it before returning; False hands
        the fit to a worker thread (the root snapshot is still taken here,
        synchronously) and returns None — the model appears at the next
        ``poll_refresh``/``drain``/``ingest`` boundary.  An async refresh
        requested while one is already in flight is coalesced: it re-fires
        on the newest root as soon as the in-flight fit lands.  Either way
        the cadence counter restarts.

        With ``cfg.store.incremental_refresh`` and an unchanged root since
        the last fit, ``_fit_closure`` returns None and the refresh is
        *skipped*: the serving model — provably bit-identical to what a
        refit would install — stays, the version does not advance, and
        the skip is counted (``refresh.skipped``).
        """
        if blocking:
            self.join_refresh()
            self._next_version += 1
            with obs.root_trace("refresh", topology=self._topology,
                                version=self._next_version):
                with obs.trace("refresh.gather", topology=self._topology):
                    fit = self._fit_closure(self._next_version)
                    records = self._root_records()
                if fit is None:
                    self._skip_refresh()
                    return self.model
                model, fit_s = self._timed_fit(fit)
                self._install(model, fit_s, records)
            self._since_refresh = 0
            return model
        if self._worker is not None:
            self._backlog = True
        else:
            self._spawn_fit()
        self._since_refresh = 0
        return None

    def _end_refresh_trace(self, status: str = "ok",
                           error: Optional[BaseException] = None) -> None:
        """Record the async refresh trace's root span at install time."""
        rec, tctx, t_start = self._refresh_trace
        self._refresh_trace = (None, None, 0.0)
        if tctx is None:
            return
        attrs: dict = {"topology": self._topology}
        if error is not None:
            attrs["error"] = type(error).__name__
        rec.record_span("refresh", tctx, t0=t_start, t1=time.perf_counter(),
                        span_id=tctx.span_id, parent_id=None, status=status,
                        force=status == "error", attrs=attrs)

    def _skip_refresh(self) -> None:
        """Account an incremental-refresh skip: the root is unchanged, so
        the installed model already equals what a refit would produce."""
        self._next_version -= 1   # the skipped fit never claimed a version
        self._pending_fit_epoch = None
        obs.counter("refresh.skipped", topology=self._topology).inc()
        self._since_refresh = 0

    def _spawn_fit(self) -> None:
        self._next_version += 1
        # the refresh trace opens here and is carried explicitly across
        # the worker-thread boundary (gather on this thread, fit on the
        # worker, install + root span back on the polling thread)
        rec = obs.get_default_recorder()
        tctx = rec.new_trace()
        self._refresh_trace = (rec, tctx, time.perf_counter())
        with obs.use_context(tctx):
            with obs.trace("refresh.gather", topology=self._topology):
                fit = self._fit_closure(self._next_version)
                records = self._root_records()
        if fit is None:
            self._skip_refresh()
            self._end_refresh_trace("skipped")
            return
        box: list = []

        def run():
            with obs.use_context(tctx):
                try:
                    model, fit_s = self._timed_fit(fit)
                    box.append(("ok", model, fit_s, records))
                except BaseException as e:  # surfaced at poll/join
                    box.append(("err", e, 0.0, 0))

        self._worker_box = box
        self._worker = threading.Thread(
            target=run, name="stream-refresh", daemon=True)
        self._worker.start()

    def poll_refresh(self) -> bool:
        """Install a finished background fit, if any.  Returns True iff the
        serving model changed.  Re-raises a failed fit's exception here, on
        the caller's thread."""
        w = self._worker
        if w is None or w.is_alive():
            return False
        w.join()
        status, payload, fit_s, records = self._worker_box[0]
        self._worker, self._worker_box = None, []
        if status == "err":
            self._backlog = False   # don't respawn on top of a failed fit
            self._end_refresh_trace("error", payload)
            raise payload
        _, tctx, _ = self._refresh_trace
        with obs.use_context(tctx):
            self._install(payload, fit_s, records)
        self._end_refresh_trace()
        if self._backlog:
            self._backlog = False
            self._spawn_fit()
        return True

    def join_refresh(self) -> None:
        """Block until no refresh is in flight (incl. a coalesced backlog)."""
        while self._worker is not None:
            self._worker.join()
            self.poll_refresh()

    @property
    def refresh_in_flight(self) -> bool:
        return self._worker is not None

    # ------------------------------------------------------------ read path
    def submit(self, points) -> list[int]:
        """Enqueue query rows; returns their request ids."""
        # validate here, where the caller can handle it — a bad row that
        # reaches drain() would crash mid-batch after requests were
        # already dequeued
        x, _ = self._validate_points(points, None)
        now = time.perf_counter()
        with obs.trace("score.enqueue", topology=self._topology):
            n = x.shape[0]
            ids = list(range(self._next_id, self._next_id + n))
            self._queue.append((self._next_id, x, now))
            self._queued_rows += n
            self._next_id += n
        obs.counter("score.requests", topology=self._topology).inc(len(ids))
        return ids

    def discard_pending(self) -> int:
        """Drop every submitted-but-undrained request; returns the count.
        The serving scheduler calls this when a tick fails after
        ``submit`` — rows left queued would be drained by the *next* tick
        and misalign its results."""
        n = self._queued_rows
        self._queue.clear()
        self._queued_rows = 0
        return n

    def drain(self, max_requests: Optional[int] = None) -> list[QueryResult]:
        """Serve queued requests in micro-batches against the current model."""
        self.poll_refresh()
        if self.model is None:
            self.join_refresh()   # a first async refresh may be in flight
        if self.model is None:
            raise RuntimeError("no model yet — call refresh() first")
        cfg = self.cfg
        out: list[QueryResult] = []
        budget = self._queued_rows if max_requests is None else max_requests
        with obs.trace("score.drain", topology=self._topology):
            while self._queue and budget > 0:
                with obs.trace("score.batch", topology=self._topology):
                    take = min(cfg.micro_batch, self._queued_rows, budget)
                    xb = np.zeros((cfg.micro_batch, cfg.dim), np.float32)
                    # slice whole blocks into the pad buffer; a block that
                    # overhangs the batch is split, its tail re-queued
                    runs, filled = [], 0
                    while filled < take:
                        rid0, rows, t0 = self._queue[0]
                        r = min(rows.shape[0], take - filled)
                        xb[filled:filled + r] = rows[:r]
                        runs.append((rid0, r, t0))
                        if r == rows.shape[0]:
                            self._queue.popleft()
                        else:
                            self._queue[0] = (rid0 + r, rows[r:], t0)
                        filled += r
                    self._queued_rows -= take
                    budget -= take
                with obs.trace("score.fused", topology=self._topology):
                    dist, amin, score = _score_batch(
                        jnp.asarray(xb), self.model.centers,
                        self.model.threshold,
                        metric=cfg.metric, policy=cfg.policy)
                    jax.block_until_ready(dist)
                done = time.perf_counter()
                dist, amin, score = (np.asarray(a)
                                     for a in (dist, amin, score))
                i = 0
                for rid0, r, t0 in runs:
                    lat = done - t0
                    for j in range(i, i + r):
                        self._lat.observe(lat)
                        out.append(QueryResult(
                            request_id=rid0 + (j - i), center=int(amin[j]),
                            distance=float(dist[j]),
                            outlier_score=float(score[j]),
                            is_outlier=bool(score[j] > 1.0), latency_s=lat))
                    i += r
        if out:
            self._monitors.observe_scores(
                self._topology, len(out),
                sum(1 for r in out if r.is_outlier))
        return out

    def score(self, points) -> list[QueryResult]:
        """Synchronous convenience: submit + drain in one call."""
        self.submit(points)
        return self.drain()

    def latency_stats(self) -> dict:
        """Compat shim over the ``serve.latency`` histogram: same keys the
        pre-registry list-based implementation returned.  Percentiles are
        exact over the histogram's recent-sample ring (the full snapshot —
        buckets, p95, min/max — lives in ``obs.snapshot()``)."""
        if self._lat.count == 0:
            return {"count": 0, "p50_ms": float("nan"), "p99_ms": float("nan")}
        return {"count": int(self._lat.count),
                "p50_ms": float(self._lat.percentile(50)) * 1e3,
                "p99_ms": float(self._lat.percentile(99)) * 1e3}

    def reset_latency_stats(self) -> None:
        """Zero the ``serve.latency`` histogram (benchmark epochs)."""
        self._lat.reset()

    def seconds_since_install(self) -> Optional[float]:
        """Age of the serving model — None before the first refresh.  Also
        exported live as the ``model.seconds_since_install`` gauge."""
        if self.last_fit is None:
            return None
        return time.perf_counter() - self.last_fit.installed_at

    # ------------------------------------------------------------ checkpoint
    def _model_arrays(self) -> dict:
        cfg = self.cfg
        m = self.model
        if m is None:
            m = ModelState(jnp.zeros((cfg.k, cfg.dim), jnp.float32),
                           jnp.float32(0), jnp.float32(0), jnp.int32(0),
                           jnp.float32(0))
        return {"centers": m.centers, "threshold": m.threshold,
                "cost": m.cost, "version": m.version,
                "trained_weight": m.trained_weight}

    @staticmethod
    def _model_skeleton(cfg) -> dict:
        return {"centers": jnp.zeros((cfg.k, cfg.dim), jnp.float32),
                "threshold": jnp.float32(0), "cost": jnp.float32(0),
                "version": jnp.int32(0), "trained_weight": jnp.float32(0)}

    def _install_model_arrays(self, md: dict) -> None:
        if int(md["version"]) > 0:
            self.model = ModelState(
                centers=jnp.asarray(md["centers"], jnp.float32),
                threshold=jnp.asarray(md["threshold"], jnp.float32),
                cost=jnp.asarray(md["cost"], jnp.float32),
                version=jnp.asarray(md["version"], jnp.int32),
                trained_weight=jnp.asarray(md["trained_weight"], jnp.float32))
        self._next_version = int(md["version"])


class StreamService(ServingFrontEnd):
    _topology = "stream"

    def __init__(self, cfg: ServiceConfig, key: jax.Array | None = None):
        super().__init__(cfg)
        key = key if key is not None else jax.random.key(cfg.seed)
        kt, self._model_key = jax.random.split(key)
        self.tree = StreamTree(cfg.tree_config(), kt)

    def _root_records(self) -> int:
        return self.tree.num_records

    # ------------------------------------------------------------ write path
    def ingest(self, points, weights=None) -> None:
        self.poll_refresh()
        x, w = self._validate_points(points, weights)
        self._ingest_cadenced(x, w, self.tree.ingest)

    def _fit_closure(self, version: int):
        """Snapshot the tree root now; fit later (possibly on a worker).

        With ``cfg.store`` set, the fit key is derived from the tree's
        ``root_epoch`` instead of the model version: an unchanged root then
        provably refits to the bit-identical model, which licenses both the
        incremental-refresh *skip* (return None) and the opt-in warm start
        from the previous centers when little of the root changed.
        """
        cfg = self.cfg
        if self.tree.num_records == 0:
            raise RuntimeError("refresh() before any point was ingested")
        store, init = cfg.store, None
        if store is not None:
            # touch the incremental-refresh series so a store-configured
            # run always exposes them (at zero until the first skip)
            obs.counter("refresh.skipped", topology=self._topology).inc(0)
            obs.counter("refresh.warm_starts",
                        topology=self._topology).inc(0)
            epoch = self.tree.root_epoch
            if (store.incremental_refresh and self.model is not None
                    and epoch == self._last_fit_epoch):
                return None
            key = jax.random.fold_in(self._model_key, epoch)
            if (store.warm_start_frac > 0.0 and self.model is not None
                    and self._last_fit_epoch is not None):
                changed, total = self.tree.changed_weight_since(
                    self._last_fit_epoch)
                if changed <= store.warm_start_frac * total:
                    init = self.model.centers
                    obs.counter("refresh.warm_starts",
                                topology=self._topology).inc()
            self._pending_fit_epoch = epoch
        else:
            key = jax.random.fold_in(self._model_key, version)
        pts, wts, valid = self.tree.packed_root()
        return functools.partial(
            fit_model, jnp.asarray(pts), jnp.asarray(wts), jnp.asarray(valid),
            key, version, k=cfg.k, t=cfg.t, iters=cfg.second_iters,
            metric=cfg.metric, policy=cfg.policy, init_centers=init)

    # ------------------------------------------------------------ checkpoint
    def _state(self) -> dict:
        self.join_refresh()   # a half-fitted model must not race the snapshot
        return {
            "tree": self.tree.pack_state(),
            "model": self._model_arrays(),
            "counters": {
                "since_refresh": np.int64(self._since_refresh),
                "next_id": np.int64(self._next_id),
                "last_fit_epoch": np.int64(
                    -1 if self._last_fit_epoch is None
                    else self._last_fit_epoch),
                "model_key": np.asarray(jax.random.key_data(self._model_key)),
            },
        }

    def _skeleton(self) -> dict:
        cfg = self.cfg
        return {
            "tree": StreamTree.skeleton_state(cfg.tree_config()),
            "model": self._model_skeleton(cfg),
            "counters": {"since_refresh": np.int64(0), "next_id": np.int64(0),
                         "last_fit_epoch": np.int64(-1),
                         "model_key": np.zeros((2,), np.uint32)},
        }

    def save(self, manager: CheckpointManager, step: int, *,
             blocking: bool = True, extra_meta: Optional[dict] = None) -> None:
        """``extra_meta``: caller facts merged into the manifest meta (the
        ``Session`` facade embeds its serialized ``PipelineConfig`` here so
        a checkpoint is restorable without caller-side state)."""
        manager.save(step, self._state(), blocking=blocking,
                     meta={**(extra_meta or {}), "format": "stream-service-v1"})

    @classmethod
    def restore(cls, cfg: ServiceConfig, manager: CheckpointManager,
                step: int | None = None) -> "StreamService":
        fmt = manager.read_meta(step).get("format")
        if fmt is not None and fmt != "stream-service-v1":
            raise ValueError(
                f"checkpoint format {fmt!r} is not a single-host stream "
                f"checkpoint — restore it with the service that wrote it")
        svc = cls(cfg)
        state, _ = manager.restore(svc._skeleton(), step)
        svc.tree = StreamTree.from_state(cfg.tree_config(), state["tree"])
        svc._since_refresh = int(state["counters"]["since_refresh"])
        svc._next_id = int(state["counters"]["next_id"])
        lfe = int(state["counters"]["last_fit_epoch"])
        svc._last_fit_epoch = None if lfe < 0 else lfe
        svc._model_key = jax.random.wrap_key_data(
            jnp.asarray(state["counters"]["model_key"], jnp.uint32))
        svc._install_model_arrays(state["model"])
        return svc
