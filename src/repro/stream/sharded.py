"""Multi-host sharded streaming service: per-site trees + all_gather roots.

Topology (Algorithm 3 lifted onto the stream):

    site 0: raw points --> leaf buffer --> StreamTree (merge-and-reduce)
    site 1: raw points --> leaf buffer --> StreamTree          |
      ...                                                      | packed roots
    site s: raw points --> leaf buffer --> StreamTree          v
                                       one all_gather of fixed-shape roots
                                                               |
                       replicated weighted k-means--  <--------+
                                   (one global ModelState on every site)

Each site ingests its shard of the stream completely locally — leaf
reduction, merge-and-reduce, window eviction never leave the site.  On the
refresh cadence every site contributes its tree root, padded to one static
record capacity, to a single ``all_gather`` (the paper's one round of
communication, reusing the collective path of ``repro.core.distributed``),
and the second-level weighted k-means-- runs replicated on the union.
Because the second level sees *every* site's summaries, a global outlier
that looks locally unremarkable — e.g. a small cluster split evenly over
all sites — is still caught, exactly as in the one-shot Algorithm 3.

Execution paths, same math:

* host-simulated (default, any device count): the driver owns all ``s``
  trees, the gather is a concatenation in site order — bit-identical to
  what the collective delivers — and communication is *accounted* (records
  and bytes) rather than performed;
* ``use_shard_map=True`` with >= s devices: the gather + second level run
  as one ``shard_map`` program over the ``sites`` mesh axis
  (``repro.core.collective``), so on hardware the root exchange lowers to
  one ICI collective per leaf of the payload.

The read path (micro-batched scoring, latency accounting) and the
double-buffered async refresh are inherited from
``repro.stream.service.ServingFrontEnd``: queries keep scoring against the
previous model while the gathered refresh computes.

Communication cost per refresh is exactly the packed roots: s sites x
root_rows records x (4d + 4 + 1) bytes — reported per refresh in
``last_refresh`` and aggregated by ``benchmarks/stream_bench.py --sites``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core.collective import (gather_sites, gathered_bytes,
                                   payload_bytes, replicated_coordinator,
                                   sites_mesh)
from repro.core.distributed import local_budget
from repro.stream.service import (BaseServiceConfig, ModelState,
                                  ServingFrontEnd, fit_model)
from repro.stream.tree import StreamTree, TreeConfig
from repro.stream.weighted import _bucket


@dataclasses.dataclass(frozen=True)
class ShardedServiceConfig(BaseServiceConfig):
    """``BaseServiceConfig`` (all serving knobs, incl. ``refresh_every`` and
    ``window`` which are GLOBAL raw-point counts here) plus the multi-host
    topology fields only the sharded service has."""

    n_sites: int = 4
    site_budget: str = "full"        # "full": t per site (window/adversarial
    #                                  safe); "paper": 2t/s (cheaper roots)
    use_shard_map: bool = False      # real collective when devices allow

    def site_t(self) -> int:
        if self.site_budget == "full":
            return self.t
        if self.site_budget == "paper":
            return local_budget(self.t, self.n_sites, "random")
        raise ValueError(f"unknown site_budget {self.site_budget!r}")

    def site_tree_config(self) -> TreeConfig:
        w = self.window
        if w is not None:
            # each site sees ~1/s of the stream, so a site-local window of
            # ceil(W/s) tracks the last ~W global points
            w = -(-w // self.n_sites)
        return TreeConfig(
            dim=self.dim, k=self.k, t=self.site_t(),
            leaf_size=self.leaf_size, metric=self.metric,
            policy=self.policy, summarizer=self.summarizer, window=w,
            seed=self.seed, store=self.store)


class RefreshStats(NamedTuple):
    """Communication accounting for one gathered refresh."""
    version: int
    path: str                 # "shard_map" | "host-sim"
    root_rows: int            # static per-site packed-root rows
    per_site_records: tuple   # live (valid) records each site contributed
    comm_records: int         # total valid records gathered (paper's measure)
    comm_bytes: int           # total bytes one all_gather moves (padded)
    payload_bytes: int        # one site's padded contribution in bytes


class ShardedStreamService(ServingFrontEnd):
    """One ``StreamTree`` per site; one ``all_gather`` of roots per refresh.

    The driver process owns every site's tree (host-simulated sites); on a
    real deployment each host would run the write path for its own site and
    the identical replicated refresh — the state layout (per-site subtrees
    keyed by site id) and the fixed-shape root exchange are the same either
    way, which is what makes the host-sim path a faithful model of the
    multi-host one.
    """

    _topology = "sharded"

    def __init__(self, cfg: ShardedServiceConfig,
                 key: jax.Array | None = None):
        if cfg.n_sites < 1:
            raise ValueError(f"n_sites must be >= 1, got {cfg.n_sites}")
        super().__init__(cfg)
        key = key if key is not None else jax.random.key(cfg.seed)
        kt, self._model_key = jax.random.split(key)
        site_cfg = cfg.site_tree_config()
        self.trees = [StreamTree(site_cfg, jax.random.fold_in(kt, i))
                      for i in range(cfg.n_sites)]
        for i, tr in enumerate(self.trees):
            tr.obs_labels["site"] = i
        self._routed = 0             # round-robin cursor over sites
        self._fit_program = None     # cached shard_map program (all refreshes)
        self.last_refresh: Optional[RefreshStats] = None

    def _root_records(self) -> int:
        return self.num_records

    # ------------------------------------------------------------ write path
    def ingest(self, points, weights=None, site: int | None = None) -> None:
        """Feed raw points.

        ``site=None`` (dispatcher model): rows are interleaved round-robin
        over sites, continuing across calls, so every site sees an unbiased
        1/s sample of the stream.  ``site=i`` pins the whole batch to site i
        — the multi-host reality, where each host ingests only the traffic
        that reached it.
        """
        self.poll_refresh()
        cfg = self.cfg
        x, w = self._validate_points(points, weights)
        if site is not None:
            if not 0 <= site < cfg.n_sites:
                raise ValueError(
                    f"site {site} out of range [0, {cfg.n_sites})")
            sink = self.trees[site].ingest
        else:
            def sink(xc, wc):
                lanes = (self._routed + np.arange(xc.shape[0])) % cfg.n_sites
                for j in range(cfg.n_sites):
                    m = lanes == j
                    if m.any():
                        self.trees[j].ingest(xc[m],
                                             None if wc is None else wc[m])
                self._routed += xc.shape[0]
        self._ingest_cadenced(x, w, sink)

    # ------------------------------------------------------------ refresh fit
    def _gathered_program(self):
        """One shard_map program for every refresh: key/version flow in as
        arguments so the traced closure is stable and the compiled program
        is reused (it only recompiles when the packed-root rows grow)."""
        if self._fit_program is None:
            cfg = self.cfg

            def per_site(triple, key, version):
                p, w, v = triple   # each carries its site block: (1, rows, ..)
                gp, gw, gv = gather_sites((p[0], w[0], v[0]))
                return fit_model(gp, gw, gv, key, version, k=cfg.k, t=cfg.t,
                                 iters=cfg.second_iters, metric=cfg.metric,
                                 policy=cfg.policy)

            self._fit_program = replicated_coordinator(
                per_site, sites_mesh(cfg.n_sites), n_sharded=1)
        return self._fit_program

    def _fit_closure(self, version: int):
        """Snapshot every site's packed root now; gather + fit later.

        With ``cfg.store`` set the fit key derives from the per-site root
        epochs (monotone, so the tuple repeats iff no site's root moved):
        an unchanged gathered root refits bit-identically, licensing the
        incremental-refresh skip.  The opt-in warm start is host-sim only —
        threading previous centers through the cached shard_map program
        would retrace it for every refresh.
        """
        cfg = self.cfg
        recs = [tr.num_records for tr in self.trees]
        if sum(recs) == 0:
            raise RuntimeError("refresh() before any point was ingested")
        store, init = cfg.store, None
        epochs = tuple(tr.root_epoch for tr in self.trees)
        if store is not None:
            # touch the incremental-refresh series so a store-configured
            # run always exposes them (at zero until the first skip)
            obs.counter("refresh.skipped", topology=self._topology).inc(0)
            obs.counter("refresh.warm_starts",
                        topology=self._topology).inc(0)
            if (store.incremental_refresh and self.model is not None
                    and epochs == self._last_fit_epoch):
                return None
            self._pending_fit_epoch = epochs
        # one static row count for every site: the all_gather payload shape
        rows = _bucket(max(max(recs), 1))
        # per-site gather spans: inside refresh.gather, so one refresh
        # trace stitches every site's root snapshot under a single root
        roots = []
        for i, tr in enumerate(self.trees):
            with obs.trace("refresh.site_root", topology="sharded", site=i):
                roots.append(tr.packed_root(rows))
        pts = np.stack([r[0] for r in roots])          # (s, rows, d)
        wts = np.stack([r[1] for r in roots])          # (s, rows)
        val = np.stack([r[2] for r in roots])          # (s, rows)
        one_site = (roots[0][0], roots[0][1], roots[0][2])
        use_sm = cfg.use_shard_map and len(jax.devices()) >= cfg.n_sites
        site_bytes = payload_bytes(one_site)
        self.last_refresh = RefreshStats(
            version=version,
            path="shard_map" if use_sm else "host-sim",
            root_rows=rows,
            per_site_records=tuple(recs),
            comm_records=int(sum(recs)),
            comm_bytes=gathered_bytes(one_site, cfg.n_sites),
            payload_bytes=site_bytes)
        # every site ships the same padded root shape, hence equal bytes
        obs.record_comm(recs, [site_bytes] * cfg.n_sites, topology="sharded")
        if store is not None:
            # epoch-keyed: the same roots refit to the same model.  The sum
            # is strictly monotone in the per-site epochs, so it collides
            # only when every site's root is unchanged.
            key = jax.random.fold_in(self._model_key, sum(epochs))
            if (store.warm_start_frac > 0.0 and self.model is not None
                    and self._last_fit_epoch is not None and not use_sm):
                parts = [tr.changed_weight_since(e) for tr, e
                         in zip(self.trees, self._last_fit_epoch)]
                changed = sum(c for c, _ in parts)
                total = sum(t_ for _, t_ in parts)
                if changed <= store.warm_start_frac * total:
                    init = self.model.centers
                    obs.counter("refresh.warm_starts",
                                topology=self._topology).inc()
        else:
            key = jax.random.fold_in(self._model_key, version)

        if not use_sm:
            # host-sim: concatenation in site order is exactly what the
            # collective would deliver to every participant
            s, r, d = pts.shape
            return functools.partial(
                fit_model, jnp.asarray(pts.reshape(s * r, d)),
                jnp.asarray(wts.reshape(s * r)),
                jnp.asarray(val.reshape(s * r)), key, version, k=cfg.k,
                t=cfg.t, iters=cfg.second_iters, metric=cfg.metric,
                policy=cfg.policy, init_centers=init)

        program = self._gathered_program()
        triple = (jnp.asarray(pts), jnp.asarray(wts), jnp.asarray(val))
        return lambda: program(triple, key, np.int32(version))

    # ------------------------------------------------------------ aggregates
    @property
    def num_records(self) -> int:
        return sum(tr.num_records for tr in self.trees)

    @property
    def total_weight(self) -> float:
        return float(sum(tr.total_weight for tr in self.trees))

    @property
    def total_ingested(self) -> int:
        return sum(tr.total_ingested for tr in self.trees)

    # ------------------------------------------------------------ checkpoint
    def _state(self) -> dict:
        self.join_refresh()
        return {
            "sites": {f"site_{i:03d}": tr.pack_state()
                      for i, tr in enumerate(self.trees)},
            "model": self._model_arrays(),
            "counters": {
                "since_refresh": np.int64(self._since_refresh),
                "next_id": np.int64(self._next_id),
                "routed": np.int64(self._routed),
                "last_fit_epochs": (
                    np.full((self.cfg.n_sites,), -1, np.int64)
                    if self._last_fit_epoch is None
                    else np.asarray(self._last_fit_epoch, np.int64)),
                "model_key": np.asarray(jax.random.key_data(self._model_key)),
            },
        }

    def _skeleton(self) -> dict:
        cfg = self.cfg
        site_cfg = cfg.site_tree_config()
        return {
            "sites": {f"site_{i:03d}": StreamTree.skeleton_state(site_cfg)
                      for i in range(cfg.n_sites)},
            "model": self._model_skeleton(cfg),
            "counters": {"since_refresh": np.int64(0), "next_id": np.int64(0),
                         "routed": np.int64(0),
                         "last_fit_epochs": np.full((cfg.n_sites,), -1,
                                                    np.int64),
                         "model_key": np.zeros((2,), np.uint32)},
        }

    def save(self, manager: CheckpointManager, step: int, *,
             blocking: bool = True, extra_meta: Optional[dict] = None) -> None:
        """``extra_meta``: caller facts merged into the manifest meta (the
        ``Session`` facade embeds its serialized ``PipelineConfig`` here)."""
        manager.save(step, self._state(), blocking=blocking,
                     meta={**(extra_meta or {}),
                           "format": "sharded-stream-v1",
                           "n_sites": self.cfg.n_sites})

    @classmethod
    def restore(cls, cfg: ShardedServiceConfig, manager: CheckpointManager,
                step: int | None = None) -> "ShardedStreamService":
        meta = manager.read_meta(step)
        fmt = meta.get("format")
        if fmt is not None and fmt != "sharded-stream-v1":
            raise ValueError(
                f"checkpoint format {fmt!r} is not a sharded stream "
                f"checkpoint — restore it with the service that wrote it")
        ck_sites = meta.get("n_sites")
        if ck_sites is not None and ck_sites != cfg.n_sites:
            raise ValueError(
                f"checkpoint was written by {ck_sites} sites but the "
                f"restoring config has n_sites={cfg.n_sites}; per-site trees "
                f"cannot be re-sharded — restore with the writer's topology")
        svc = cls(cfg)
        state, _ = manager.restore(svc._skeleton(), step)
        site_cfg = cfg.site_tree_config()
        svc.trees = [
            StreamTree.from_state(site_cfg, state["sites"][f"site_{i:03d}"])
            for i in range(cfg.n_sites)]
        for i, tr in enumerate(svc.trees):
            tr.obs_labels["site"] = i
        svc._since_refresh = int(state["counters"]["since_refresh"])
        svc._next_id = int(state["counters"]["next_id"])
        svc._routed = int(state["counters"]["routed"])
        lfe = np.asarray(state["counters"]["last_fit_epochs"])
        svc._last_fit_epoch = (tuple(int(e) for e in lfe)
                               if (lfe >= 0).all() else None)
        svc._model_key = jax.random.wrap_key_data(
            jnp.asarray(state["counters"]["model_key"], jnp.uint32))
        svc._install_model_arrays(state["model"])
        return svc
