"""Streaming clustering service: merge-and-reduce over Summary-Outliers.

The repo's one-shot pipeline (Algorithms 1-3) clusters a fully materialized
dataset.  This package turns it into a continuously serving system:

    raw stream --> leaf buffer --> weighted summaries --> buffer tree
                                                             |
                 queries <-- fused score kernel <-- weighted k-means--

Why merge-and-reduce is correct here
------------------------------------
The paper's central object, the weighted summary Q of X, has two properties
that make it a composable (mergeable) sketch:

1. **Mass conservation.**  Each record (q, w_q) in Q carries the mass of
   the input records mapped to it (w_q = |sigma^{-1}(q)| in the unit case),
   so sum(weights(Q)) == |X| exactly — unions of summaries represent unions
   of data with no double counting, and Algorithm 3 already *relies* on
   this when it clusters the union of per-site summaries.

2. **Telescoping information loss.**  ``weighted_summary_outliers`` treats
   a record of weight w as w coincident points (sampling ∝ weight, ball
   capture by weight mass), so re-summarizing Q1 u Q2 is Algorithm 1 run on
   a perturbed version of X1 u X2 in which every point has been moved to
   its level-below representative.  By the triangle inequality the loss of
   the composed map is at most loss(level below) + loss(new level); L
   levels of merging cost at most an O(L) (O(log n)) factor over the
   one-shot loss — the standard merge-and-reduce argument (Guha et al.,
   *Distributed Partial Clustering*), and each level keeps the full
   outlier budget t so up to t true outliers survive as candidates all the
   way to the root.

The root of the tree is therefore exactly what the paper's coordinator
sees in the distributed setting — a union of weighted summaries — and the
same second-level weighted k-means-- yields the serving model.

Multi-host topology (sites -> trees -> all_gather roots -> global model)
------------------------------------------------------------------------
``ShardedStreamService`` runs the same pipeline over a mesh axis
``sites``: each DP shard owns its own ``StreamTree`` (leaf ingest,
merge-and-reduce, window eviction all stay site-local), and on the refresh
cadence every site contributes its root, padded to one fixed record
capacity, to a single ``all_gather`` — the paper's one round of
communication, shared with ``repro.core.distributed`` through
``repro.core.collective``.  The second-level weighted k-means-- then runs
replicated on the union, so one global model serves every site and global
outliers that are locally unremarkable are still caught (Algorithm 3's
guarantee, kept under streaming).  Communication per refresh is exactly
the packed roots; ``RefreshStats`` reports it in records and bytes.

Model refresh is double-buffered (``async_refresh=True``): the next model
fits on a worker thread from a root snapshot while ingest continues and
queries score against the previous model — same ModelState bits, later
install.

Modules: ``weighted`` (weighted Algorithm 1 + merge/reduce primitives),
``tree`` (buffer tree, sliding-window eviction, checkpointable state),
``service`` (micro-batched scoring front end, double-buffered refresh +
CheckpointManager glue), ``sharded`` (per-site trees + gathered refresh).
The summary algorithm itself is pluggable: every config takes a
``summarizer=SummarizerPolicy(...)`` selecting a ``repro.summarize``
registry entry (default: the paper's Algorithm 1, bit-identical to the
pre-registry behavior).

Remaining follow-on tracked in ROADMAP.md: validate Pallas scoring on
real TPU hardware.
"""
from repro.stream.weighted import (  # noqa: F401
    WeightedSummary, merge_summaries, resummarize, weighted_summary_outliers,
)
from repro.stream.tree import StreamTree, TreeConfig, record_cap  # noqa: F401
from repro.stream.service import (  # noqa: F401
    BaseServiceConfig, ModelState, QueryResult, ServiceConfig,
    ServingFrontEnd, StreamService, fit_model,
)
from repro.stream.sharded import (  # noqa: F401
    RefreshStats, ShardedServiceConfig, ShardedStreamService,
)
