"""Merge-and-reduce buffer tree over weighted summaries.

Ingest path: raw points accumulate in a leaf buffer; every ``leaf_size``
points the buffer is reduced to a level-0 weighted summary (by default
Algorithm 1 at full outlier budget t; ``TreeConfig.summarizer`` selects
any registered ``repro.summarize`` algorithm for both the leaf reduction
and the merge-reduce step).  Whenever two summaries share a level, the
older pair is merged (concatenate) and reduced (the summarizer re-run on
the union) into one level-(l+1) summary — the classic binary-counter
coreset tree, so a stream of n points holds at most O(log(n / leaf_size))
live summaries of O(m + 8t) records each: O(m log n) memory total.
Mass conservation — the summarize-registry contract — is exactly what
makes any registered summarizer safe to slot in here.

Sliding window (optional): with ``window=W`` set, merges are capped so no
summary spans more than max(leaf_size, W // 4) raw points, and summaries
whose newest point has fallen out of the window are evicted whole.  The
model then tracks the last ~W points with eviction granularity <= W/4.

Tiered storage (optional): with ``TreeConfig.store`` set to a tiered
:class:`repro.store.StoreSpec`, summaries beyond the hot budget spill to
disk through :class:`repro.store.TieredStore` and are demand-paged back
exactly when a merge, ``root()`` or ``pack_state()`` touches them — the
root stays bit-identical to the all-resident tree, only residency moves.
The tree also tracks a monotone ``root_epoch`` (bumped on every mutation
that changes ``root()``) plus per-node creation epochs, which is what
lets the serving layer skip or warm-start provably-redundant refreshes.

Checkpointing: the tree's state packs into a *fixed-shape* pytree of
arrays (``pack_state``/``from_state``), so ``CheckpointManager`` can
save/restore it across process restarts with its usual shape-checked
manifest — no pickling.  Spilled summaries are paged in for the pack (a
checkpoint is self-contained) and the restored tree re-applies its hot
budget.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.dispatch import KernelPolicy, get_default_policy
from repro.store.spec import StoreSpec
from repro.stream.weighted import WeightedSummary, _bucket

if TYPE_CHECKING:   # runtime import is lazy: repro.store.tiered imports
    from repro.store.tiered import TieredStore   # this module's package
from repro.summarize.base import (SummarizerPolicy, get_default_summarizer,
                                  record_bound, reduce_summaries, summarize)


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    dim: int
    k: int
    t: int
    leaf_size: int = 2048
    alpha: float = 2.0
    beta: float = 0.45
    metric: str = "l2sq"
    # None = capture the process default (set_default_policy) at construction
    policy: Optional[KernelPolicy] = None
    # None = capture the process default (set_default_summarizer); the
    # default "auto" resolves to the paper summarizer — bit-identical to
    # the pre-registry weighted_summary_outliers/resummarize calls
    summarizer: Optional[SummarizerPolicy] = None
    window: Optional[int] = None     # raw points; None = full stream
    max_summaries: int = 64          # checkpoint slots; force-merge beyond
    max_points: int = 2 ** 34        # stream-length bound for the record cap
    seed: int = 0
    # None = everything resident (the classic in-memory tree); a tiered
    # StoreSpec spills cold levels to disk behind the same root
    store: Optional[StoreSpec] = None

    def __post_init__(self):
        if self.policy is None:
            object.__setattr__(self, "policy", get_default_policy())
        if self.summarizer is None:
            object.__setattr__(self, "summarizer", get_default_summarizer())


def record_cap(cfg: TreeConfig) -> int:
    """Static per-summary record capacity for checkpoint packing.

    Delegates to the selected summarizer's registered ``record_bound`` —
    for the paper summarizer: centers <= rounds * m where rounds depends
    only on the mass (<= the mass bound below) and candidates carry >= 1
    mass each in tree use (raw points enter with unit weight), so <= 8t.

    With a sliding window the mass bound tightens: no summary can carry
    more mass than the live stream, which eviction keeps under
    ``window + merge-span + flush slack`` (unit weights).  The force-merge
    loop in ``_compact`` ignores the span cap, so the tightening only
    applies when the checkpoint slot budget provably keeps force-merge
    from firing (every node carries >= leaf_size mass, so the node count
    never exceeds live_mass // leaf_size).  Non-windowed configs keep the
    ``cfg.max_points`` stream-length bound unchanged.
    """
    max_points = cfg.max_points
    if cfg.window is not None:
        span = max(cfg.leaf_size, cfg.window // 4)
        live = cfg.window + span + 2 * cfg.leaf_size
        if live // cfg.leaf_size + 1 <= cfg.max_summaries:
            max_points = min(max_points, live)
    return record_bound(cfg.summarizer, metric=cfg.metric, k=cfg.k, t=cfg.t,
                        alpha=cfg.alpha, beta=cfg.beta,
                        max_points=max_points, leaf_size=cfg.leaf_size)


@dataclasses.dataclass
class TreeNode:
    summary: Optional[WeightedSummary]   # None while spilled to the store
    level: int
    min_seq: int    # [min_seq, max_seq): raw-point sequence ids spanned
    max_seq: int
    count: int      # raw points spanned
    # metadata that must survive a spill (the store rebuilds the summary
    # from these + the on-disk blob) and feed refresh reuse decisions
    epoch: int = 0           # tree root_epoch when this node was created
    n_records: int = 0       # summary rows (== summary.points.shape[0])
    nbytes: int = 0          # resident payload bytes of the summary
    weight: float = 0.0      # summary mass (WeightedSummary.total_weight)
    spill_step: Optional[int] = None   # store step id while spilled


class StreamTree:
    """Mergeable summary tree; all state numpy-side, distance loops jitted."""

    def __init__(self, cfg: TreeConfig, key: jax.Array | None = None):
        self.cfg = cfg
        self.key = key if key is not None else jax.random.key(cfg.seed)
        self.nodes: List[TreeNode] = []      # chronological order
        self._buf = np.zeros((cfg.leaf_size, cfg.dim), np.float32)
        self._buf_w = np.zeros((cfg.leaf_size,), np.float32)
        self._buf_n = 0
        self._flushed = 0                    # raw points reduced into leaves
        self.total_ingested = 0
        self._cap = record_cap(cfg)
        self._epoch = 0                      # bumped whenever root() changes
        # the spill tier is created lazily, on the first budget enforcement:
        # skeleton/throwaway trees never touch disk
        self._store: Optional[TieredStore] = None
        # telemetry labels; owners may add context after construction (the
        # sharded service tags each site's tree with its site id)
        self.obs_labels: dict = {"summarizer": cfg.summarizer.name}

    # ------------------------------------------------------------ ingest
    def ingest(self, points, weights=None) -> None:
        x = np.asarray(points, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        if x.shape[1] != self.cfg.dim:
            raise ValueError(f"expected dim {self.cfg.dim}, got {x.shape[1]}")
        w = (np.ones((x.shape[0],), np.float32) if weights is None
             else np.asarray(weights, np.float32).reshape(-1))
        if w.shape[0] != x.shape[0]:
            raise ValueError(
                f"{w.shape[0]} weights for {x.shape[0]} points — a silent "
                f"truncation here would break mass conservation")
        if x.shape[0]:
            self._epoch += 1   # buffered rows are part of root()
        i = 0
        while i < x.shape[0]:
            take = min(self.cfg.leaf_size - self._buf_n, x.shape[0] - i)
            self._buf[self._buf_n:self._buf_n + take] = x[i:i + take]
            self._buf_w[self._buf_n:self._buf_n + take] = w[i:i + take]
            self._buf_n += take
            self.total_ingested += take
            i += take
            if self._buf_n == self.cfg.leaf_size:
                self._flush_leaf()

    def _next_key(self) -> jax.Array:
        self.key, sk = jax.random.split(self.key)
        return sk

    def _flush_leaf(self) -> None:
        cfg = self.cfg
        with obs.trace("ingest.leaf_flush", **self.obs_labels):
            summ = summarize(
                self._buf[:self._buf_n], self._buf_w[:self._buf_n],
                self._next_key(), k=cfg.k, t=cfg.t, alpha=cfg.alpha,
                beta=cfg.beta, metric=cfg.metric, policy=cfg.summarizer,
                kernel_policy=cfg.policy)
        obs.counter("tree.leaf_flushes", **self.obs_labels).inc()
        self._check_cap(summ)
        self._epoch += 1
        self.nodes.append(self._make_node(
            summ, level=0, min_seq=self._flushed,
            max_seq=self._flushed + self._buf_n, count=self._buf_n))
        self._flushed += self._buf_n
        self._buf_n = 0
        self._evict()
        self._compact()
        self._enforce_store()
        self._update_gauges()

    def _update_gauges(self) -> None:
        reg = obs.get_default_registry()
        if not reg.enabled:
            return
        reg.gauge("tree.records", **self.obs_labels).set(self.num_records)
        reg.gauge("tree.summaries", **self.obs_labels).set(len(self.nodes))
        reg.gauge("tree.max_level", **self.obs_labels).set(
            max((nd.level for nd in self.nodes), default=0))

    def _check_cap(self, summ: WeightedSummary) -> None:
        if summ.points.shape[0] > self._cap:
            raise RuntimeError(
                f"summary has {summ.points.shape[0]} records > static cap "
                f"{self._cap}; raise TreeConfig.max_points or check weights "
                f"(sub-unit weights break the 8t candidate-count bound)")

    # ------------------------------------------------------------ store
    def _make_node(self, summ: WeightedSummary, *, level: int, min_seq: int,
                   max_seq: int, count: int) -> TreeNode:
        from repro.store.tiered import summary_nbytes
        return TreeNode(
            summary=summ, level=level, min_seq=min_seq, max_seq=max_seq,
            count=count, epoch=self._epoch,
            n_records=int(summ.points.shape[0]),
            nbytes=summary_nbytes(summ),
            weight=float(summ.total_weight))

    @property
    def store(self) -> Optional[TieredStore]:
        """The spill tier, created on first use (None until then, and
        forever when the config has no tiered store)."""
        cfg = self.cfg
        if self._store is None and cfg.store is not None and cfg.store.tiered:
            from repro.store.tiered import TieredStore
            self._store = TieredStore(cfg.store, dim=cfg.dim,
                                      labels=self.obs_labels)
        return self._store

    def _enforce_store(self) -> None:
        if self.cfg.store is not None and self.cfg.store.tiered:
            self.store.enforce(self.nodes)

    def _node_summary(self, nd: TreeNode) -> WeightedSummary:
        """The node's summary, demand-paged from the spill tier if cold
        (transient — the node stays cold; see TieredStore.page_in)."""
        if nd.summary is not None:
            return nd.summary
        return self._store.page_in(nd)

    def _discard_node(self, nd: TreeNode) -> None:
        if nd.spill_step is not None:
            self._store.discard(nd)

    @property
    def root_epoch(self) -> int:
        """Monotone counter, bumped on every mutation that changes
        ``root()`` (ingest, flush, merge, evict).  Equal epochs imply an
        identical root, which is what licenses skipping a refresh."""
        return self._epoch

    def level_epochs(self) -> dict[int, int]:
        """Per-level dirty epoch: the newest node-creation epoch at each
        live level (diagnostics for the incremental-refresh decisions)."""
        out: dict[int, int] = {}
        for nd in self.nodes:
            out[nd.level] = max(out.get(nd.level, 0), nd.epoch)
        return out

    def changed_weight_since(self, epoch: int) -> tuple[float, float]:
        """(mass created after ``epoch``, total live mass) — from node
        metadata + the buffer, no page-ins.  The serving layer compares
        the ratio against ``StoreSpec.warm_start_frac``."""
        buf = float(self._buf_w[:self._buf_n].sum()) if self._buf_n else 0.0
        changed = buf + sum(nd.weight for nd in self.nodes
                            if nd.epoch > epoch)
        total = buf + sum(nd.weight for nd in self.nodes)
        return changed, total

    # ------------------------------------------------------------ merge
    def _evict(self) -> None:
        if self.cfg.window is None:
            return
        cutoff = self.total_ingested - self.cfg.window
        keep = [nd for nd in self.nodes if nd.max_seq > cutoff]
        if len(keep) < len(self.nodes):
            obs.counter("tree.evictions",
                        **self.obs_labels).inc(len(self.nodes) - len(keep))
            self._epoch += 1
            for nd in self.nodes:
                if nd.max_seq <= cutoff:
                    self._discard_node(nd)   # spilled blob leaves with it
        self.nodes = keep

    def _merge_pair(self, i: int, j: int) -> None:
        a, b = self.nodes[i], self.nodes[j]
        cfg = self.cfg
        with obs.trace("ingest.merge_reduce", **self.obs_labels):
            # demand-page spilled operands exactly here, where the merge
            # actually consumes them
            summ = reduce_summaries(
                [self._node_summary(a), self._node_summary(b)],
                self._next_key(), k=cfg.k, t=cfg.t,
                alpha=cfg.alpha, beta=cfg.beta, metric=cfg.metric,
                policy=cfg.summarizer, kernel_policy=cfg.policy)
        obs.counter("tree.merges", **self.obs_labels).inc()
        self._check_cap(summ)
        self._epoch += 1
        self.nodes[i] = self._make_node(
            summ, level=max(a.level, b.level) + 1,
            min_seq=min(a.min_seq, b.min_seq),
            max_seq=max(a.max_seq, b.max_seq),
            count=a.count + b.count)
        del self.nodes[j]
        self._discard_node(a)
        self._discard_node(b)

    def _max_span(self) -> Optional[int]:
        if self.cfg.window is None:
            return None
        return max(self.cfg.leaf_size, self.cfg.window // 4)

    def _compact(self) -> None:
        span = self._max_span()
        while True:
            by_level: dict[int, list[int]] = {}
            for i, nd in enumerate(self.nodes):
                by_level.setdefault(nd.level, []).append(i)
            pair = None
            for lvl in sorted(by_level):
                ids = by_level[lvl]
                if len(ids) < 2:
                    continue
                i, j = ids[0], ids[1]   # oldest two of this level
                if span is not None and \
                        self.nodes[i].count + self.nodes[j].count > span:
                    continue
                pair = (i, j)
                break
            if pair is None:
                break
            self._merge_pair(*pair)
        # checkpoint slots are finite: collapse the two oldest summaries
        # regardless of level rather than overflow.
        while len(self.nodes) > self.cfg.max_summaries:
            self._merge_pair(0, 1)

    # ------------------------------------------------------------ read
    def root(self, include_buffer: bool = True):
        """Union of all live summaries (+ the unreduced buffer as unit-ish
        weighted raw records): (points (s,d), weights (s,), is_candidate).
        Spilled summaries are paged in transiently — the concatenation is
        bit-identical to the all-resident tree's."""
        summs = [self._node_summary(nd) for nd in self.nodes]
        pts = [s.points for s in summs]
        wts = [s.weights for s in summs]
        cand = [s.is_candidate for s in summs]
        if include_buffer and self._buf_n:
            pts.append(self._buf[:self._buf_n].copy())
            wts.append(self._buf_w[:self._buf_n].copy())
            cand.append(np.zeros((self._buf_n,), bool))
        if not pts:
            return (np.zeros((0, self.cfg.dim), np.float32),
                    np.zeros((0,), np.float32), np.zeros((0,), bool))
        return (np.concatenate(pts), np.concatenate(wts),
                np.concatenate(cand))

    def packed_root(self, rows: int | None = None,
                    include_buffer: bool = True):
        """``root()`` padded to a static row count for collectives.

        Returns ``(points (rows, d) f32, weights (rows,) f32,
        valid (rows,) bool)`` with zero rows / zero weight / False beyond the
        live records — exactly the (points, weights, valid) triple the
        second-level ``kmeans_minus_minus`` consumes, and a fixed shape every
        site can contribute to one ``all_gather``.  ``rows`` defaults to the
        shared power-of-two bucket of the live record count (the same
        bucketing the scoring path uses, so shapes — and therefore compiled
        programs — are reused across refreshes).
        """
        pts, wts, _ = self.root(include_buffer)
        s = pts.shape[0]
        rows = _bucket(max(s, 1)) if rows is None else rows
        if s > rows:
            raise ValueError(f"{s} live records exceed packed capacity {rows}")
        out_p = np.zeros((rows, self.cfg.dim), np.float32)
        out_w = np.zeros((rows,), np.float32)
        out_v = np.zeros((rows,), bool)
        out_p[:s] = pts
        out_w[:s] = wts
        out_v[:s] = True
        return out_p, out_w, out_v

    @property
    def total_weight(self) -> float:
        _, w, _ = self.root()
        return float(w.sum())

    @property
    def num_records(self) -> int:
        # node metadata, not the summaries: must not fault spilled nodes in
        return sum(nd.n_records for nd in self.nodes) + self._buf_n

    # ------------------------------------------------------------ state
    def pack_state(self) -> dict:
        """Fixed-shape pytree of the full tree state (CheckpointManager-safe)."""
        cfg, cap, S = self.cfg, self._cap, self.cfg.max_summaries
        if len(self.nodes) > S:
            raise RuntimeError(f"{len(self.nodes)} summaries > {S} slots")
        pts = np.zeros((S, cap, cfg.dim), np.float32)
        wts = np.zeros((S, cap), np.float32)
        cand = np.zeros((S, cap), bool)
        valid = np.zeros((S, cap), bool)
        level = np.full((S,), -1, np.int32)
        min_seq = np.zeros((S,), np.int64)
        max_seq = np.zeros((S,), np.int64)
        count = np.zeros((S,), np.int64)
        node_epoch = np.zeros((S,), np.int64)
        for i, nd in enumerate(self.nodes):
            summ = self._node_summary(nd)   # checkpoints are self-contained
            s = summ.points.shape[0]
            pts[i, :s] = summ.points
            wts[i, :s] = summ.weights
            cand[i, :s] = summ.is_candidate
            valid[i, :s] = True
            level[i] = nd.level
            min_seq[i], max_seq[i], count[i] = nd.min_seq, nd.max_seq, nd.count
            node_epoch[i] = nd.epoch
        return {
            "points": pts, "weights": wts, "is_candidate": cand,
            "valid": valid, "level": level, "min_seq": min_seq,
            "max_seq": max_seq, "count": count, "node_epoch": node_epoch,
            "root_epoch": np.int64(self._epoch),
            "buffer": self._buf.copy(), "buffer_w": self._buf_w.copy(),
            "buffer_n": np.int64(self._buf_n),
            "flushed": np.int64(self._flushed),
            "total_ingested": np.int64(self.total_ingested),
            "key_data": np.asarray(jax.random.key_data(self.key)),
        }

    @classmethod
    def skeleton_state(cls, cfg: TreeConfig) -> dict:
        """Zero state with the shapes pack_state produces — the ``tree_like``
        argument CheckpointManager.restore needs."""
        return cls(cfg).pack_state()

    @classmethod
    def from_state(cls, cfg: TreeConfig, state: dict) -> "StreamTree":
        tree = cls(cfg)
        g = {k: np.asarray(v) for k, v in state.items()}
        tree.key = jax.random.wrap_key_data(
            jnp.asarray(g["key_data"], jnp.uint32))
        tree._buf = g["buffer"].astype(np.float32).copy()
        tree._buf_w = g["buffer_w"].astype(np.float32).copy()
        tree._buf_n = int(g["buffer_n"])
        tree._flushed = int(g["flushed"])
        tree.total_ingested = int(g["total_ingested"])
        tree._epoch = int(g["root_epoch"])
        for i in range(cfg.max_summaries):
            if int(g["level"][i]) < 0:
                continue
            v = g["valid"][i]
            summ = WeightedSummary(
                points=g["points"][i][v].astype(np.float32),
                weights=g["weights"][i][v].astype(np.float32),
                is_candidate=g["is_candidate"][i][v].astype(bool),
                n_rounds=0,
                total_weight=float(g["weights"][i][v].sum()))
            nd = tree._make_node(
                summ, level=int(g["level"][i]),
                min_seq=int(g["min_seq"][i]), max_seq=int(g["max_seq"][i]),
                count=int(g["count"][i]))
            nd.epoch = int(g["node_epoch"][i])
            tree.nodes.append(nd)
        tree._enforce_store()   # restored nodes re-obey the hot budget
        return tree
