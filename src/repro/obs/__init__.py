"""One telemetry plane for the whole pipeline.

``repro.obs`` is where every layer — serving front end, stream tree,
sharded refresh, kernel dispatch, checkpointing — reports what it did:
counters, gauges, latency histograms with exact percentiles, and
``trace(phase)`` wall-time spans, all snapshot-able to one plain dict
(``Session.stats()`` at the front door) and renderable as Prometheus
text (:func:`render_prometheus`).

Disable process-wide with ``REPRO_METRICS=0`` or
:func:`set_metrics_enabled`; instrumentation is timers and tallies only,
so results are bit-identical either way.
"""
from repro.obs.registry import (DEFAULT_BUCKETS, DEFAULT_RING,
                                SNAPSHOT_VERSION, Counter, Gauge, Histogram,
                                MetricsRegistry, counter, gauge,
                                get_default_registry, histogram, metric_key,
                                metrics_enabled, record_comm,
                                set_default_registry, set_metrics_enabled,
                                snapshot, split_key, trace, using_registry)
from repro.obs.prom import render_prometheus

__all__ = [
    "DEFAULT_BUCKETS",
    "DEFAULT_RING",
    "SNAPSHOT_VERSION",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "counter",
    "gauge",
    "get_default_registry",
    "histogram",
    "metric_key",
    "metrics_enabled",
    "record_comm",
    "render_prometheus",
    "set_default_registry",
    "set_metrics_enabled",
    "snapshot",
    "split_key",
    "trace",
    "using_registry",
]
