"""One telemetry plane for the whole pipeline.

``repro.obs`` is where every layer — serving front end, stream tree,
sharded refresh, kernel dispatch, checkpointing — reports what it did:
counters, gauges, latency histograms with exact percentiles, and
``trace(phase)`` wall-time spans, all snapshot-able to one plain dict
(``Session.stats()`` at the front door) and renderable as Prometheus
text (:func:`render_prometheus`).

Since schema v2 the plane is *explainable*, not just aggregate:
``trace(phase)`` spans executed under an active trace also land as
structured ``trace_id``/``span_id``/``parent_id`` records in a bounded
:class:`FlightRecorder` ring (export with :func:`dump_trace` — Chrome
trace-event JSON or JSON-lines), and a :class:`~repro.obs.monitors.\
MonitorHub` of online monitors (outlier-rate drift vs the z/n budget,
model staleness, shed burn) emits typed ``Alert`` records into
``snapshot()["alerts"]``.

Disable metrics process-wide with ``REPRO_METRICS=0`` or
:func:`set_metrics_enabled`, tracing with ``REPRO_TRACE=0`` or
:func:`set_tracing_enabled`; instrumentation is timers and tallies only,
so results are bit-identical either way.
"""
from repro.obs.registry import (DEFAULT_BUCKETS, DEFAULT_RING,
                                SNAPSHOT_VERSION, Counter, Gauge, Histogram,
                                MetricsRegistry, counter, gauge,
                                get_default_registry, histogram, metric_key,
                                metrics_enabled, record_comm,
                                set_default_registry, set_metrics_enabled,
                                snapshot, split_key, using_registry)
# ``trace`` is the combined histogram + flight-recorder span (degrades
# to histogram-only outside an active sampled trace).
from repro.obs.tracing import (FlightRecorder, SpanContext, TraceSpec,
                               apply_trace_spec, configure_tracing,
                               current_context, dump_trace, export_chrome,
                               export_jsonl, get_default_recorder,
                               root_trace, set_tracing_enabled, trace,
                               tracing_enabled, use_context)
from repro.obs.monitors import (Alert, MonitorHub, OutlierRateMonitor,
                                ShedRateMonitor, StalenessMonitor)
from repro.obs.prom import render_prometheus

__all__ = [
    "DEFAULT_BUCKETS",
    "DEFAULT_RING",
    "SNAPSHOT_VERSION",
    "Alert",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MonitorHub",
    "OutlierRateMonitor",
    "ShedRateMonitor",
    "SpanContext",
    "StalenessMonitor",
    "TraceSpec",
    "apply_trace_spec",
    "configure_tracing",
    "counter",
    "current_context",
    "dump_trace",
    "export_chrome",
    "export_jsonl",
    "gauge",
    "get_default_recorder",
    "get_default_registry",
    "histogram",
    "metric_key",
    "metrics_enabled",
    "record_comm",
    "render_prometheus",
    "root_trace",
    "set_default_registry",
    "set_metrics_enabled",
    "set_tracing_enabled",
    "snapshot",
    "split_key",
    "trace",
    "tracing_enabled",
    "use_context",
    "using_registry",
]
