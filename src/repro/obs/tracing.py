"""Request-level tracing: structured spans in a bounded flight recorder.

The metrics registry (:mod:`repro.obs.registry`) answers *how much* —
aggregate counters and latency histograms.  This module answers *where
it went*: every instrumented phase can also record a structured span
(``trace_id`` / ``span_id`` / ``parent_id`` + wall-clock bounds) into an
in-memory **flight recorder** — a fixed-size ring that is cheap enough
to leave on in production and can be dumped after the fact as Chrome
trace-event JSON (loadable in Perfetto / ``chrome://tracing``) or
JSON-lines.

Design points:

- **Zero-alloc when disabled.** ``FlightRecorder.new_trace()`` returns
  ``None`` without taking a lock when tracing is off; every recording
  helper treats a ``None`` context as "do nothing".
- **Head sampling.** The keep/drop decision is made once, at the trace
  root, by a seeded ``random.Random`` — deterministic under test.
  Children inherit the decision through the propagated context.
- **Always-sample on error.** ``record_span(..., force=True)`` and
  ``record_event(..., force=True)`` bypass the sampling decision so
  shed rejections and worker-tick failures are always reconstructable.
- **Cross-thread propagation.** The current span context lives in a
  ``contextvars.ContextVar``; :func:`use_context` carries it explicitly
  across thread boundaries (the serving scheduler installs the client
  ticket's context around the worker tick so one request stitches
  admission -> queue wait -> tick -> fused score -> drain into ONE
  trace).

The module-level :func:`trace` is a drop-in upgrade of the registry's
histogram-only span: it observes the same ``phase.*`` histogram *and*
records a flight span when called under an active sampled trace, so
every existing ``obs.trace(...)`` call site participates in structured
tracing with no per-site changes.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
import os
import random
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, Iterator, List, NamedTuple, Optional

from repro.obs import registry as _registry

__all__ = [
    "SpanContext",
    "FlightRecorder",
    "TraceSpec",
    "trace",
    "root_trace",
    "use_context",
    "current_context",
    "get_default_recorder",
    "configure_tracing",
    "set_tracing_enabled",
    "tracing_enabled",
    "export_chrome",
    "export_jsonl",
    "dump_trace",
]

_DEFAULT_RING = 65536


class SpanContext(NamedTuple):
    """Propagated identity of the active span within a trace.

    ``sampled`` is the head-sampling decision made at the trace root;
    children never re-roll it.
    """

    trace_id: int
    span_id: int
    sampled: bool


_current: contextvars.ContextVar[Optional[SpanContext]] = \
    contextvars.ContextVar("repro_trace_ctx", default=None)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Config-artifact knobs for the flight recorder (``tracing:``)."""

    enabled: bool = True
    sample_rate: float = 1.0
    ring: int = _DEFAULT_RING
    seed: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= float(self.sample_rate) <= 1.0:
            raise ValueError(
                f"sample_rate must be in [0, 1], got {self.sample_rate}")
        if int(self.ring) < 1:
            raise ValueError(f"ring must be >= 1, got {self.ring}")

    def to_dict(self) -> Dict[str, Any]:
        return {
            "enabled": bool(self.enabled),
            "sample_rate": float(self.sample_rate),
            "ring": int(self.ring),
            "seed": int(self.seed),
        }


class FlightRecorder:
    """Bounded in-memory ring of structured spans and instant events.

    Thread-safe.  All timestamps are ``time.perf_counter()`` floats;
    export maps them to microseconds relative to the recorder's epoch
    (Chrome) or to wall-clock seconds (JSONL).
    """

    def __init__(self, enabled: Optional[bool] = None, *,
                 sample_rate: Optional[float] = None,
                 ring: Optional[int] = None,
                 seed: Optional[int] = None) -> None:
        if enabled is None:
            enabled = os.environ.get("REPRO_TRACE", "1") != "0"
        if sample_rate is None:
            sample_rate = float(os.environ.get("REPRO_TRACE_SAMPLE", "1.0"))
        if ring is None:
            ring = int(os.environ.get("REPRO_TRACE_RING", str(_DEFAULT_RING)))
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate must be in [0, 1], got {sample_rate}")
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self.ring = int(ring)
        self.seed = 0 if seed is None else int(seed)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.ring)
        self._rng = random.Random(self.seed)
        self._next_id = 1
        self._traces = 0
        self._recorded = 0
        self._dropped = 0
        self._t0 = time.perf_counter()
        self._wall0 = time.time()

    # -- identity ----------------------------------------------------------

    def alloc_id(self) -> int:
        with self._lock:
            i = self._next_id
            self._next_id += 1
        return i

    def new_trace(self) -> Optional[SpanContext]:
        """Start a trace: allocate ids and make the sampling decision.

        Returns ``None`` (no lock, no allocation) when disabled.  The
        sampler is only consulted for rates strictly inside (0, 1) so
        the rng stream — and therefore the sampled set under a fixed
        seed — is a pure function of the root-creation order.
        """
        if not self.enabled:
            return None
        with self._lock:
            tid = self._next_id
            sid = self._next_id + 1
            self._next_id += 2
            if self.sample_rate >= 1.0:
                sampled = True
            elif self.sample_rate <= 0.0:
                sampled = False
            else:
                sampled = self._rng.random() < self.sample_rate
            self._traces += 1
        return SpanContext(tid, sid, sampled)

    # -- recording ---------------------------------------------------------

    def record_span(self, name: str, ctx: Optional[SpanContext], *,
                    t0: float, t1: float,
                    span_id: Optional[int] = None,
                    parent_id: Optional[int] = None,
                    status: str = "ok",
                    force: bool = False,
                    attrs: Optional[Dict[str, Any]] = None) -> Optional[int]:
        """Append one completed span; returns its span id or ``None``.

        Skipped unless the trace was sampled or ``force`` is set
        (errors and shed rejections force-record so incidents survive
        any sampling rate).
        """
        if ctx is None or not self.enabled:
            return None
        if not (ctx.sampled or force):
            return None
        if span_id is None:
            span_id = self.alloc_id()
        rec = {
            "kind": "span",
            "name": name,
            "trace_id": ctx.trace_id,
            "span_id": span_id,
            "parent_id": parent_id,
            "t0": t0,
            "t1": t1,
            "status": status,
            "attrs": dict(attrs) if attrs else {},
        }
        self._append(rec)
        return span_id

    def record_event(self, name: str,
                     ctx: Optional[SpanContext] = None, *,
                     force: bool = False,
                     attrs: Optional[Dict[str, Any]] = None) -> bool:
        """Append an instant event (Chrome ``ph: "i"``)."""
        if not self.enabled:
            return False
        if not (force or (ctx is not None and ctx.sampled)):
            return False
        now = time.perf_counter()
        rec = {
            "kind": "event",
            "name": name,
            "trace_id": ctx.trace_id if ctx is not None else 0,
            "span_id": self.alloc_id(),
            "parent_id": ctx.span_id if ctx is not None else None,
            "t0": now,
            "t1": now,
            "status": "ok",
            "attrs": dict(attrs) if attrs else {},
        }
        self._append(rec)
        return True

    def _append(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self._dropped += 1
            self._ring.append(rec)
            self._recorded += 1

    # -- inspection --------------------------------------------------------

    def records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(r) for r in self._ring]

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        out = [r for r in self.records() if r["kind"] == "span"]
        if name is not None:
            out = [r for r in out if r["name"] == name]
        return out

    def events(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        out = [r for r in self.records() if r["kind"] == "event"]
        if name is not None:
            out = [r for r in out if r["name"] == name]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def snapshot_section(self) -> Dict[str, Any]:
        """The ``trace`` section of ``snapshot()`` schema v2."""
        with self._lock:
            return {
                "enabled": self.enabled,
                "sample_rate": self.sample_rate,
                "ring": self.ring,
                "recorded": self._recorded,
                "buffered": len(self._ring),
                "dropped": self._dropped,
                "traces": self._traces,
            }

    # -- export ------------------------------------------------------------

    def _kept(self, records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
        """Drop records whose parent chain left the ring (orphans).

        The ring evicts oldest-first, so a long-lived root can be
        evicted while its children survive; exporting those children
        would break the "every span's parent exists" invariant the
        trace validator checks, so they are filtered here.
        """
        by_id = {r["span_id"]: r for r in records if r["kind"] == "span"}
        memo: Dict[int, bool] = {}

        def keep(rec: Dict[str, Any]) -> bool:
            sid = rec["span_id"]
            if sid in memo:
                return memo[sid]
            chain = []
            cur: Optional[Dict[str, Any]] = rec
            ok = True
            while cur is not None:
                cid = cur["span_id"]
                if cid in memo:
                    ok = memo[cid]
                    break
                chain.append(cid)
                pid = cur["parent_id"]
                if pid is None:
                    break
                cur = by_id.get(pid)
                if cur is None:
                    ok = False
            for cid in chain:
                memo[cid] = ok
            return ok

        return [r for r in records if keep(r)]

    def export_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (``ph: "X"`` complete events).

        Each trace gets its own ``tid`` row so stitched requests read
        as one lane in Perfetto / ``chrome://tracing``.
        """
        records = self.records()
        kept = self._kept(records)
        events: List[Dict[str, Any]] = []
        for r in kept:
            args = {
                "trace_id": r["trace_id"],
                "span_id": r["span_id"],
                "parent_id": r["parent_id"],
                "status": r["status"],
            }
            args.update(r["attrs"])
            ev: Dict[str, Any] = {
                "name": r["name"],
                "ts": round(max(r["t0"] - self._t0, 0.0) * 1e6, 3),
                "pid": 0,
                "tid": r["trace_id"],
                "args": args,
            }
            if r["kind"] == "span":
                ev["ph"] = "X"
                ev["dur"] = round(max(r["t1"] - r["t0"], 0.0) * 1e6, 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        events.sort(key=lambda e: (e["ts"], e["args"]["span_id"]))
        with self._lock:
            dropped = self._dropped
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "dropped_spans": dropped,
                "orphaned_spans": len(records) - len(kept),
            },
        }

    def export_jsonl(self) -> str:
        """One JSON object per record, wall-clock timestamps."""
        lines = []
        for r in self._kept(self.records()):
            out = dict(r)
            t0 = out.pop("t0")
            t1 = out.pop("t1")
            out["ts"] = round(self._wall0 + (t0 - self._t0), 6)
            out["dur_s"] = round(max(t1 - t0, 0.0), 9)
            lines.append(json.dumps(out, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def dump(self, path: str | Path, fmt: str = "chrome") -> Path:
        path = Path(path)
        if fmt == "chrome":
            path.write_text(json.dumps(self.export_chrome()))
        elif fmt == "jsonl":
            path.write_text(self.export_jsonl())
        else:
            raise ValueError(f"unknown trace format {fmt!r}; "
                             f"expected 'chrome' or 'jsonl'")
        return path


# -- context propagation ----------------------------------------------------

def current_context() -> Optional[SpanContext]:
    return _current.get()


@contextlib.contextmanager
def use_context(ctx: Optional[SpanContext]) -> Iterator[Optional[SpanContext]]:
    """Install ``ctx`` as the current span context (no-op for ``None``).

    This is the explicit cross-thread carry: a worker thread that
    processes work submitted elsewhere wraps the processing in
    ``use_context(ticket_ctx)`` so spans it opens stitch into the
    submitter's trace.
    """
    if ctx is None:
        yield None
        return
    token = _current.set(ctx)
    try:
        yield ctx
    finally:
        _current.reset(token)


@contextlib.contextmanager
def root_trace(name: str, **attrs: Any) -> Iterator[Optional[SpanContext]]:
    """Start a new trace rooted at a span named ``name``."""
    rec = get_default_recorder()
    ctx = rec.new_trace()
    if ctx is None:
        yield None
        return
    token = _current.set(ctx)
    t0 = time.perf_counter()
    status = "ok"
    try:
        yield ctx
    except BaseException as e:
        status = "error"
        attrs = dict(attrs)
        attrs["error"] = type(e).__name__
        raise
    finally:
        _current.reset(token)
        rec.record_span(name, ctx, t0=t0, t1=time.perf_counter(),
                        span_id=ctx.span_id, parent_id=None,
                        status=status, force=status == "error",
                        attrs=attrs)


class _DualSpan:
    """Span that feeds both the phase histogram and the flight recorder.

    Installs itself as the current context so nested ``trace()`` calls
    parent correctly.
    """

    __slots__ = ("_reg", "_rec", "_outer", "_name", "_labels",
                 "_ctx", "_token", "_t0")

    def __init__(self, reg: "_registry.MetricsRegistry",
                 rec: FlightRecorder, outer: SpanContext,
                 name: str, labels: Dict[str, Any]) -> None:
        self._reg = reg
        self._rec = rec
        self._outer = outer
        self._name = name
        self._labels = labels

    def __enter__(self) -> "_DualSpan":
        self._ctx = SpanContext(self._outer.trace_id, self._rec.alloc_id(),
                                True)
        self._token = _current.set(self._ctx)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        _current.reset(self._token)
        if self._reg.enabled:
            self._reg.histogram(f"phase.{self._name}",
                                **self._labels).observe(t1 - self._t0)
        attrs = dict(self._labels)
        status = "ok"
        if exc_type is not None:
            status = "error"
            attrs["error"] = exc_type.__name__
        self._rec.record_span(self._name, self._outer, t0=self._t0, t1=t1,
                              span_id=self._ctx.span_id,
                              parent_id=self._outer.span_id,
                              status=status, force=status == "error",
                              attrs=attrs)
        return False


def trace(phase: str, **labels: Any):
    """Combined histogram + flight-recorder span.

    Outside an active sampled trace this degrades to the registry's
    histogram-only span (one contextvar read of extra cost), so the
    hot path stays within the obs overhead budget.
    """
    reg = _registry.get_default_registry()
    ctx = _current.get()
    if ctx is not None and ctx.sampled and reg.recorder.enabled:
        return _DualSpan(reg, reg.recorder, ctx, phase, labels)
    return reg.trace(phase, **labels)


# -- default-recorder front door --------------------------------------------

def get_default_recorder() -> FlightRecorder:
    return _registry.get_default_registry().recorder


def configure_tracing(*, enabled: Optional[bool] = None,
                      sample_rate: Optional[float] = None,
                      ring: Optional[int] = None,
                      seed: Optional[int] = None) -> FlightRecorder:
    """Replace the default registry's recorder with a reconfigured one."""
    rec = FlightRecorder(enabled, sample_rate=sample_rate, ring=ring,
                         seed=seed)
    _registry.get_default_registry().recorder = rec
    return rec


def apply_trace_spec(spec: TraceSpec) -> FlightRecorder:
    """Apply a config-artifact :class:`TraceSpec` to the default plane."""
    return configure_tracing(enabled=spec.enabled,
                             sample_rate=spec.sample_rate,
                             ring=spec.ring, seed=spec.seed)


def set_tracing_enabled(flag: bool) -> bool:
    rec = get_default_recorder()
    prev = rec.enabled
    rec.enabled = bool(flag)
    return prev


def tracing_enabled() -> bool:
    return get_default_recorder().enabled


def export_chrome() -> Dict[str, Any]:
    return get_default_recorder().export_chrome()


def export_jsonl() -> str:
    return get_default_recorder().export_jsonl()


def dump_trace(path: str | Path, fmt: str = "chrome") -> Path:
    return get_default_recorder().dump(path, fmt)
