"""Prometheus text-format rendering of a metrics snapshot (stdlib-only).

Renders the ONE plain dict produced by
:meth:`repro.obs.MetricsRegistry.snapshot` as Prometheus exposition text
(text/plain; version=0.0.4), so a scrape endpoint — or just
``python -m repro stats --format prom`` piped to a file — feeds the same
numbers every other consumer sees.  No client library: the format is a
few lines of string assembly, and the container must not grow deps.

Mapping:

* counters   -> ``<name>_total{labels} value`` (TYPE counter)
* gauges     -> ``<name>{labels} value`` (TYPE gauge; unset/None skipped)
* histograms -> ``<name>_bucket{le="..."}`` cumulative series plus
  ``_sum``/``_count`` (TYPE histogram); the exact p50/p95/p99 ride along
  as ``<name>_quantile{quantile="0.5"}`` gauges since Prometheus
  histograms cannot carry precomputed quantiles.

Metric names are sanitized to ``[a-zA-Z_][a-zA-Z0-9_]*`` (dots become
underscores: ``phase.refresh.fit`` -> ``phase_refresh_fit``).
"""
from __future__ import annotations

import re

from repro.obs.registry import split_key

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    s = _NAME_OK.sub("_", name)
    if not s or s[0].isdigit():
        s = "_" + s
    return s


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{_escape(v)}"'
                     for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def _fmt(v) -> str:
    if v is None:
        return "NaN"
    f = float(v)
    if f != f:
        return "NaN"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_prometheus(snapshot: dict) -> str:
    """Snapshot dict -> Prometheus exposition text (one trailing newline)."""
    lines: list[str] = []
    typed: set[str] = set()

    def head(name: str, kind: str) -> None:
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for key, value in snapshot.get("counters", {}).items():
        raw, labels = split_key(key)
        name = _prom_name(raw) + "_total"
        head(name, "counter")
        lines.append(f"{name}{_prom_labels(labels)} {_fmt(value)}")

    for key, value in snapshot.get("gauges", {}).items():
        if value is None:
            continue
        raw, labels = split_key(key)
        name = _prom_name(raw)
        head(name, "gauge")
        lines.append(f"{name}{_prom_labels(labels)} {_fmt(value)}")

    for key, h in snapshot.get("histograms", {}).items():
        raw, labels = split_key(key)
        name = _prom_name(raw)
        head(name, "histogram")
        for le, cum in h.get("buckets", {}).items():
            lines.append(
                f"{name}_bucket{_prom_labels(labels, {'le': le})} {cum}")
        lines.append(f"{name}_sum{_prom_labels(labels)} {_fmt(h.get('sum'))}")
        lines.append(
            f"{name}_count{_prom_labels(labels)} {h.get('count', 0)}")
        for q, field in (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")):
            v = h.get(field)
            if v is not None:
                qname = name + "_quantile"
                head(qname, "gauge")
                lines.append(
                    f"{qname}{_prom_labels(labels, {'quantile': q})} "
                    f"{_fmt(v)}")
    return "\n".join(lines) + "\n"
