"""Online health monitors over the telemetry plane.

The paper's contract is quantitative: a (k, z)-fit flags roughly z of
the n trained points as outliers, so the *live* outlier fraction of a
healthy stream should hover near the configured ``z / n`` budget.  When
it leaves that band the data has drifted (or a site has gone bad —
exactly the detection signal robust-aggregation schemes assume exists).
These monitors watch that, plus two serving-health invariants, and emit
typed :class:`Alert` records into ``snapshot()`` (schema v2):

- :class:`OutlierRateMonitor` — EWMA of the observed outlier fraction
  of scored queries vs a multiplicative band around the configured
  ``z / trained_weight`` fraction.
- :class:`StalenessMonitor` — model age (``seconds_since_install``) vs
  a freshness SLO; a stale model silently mis-scores drifted data.
- :class:`ShedRateMonitor` — EWMA of the admission shed fraction vs a
  burn threshold; sustained shedding means capacity, not a blip.

A :class:`MonitorHub` instance hangs off each ``MetricsRegistry`` so
``using_registry`` isolates monitor state exactly like metric state.
All monitors are passive: layers feed them observations, and alerts are
evaluated lazily at ``snapshot()`` time.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

__all__ = [
    "Alert",
    "OutlierRateMonitor",
    "StalenessMonitor",
    "ShedRateMonitor",
    "MonitorHub",
]


class Alert(NamedTuple):
    """One triggered monitor condition, stable enough to snapshot."""

    name: str
    severity: str
    message: str
    value: float
    threshold: float
    labels: Tuple[Tuple[str, str], ...] = ()

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "severity": self.severity,
            "message": self.message,
            "value": round(float(self.value), 6),
            "threshold": round(float(self.threshold), 6),
            "labels": dict(self.labels),
        }


class OutlierRateMonitor:
    """EWMA outlier fraction vs the configured z/n band.

    The budget is ``t / trained_weight`` — the fraction of the trained
    mass the fit was allowed to discard — installed by the service at
    every model refresh.  The band is multiplicative
    (``[budget / band, budget * band]``) with an absolute floor on the
    high side so a tiny budget doesn't page on one noisy outlier.
    """

    def __init__(self, *, alpha: float = 0.2, band_factor: float = 4.0,
                 min_count: int = 64, abs_floor: float = 0.02) -> None:
        self.alpha = float(alpha)
        self.band_factor = float(band_factor)
        self.min_count = int(min_count)
        self.abs_floor = float(abs_floor)
        self._ewma: Optional[float] = None
        self._seen = 0
        self._budget: Optional[float] = None

    def set_budget(self, frac: float) -> None:
        self._budget = float(frac)

    def observe(self, n: int, n_outliers: int) -> None:
        if n <= 0:
            return
        frac = n_outliers / n
        if self._ewma is None:
            self._ewma = frac
        else:
            self._ewma = self.alpha * frac + (1.0 - self.alpha) * self._ewma
        self._seen += n

    def evaluate(self, labels: Tuple[Tuple[str, str], ...]) -> List[Alert]:
        if (self._budget is None or self._ewma is None
                or self._seen < self.min_count):
            return []
        hi = max(self._budget * self.band_factor, self.abs_floor)
        lo = self._budget / self.band_factor
        if self._ewma > hi:
            return [Alert(
                "outlier_rate_high", "warn",
                f"observed outlier rate {self._ewma:.4f} exceeds band "
                f"[{lo:.4f}, {hi:.4f}] around budget {self._budget:.4f} "
                f"(z/n): stream has drifted from the trained model",
                self._ewma, hi, labels)]
        if self._budget > 0.0 and self._ewma < lo:
            return [Alert(
                "outlier_rate_low", "info",
                f"observed outlier rate {self._ewma:.4f} is below band "
                f"[{lo:.4f}, {hi:.4f}] around budget {self._budget:.4f} "
                f"(z/n): threshold may be too loose for current traffic",
                self._ewma, lo, labels)]
        return []


class StalenessMonitor:
    """Model age vs a freshness SLO."""

    def __init__(self, *, slo_s: float = 600.0) -> None:
        self.slo_s = float(slo_s)
        self._age_fn: Optional[Callable[[], Optional[float]]] = None

    def set_source(self, fn: Callable[[], Optional[float]]) -> None:
        self._age_fn = fn

    def evaluate(self, labels: Tuple[Tuple[str, str], ...]) -> List[Alert]:
        if self._age_fn is None:
            return []
        try:
            age = self._age_fn()
        except Exception:
            return []
        if age is None or age <= self.slo_s:
            return []
        return [Alert(
            "model_staleness", "warn",
            f"model installed {age:.1f}s ago exceeds freshness SLO "
            f"{self.slo_s:.1f}s; scores may not reflect current data",
            float(age), self.slo_s, labels)]


class ShedRateMonitor:
    """EWMA shed fraction of admission decisions vs a burn threshold.

    Each admission outcome (admit=0, shed=1) nudges the EWMA; a batch of
    ``a`` admits followed by ``s`` sheds is applied in closed form so
    the scheduler's hot path pays O(1) per call.
    """

    def __init__(self, *, alpha: float = 0.05, burn_max: float = 0.1,
                 min_events: int = 32) -> None:
        self.alpha = float(alpha)
        self.burn_max = float(burn_max)
        self.min_events = int(min_events)
        self._ewma = 0.0
        self._events = 0

    def observe(self, admitted: int, shed: int) -> None:
        if admitted <= 0 and shed <= 0:
            return
        keep = 1.0 - self.alpha
        if admitted > 0:
            self._ewma *= keep ** admitted
        if shed > 0:
            decay = keep ** shed
            self._ewma = self._ewma * decay + (1.0 - decay)
        self._events += admitted + shed

    def evaluate(self, labels: Tuple[Tuple[str, str], ...]) -> List[Alert]:
        if self._events < self.min_events or self._ewma <= self.burn_max:
            return []
        return [Alert(
            "shed_burn", "warn",
            f"admission shed rate EWMA {self._ewma:.4f} exceeds burn "
            f"threshold {self.burn_max:.4f}: sustained overload, add "
            f"capacity or tighten quotas",
            self._ewma, self.burn_max, labels)]


class MonitorHub:
    """Per-registry collection of monitors, one per (kind, topology).

    Thread-safe; every mutator is called from hot paths (drain, the
    scheduler's admission loop), every reader from ``snapshot()``.
    """

    def __init__(self, *, outlier_alpha: float = 0.2,
                 outlier_band: float = 4.0,
                 outlier_min_count: int = 64,
                 staleness_slo_s: float = 600.0,
                 shed_burn_max: float = 0.1,
                 shed_alpha: float = 0.05,
                 shed_min_events: int = 32) -> None:
        self._lock = threading.Lock()
        self._outlier_alpha = outlier_alpha
        self._outlier_band = outlier_band
        self._outlier_min_count = outlier_min_count
        self._staleness_slo_s = staleness_slo_s
        self._outlier: Dict[str, OutlierRateMonitor] = {}
        self._staleness: Dict[str, StalenessMonitor] = {}
        self._shed = ShedRateMonitor(alpha=shed_alpha,
                                     burn_max=shed_burn_max,
                                     min_events=shed_min_events)

    def _outlier_for(self, topology: str) -> OutlierRateMonitor:
        mon = self._outlier.get(topology)
        if mon is None:
            mon = self._outlier.setdefault(
                topology,
                OutlierRateMonitor(alpha=self._outlier_alpha,
                                   band_factor=self._outlier_band,
                                   min_count=self._outlier_min_count))
        return mon

    def set_outlier_budget(self, topology: str, frac: float) -> None:
        with self._lock:
            self._outlier_for(topology).set_budget(frac)

    def observe_scores(self, topology: str, n: int, n_outliers: int) -> None:
        with self._lock:
            self._outlier_for(topology).observe(n, n_outliers)

    def set_staleness_source(self, topology: str,
                             fn: Callable[[], Optional[float]]) -> None:
        with self._lock:
            mon = self._staleness.get(topology)
            if mon is None:
                mon = self._staleness.setdefault(
                    topology, StalenessMonitor(slo_s=self._staleness_slo_s))
            mon.set_source(fn)

    def observe_admission(self, admitted: int, shed: int) -> None:
        with self._lock:
            self._shed.observe(admitted, shed)

    def evaluate(self) -> List[Alert]:
        with self._lock:
            alerts: List[Alert] = []
            for topo in sorted(self._outlier):
                alerts.extend(self._outlier[topo].evaluate(
                    (("topology", topo),)))
            for topo in sorted(self._staleness):
                alerts.extend(self._staleness[topo].evaluate(
                    (("topology", topo),)))
            alerts.extend(self._shed.evaluate(()))
        return alerts

    def snapshot_alerts(self) -> List[Dict[str, Any]]:
        """The ``alerts`` section of ``snapshot()`` schema v2."""
        return [a.to_dict() for a in self.evaluate()]
