"""Process-wide metrics registry: counters, gauges, histograms, phase spans.

The paper's headline claims are *measured* claims — communication cost
(records moved per round), clustering cost, outlier recall — and a serving
deployment adds latency and staleness to that list.  Before this module
every layer kept its own ad-hoc numbers (an unbounded latency list in the
serving front end, a one-off ``RefreshStats`` tuple in the sharded
service, an inline ``comm_records`` float in the coordinator), so there
was no single snapshot of what a running ``Session`` was doing.  This is
that snapshot's home:

* :class:`Counter` — monotonically increasing total (requests served,
  comm records gathered, kernel dispatches);
* :class:`Gauge` — last-set value, or a callable evaluated at snapshot
  time (tree records held, model staleness);
* :class:`Histogram` — fixed-bucket distribution **plus a bounded ring
  buffer of recent raw samples**, so bucket counts are Prometheus-style
  cumulative totals while p50/p95/p99 are *exact* percentiles
  (``np.percentile``) over the most recent ``ring`` observations — no
  bucket-interpolation error, no unbounded memory;
* :meth:`MetricsRegistry.trace` — a ``with trace("refresh.fit"): ...``
  span recording wall time into the ``phase.refresh.fit`` histogram, the
  one idiom every pipeline phase (ingest -> leaf-flush -> merge-reduce;
  refresh: gather -> fit -> install; score: enqueue -> batch -> fused ->
  drain) is instrumented with.

Metrics are keyed by ``name{label=value,...}`` with sorted label keys, so
one family fans out over site id / summarizer / kernel backend / topology
without separate registries.  Everything is mutation-thread-safe (the
async-refresh worker and checkpoint writer threads record concurrently
with the ingest thread) and snapshots to ONE plain JSON-ready dict —
``repro.obs.prom`` renders the same snapshot as Prometheus text.

Instrumentation is process-wide on by default; ``REPRO_METRICS=0`` (or
``set_metrics_enabled(False)``) turns every mutation into a no-op.  The
plane is timers and tallies only — it never touches RNG or math, so
scores are bit-identical with it on or off (asserted in
``tests/test_obs.py``), and the ingest-throughput overhead is gated <= 5%
by ``benchmarks/check_stream_regression.py``.
"""
from __future__ import annotations

import bisect
import contextlib
import os
import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

SNAPSHOT_VERSION = 2

# Latency-oriented log-spaced bucket edges in seconds ("le" upper bounds).
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
DEFAULT_RING = 4096


def _sanitize_label(v) -> str:
    """Label values land inside the ``name{k=v,...}`` key and inside
    Prometheus quotes — strip the characters that would break either."""
    s = str(v)
    for ch in '{}=,"\n':
        s = s.replace(ch, "_")
    return s


def metric_key(name: str, labels: dict) -> str:
    """Canonical flattened key: ``name`` or ``name{k=v,...}``, label keys
    sorted so the same label set always produces the same key."""
    if not labels:
        return name
    inner = ",".join(f"{k}={_sanitize_label(labels[k])}"
                     for k in sorted(labels))
    return f"{name}{{{inner}}}"


def split_key(key: str) -> tuple[str, dict]:
    """Inverse of :func:`metric_key` (labels back as a dict)."""
    if key.endswith("}") and "{" in key:
        name, rest = key.split("{", 1)
        labels = dict(pair.split("=", 1) for pair in rest[:-1].split(","))
        return name, labels
    return key, {}


def _num(v):
    """int when integral (counters of records/bytes), float otherwise."""
    f = float(v)
    return int(f) if f.is_integer() else f


class Counter:
    """Monotonically increasing value; ``inc`` is atomic under its lock."""

    __slots__ = ("_registry", "_lock", "_value")

    def __init__(self, registry: "MetricsRegistry"):
        self._registry = registry
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, amount: int | float = 1) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += amount

    @property
    def value(self):
        return self._value


class Gauge:
    """Last-set value, or a callable evaluated lazily at snapshot time
    (``set_fn``) for quantities that are a function of *now*, like model
    staleness — a stored number would be stale the moment it was set."""

    __slots__ = ("_registry", "_lock", "_value", "_fn")

    def __init__(self, registry: "MetricsRegistry"):
        self._registry = registry
        self._lock = threading.Lock()
        self._value: Optional[float] = None
        self._fn: Optional[Callable[[], Optional[float]]] = None

    def set(self, value) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value = float(value)
            self._fn = None

    def set_fn(self, fn: Callable[[], Optional[float]]) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._fn = fn

    def get(self) -> Optional[float]:
        fn = self._fn
        if fn is not None:
            try:
                v = fn()
            except Exception:
                return None
            return None if v is None else float(v)
        return self._value


class Histogram:
    """Fixed buckets for the long-run shape, a bounded ring of recent raw
    samples for exact percentiles.

    ``count``/``sum``/``min``/``max``/bucket counts cover *every*
    observation since creation (or :meth:`reset`); ``percentile`` and the
    snapshot's p50/p95/p99 are ``np.percentile`` over the most recent
    ``ring`` samples — exact, bounded, and recency-weighted, which is what
    a serving dashboard wants anyway.
    """

    __slots__ = ("_registry", "_lock", "_edges", "_counts", "_ring",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, registry: "MetricsRegistry",
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 ring: int = DEFAULT_RING):
        self._registry = registry
        self._lock = threading.Lock()
        self._edges = tuple(sorted(float(b) for b in buckets))
        self._counts = [0] * (len(self._edges) + 1)   # +1: the +Inf bucket
        self._ring: deque = deque(maxlen=int(ring))
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None

    def observe(self, value: float) -> None:
        if not self._registry.enabled:
            return
        v = float(value)
        with self._lock:
            # "le" semantics: bucket i counts v <= edges[i]
            self._counts[bisect.bisect_left(self._edges, v)] += 1
            self._ring.append(v)
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    def percentile(self, q: float) -> Optional[float]:
        """Exact ``np.percentile`` over the recent-sample ring."""
        with self._lock:
            data = list(self._ring)
        if not data:
            return None
        return float(np.percentile(np.asarray(data, np.float64), q))

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self._edges) + 1)
            self._ring.clear()
            self._count = 0
            self._sum = 0.0
            self._min = self._max = None

    @property
    def count(self) -> int:
        return self._count

    def snapshot_entry(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            data = list(self._ring)
            count, total = self._count, self._sum
            lo, hi = self._min, self._max
        buckets: dict[str, int] = {}
        running = 0
        for edge, c in zip(self._edges, counts):
            running += c
            buckets[format(edge, ".10g")] = running
        buckets["+Inf"] = running + counts[-1]
        if data:
            arr = np.asarray(data, np.float64)
            p50, p95, p99 = (float(np.percentile(arr, q))
                             for q in (50, 95, 99))
        else:
            p50 = p95 = p99 = None
        return {
            "count": int(count),
            "sum": float(total),
            "min": lo,
            "max": hi,
            "p50": p50,
            "p95": p95,
            "p99": p99,
            "buckets": buckets,
        }


class _Span:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(time.perf_counter() - self._t0)
        return False


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class MetricsRegistry:
    """One process-wide home for every metric; snapshot to a plain dict.

    ``enabled=False`` (or env ``REPRO_METRICS=0`` for the process default)
    turns every mutation — ``inc``/``set``/``observe``/``trace`` — into a
    no-op while reads keep working, so instrumented code never branches on
    whether telemetry is on.
    """

    def __init__(self, enabled: Optional[bool] = None, *,
                 recorder=None, monitors=None):
        if enabled is None:
            enabled = os.environ.get("REPRO_METRICS", "1") != "0"
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        # Each registry carries its own flight recorder and monitor hub
        # so ``using_registry`` isolates trace/alert state exactly like
        # metric state.  Imported lazily: tracing/monitors import this
        # module at their top level.
        if recorder is None:
            from repro.obs.tracing import FlightRecorder
            recorder = FlightRecorder()
        if monitors is None:
            from repro.obs.monitors import MonitorHub
            monitors = MonitorHub()
        self.recorder = recorder
        self.monitors = monitors

    # ------------------------------------------------------------ metrics
    def counter(self, name: str, **labels) -> Counter:
        key = metric_key(name, labels)
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter(self))
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = metric_key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge(self))
        return g

    def histogram(self, name: str, *, buckets: Sequence[float] | None = None,
                  ring: int | None = None, **labels) -> Histogram:
        key = metric_key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(key, Histogram(
                    self, buckets=buckets or DEFAULT_BUCKETS,
                    ring=ring or DEFAULT_RING))
        return h

    def trace(self, phase: str, **labels):
        """``with registry.trace("refresh.fit", site=0): ...`` — wall time
        of the block lands in the ``phase.refresh.fit{site=0}`` histogram."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self.histogram(f"phase.{phase}", **labels))

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """The ONE plain dict: every counter, gauge and histogram, keyed by
        ``name{label=value,...}``, JSON-serializable as-is.  Callable
        gauges are evaluated here."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "version": SNAPSHOT_VERSION,
            "enabled": self.enabled,
            "counters": {k: _num(c.value)
                         for k, c in sorted(counters.items())},
            "gauges": {k: g.get() for k, g in sorted(gauges.items())},
            "histograms": {k: h.snapshot_entry()
                           for k, h in sorted(hists.items())},
            "alerts": self.monitors.snapshot_alerts(),
            "trace": self.recorder.snapshot_section(),
        }

    def reset(self) -> None:
        """Drop every metric (a fresh registry without re-plumbing refs
        held by long-lived callers is NOT possible — they keep their
        handles; prefer :func:`using_registry` for test isolation)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# ----------------------------------------------------------- process default
_default_registry = MetricsRegistry()


def get_default_registry() -> MetricsRegistry:
    return _default_registry


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Install ``registry`` process-wide; returns the previous default.
    Instrumented layers capture metric handles when they are constructed,
    so install the registry *before* building the service under test."""
    global _default_registry
    prev = _default_registry
    _default_registry = registry
    return prev


@contextlib.contextmanager
def using_registry(registry: MetricsRegistry):
    """Scoped :func:`set_default_registry` (test/bench isolation)."""
    prev = set_default_registry(registry)
    try:
        yield registry
    finally:
        set_default_registry(prev)


def set_metrics_enabled(flag: bool) -> bool:
    """Flip instrumentation on/off on the current default registry;
    returns the previous state."""
    reg = get_default_registry()
    prev = reg.enabled
    reg.enabled = bool(flag)
    return prev


def metrics_enabled() -> bool:
    return get_default_registry().enabled


# ------------------------------------------------- default-registry helpers
def counter(name: str, **labels) -> Counter:
    return get_default_registry().counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return get_default_registry().gauge(name, **labels)


def histogram(name: str, *, buckets: Sequence[float] | None = None,
              ring: int | None = None, **labels) -> Histogram:
    return get_default_registry().histogram(name, buckets=buckets, ring=ring,
                                            **labels)


def trace(phase: str, **labels):
    return get_default_registry().trace(phase, **labels)


def snapshot() -> dict:
    return get_default_registry().snapshot()


def record_comm(per_site_records: Sequence[int],
                per_site_bytes: Sequence[int], **labels) -> None:
    """THE one communication-accounting mechanism.

    Every gather path — the sharded stream refresh, the host-simulated
    coordinator, the shard_map one-shot — reports the same way: valid
    records (the paper's communication measure, Chen/Sun/Zhang 1805.09495)
    and padded payload bytes (what actually crosses the interconnect), per
    site, accumulated into ``comm.records{site=i}`` / ``comm.bytes{site=i}``
    counters plus a ``comm.rounds`` round counter.
    """
    reg = get_default_registry()
    if not reg.enabled:
        return
    for site, (n_rec, n_bytes) in enumerate(zip(per_site_records,
                                                per_site_bytes)):
        reg.counter("comm.records", site=site, **labels).inc(int(n_rec))
        reg.counter("comm.bytes", site=site, **labels).inc(int(n_bytes))
    reg.counter("comm.rounds", **labels).inc()
