"""Synthetic dataset generators matching the paper's Section 5.1.1.

* ``gauss(sigma)``  — exactly the paper's generator: ``n_centers`` centers
  uniform in [0,1]^d, ``per_center`` Gaussian points each, then ``t`` points
  re-sampled and shifted by U[-2,2]^d to become ground-truth outliers.
* ``kdd_like``      — statistically matched stand-in for kddFull/kddSp
  (offline container: the original is not redistributable here): d=34
  z-normalized features, 3 dominant clusters holding 98.3% of the mass with
  per-class scale spread, remaining mass in 20 small clusters treated as
  ground-truth outliers.
* ``susy_like``     — d=18 z-normalized 2-component mixture (signal/bkg) with
  ``t`` points shifted by U[-delta, delta]^d (the paper's susy-Delta).

All generators return (X float32 (n,d), outlier_ids int64) and take ``n`` so
paper-scale runs are a flag away on real hardware.
"""
from __future__ import annotations

import numpy as np


def gauss(
    n_centers: int = 100,
    per_center: int = 10_000,
    d: int = 5,
    sigma: float = 0.1,
    t: int = 5_000,
    seed: int = 0,
):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.0, 1.0, size=(n_centers, d))
    x = np.repeat(centers, per_center, axis=0) + rng.normal(
        0.0, sigma, size=(n_centers * per_center, d))
    n = x.shape[0]
    out_ids = rng.choice(n, size=t, replace=False)
    x[out_ids] += rng.uniform(-2.0, 2.0, size=(t, d))
    return x.astype(np.float32), np.sort(out_ids)


def drifting_gauss(
    n_phases: int = 3,
    n_centers: int = 8,
    per_center: int = 2_000,
    d: int = 5,
    sigma: float = 0.05,
    drift: float = 4.0,
    seed: int = 0,
):
    """Concept-shifting stream for sliding-window evaluation.

    Phase p draws ``n_centers * per_center`` points from fresh uniform
    centers inside the shifted box ``[p * drift, p * drift + 1]^d`` (rows
    shuffled within a phase, phases concatenated in stream order), so each
    phase occupies a disjoint region: a model fit on a window covering only
    the newest phase should sit in the newest box, while a full-stream model
    must split its k centers across all phases.

    Returns (X float32 (n_phases * n_centers * per_center, d) in stream
    order, phase_ids int64 (n,), centers float32 (n_phases, n_centers, d)).
    """
    rng = np.random.default_rng(seed)
    xs, phases, centers = [], [], []
    for p in range(n_phases):
        c = rng.uniform(0.0, 1.0, size=(n_centers, d)) + p * drift
        x = np.repeat(c, per_center, axis=0) + rng.normal(
            0.0, sigma, size=(n_centers * per_center, d))
        rng.shuffle(x, axis=0)
        xs.append(x)
        phases.append(np.full(x.shape[0], p))
        centers.append(c)
    return (np.concatenate(xs).astype(np.float32), np.concatenate(phases),
            np.stack(centers).astype(np.float32))


def kdd_like(n: int = 500_000, d: int = 34, t_frac: float = 0.0093, seed: int = 0):
    rng = np.random.default_rng(seed)
    big_frac = np.array([0.196, 0.216, 0.568])          # normal/neptune/smurf
    big_frac = big_frac / big_frac.sum() * (1.0 - t_frac)
    small_k = 20
    small_frac = np.full(small_k, t_frac / small_k)
    fracs = np.concatenate([big_frac, small_frac])
    ks = len(fracs)
    centers = rng.normal(0.0, 2.0, size=(ks, d))
    scales = rng.uniform(0.2, 1.0, size=(ks, 1))
    counts = np.maximum((fracs * n).astype(int), 1)
    counts[0] += n - counts.sum()
    xs, labels = [], []
    for i, c in enumerate(counts):
        xs.append(centers[i] + rng.normal(0.0, 1.0, size=(c, d)) * scales[i])
        labels.append(np.full(c, i))
    x = np.concatenate(xs).astype(np.float32)
    labels = np.concatenate(labels)
    perm = rng.permutation(x.shape[0])
    x, labels = x[perm], labels[perm]
    x = (x - x.mean(0)) / (x.std(0) + 1e-9)             # paper z-normalizes
    out_ids = np.nonzero(labels >= 3)[0]                # small clusters = outliers
    return x, np.sort(out_ids)


def susy_like(n: int = 500_000, d: int = 18, t: int = 5_000,
              delta: float = 5.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    comp = rng.integers(0, 2, size=n)
    mu = np.stack([rng.normal(0, 1, d), rng.normal(0, 1, d)])
    x = mu[comp] + rng.normal(0.0, 1.0, size=(n, d))
    x = (x - x.mean(0)) / (x.std(0) + 1e-9)
    out_ids = rng.choice(n, size=t, replace=False)
    x[out_ids] += rng.uniform(-delta, delta, size=(t, d))
    return x.astype(np.float32), np.sort(out_ids)


def partition(x: np.ndarray, s: int, mode: str = "random", seed: int = 0,
              outlier_ids: np.ndarray | None = None):
    """Split rows of x into s site-parts.

    random      — the dispatcher model (paper's experiments).
    adversarial — all outliers (plus fill) land on site 0.
    Returns (parts, global_ids per part).
    """
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    if mode == "random":
        perm = rng.permutation(n)
    elif mode == "adversarial":
        if outlier_ids is None:
            raise ValueError("adversarial partition needs outlier_ids")
        rest = np.setdiff1d(np.arange(n), outlier_ids)
        perm = np.concatenate([outlier_ids, rng.permutation(rest)])
    else:
        raise ValueError(mode)
    # equal-size parts (truncate the remainder, keeps shapes uniform)
    per = n // s
    parts, gids = [], []
    for i in range(s):
        ids = perm[i * per:(i + 1) * per]
        parts.append(x[ids])
        gids.append(ids)
    return parts, gids
