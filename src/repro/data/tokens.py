"""Deterministic, shard-aware, resumable token pipeline.

Design goals for the 1000-node story:
  * stateless addressing — batch(step, shard) is a pure function of
    (seed, step, shard), so restarts/elastic re-meshes replay exactly the
    right data with zero coordination (the checkpoint stores only `step`);
  * synthetic-but-learnable stream: an order-2 Markov chain over the vocab
    with a few deterministic motifs, so the quickstart example shows a
    real loss curve on CPU;
  * packing emulation: documents of geometric length separated by EOS.

Swap `_sample_tokens` for a real tokenized corpus reader in production; the
addressing contract is the part that matters.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1
    seed: int = 0
    eos: int = 1


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_shards == 0
        self.per_shard = cfg.global_batch // cfg.n_shards
        # fixed Markov structure derived from the seed (small state space so
        # a ~1M-param model can learn it quickly)
        rng = np.random.default_rng(cfg.seed)
        s = min(cfg.vocab, 64)
        self._states = s
        self._trans = rng.dirichlet(np.full(s, 0.3), size=(s, s))  # order-2

    def _sample_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        s = self._states
        out = np.empty(n, np.int64)
        a, b = rng.integers(0, s, 2)
        for i in range(n):
            c = rng.choice(s, p=self._trans[a, b])
            out[i] = c
            a, b = b, c
        return out

    def batch(self, step: int, shard: int = 0) -> dict:
        """(step, shard) -> {"tokens": (per_shard, seq_len) int32}. Pure."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard)
        toks = self._sample_tokens(rng, self.per_shard * cfg.seq_len)
        return {"tokens": toks.reshape(self.per_shard, cfg.seq_len).astype(np.int32)}

    def global_batch(self, step: int) -> dict:
        parts = [self.batch(step, s)["tokens"] for s in range(self.cfg.n_shards)]
        return {"tokens": np.concatenate(parts, axis=0)}
