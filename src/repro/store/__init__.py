"""Tiered summary store: bounded-memory streaming for the tree engines.

``StoreSpec`` declares the policy (hot budget, spill directory,
incremental-refresh behavior); ``TieredStore`` executes it (async spill
through the checkpoint machinery, crc-verified demand paging, residency
accounting).  See :mod:`repro.store.tiered` for the design notes and the
bit-identity contract.
"""
from repro.store.spec import StoreSpec
from repro.store.tiered import TieredStore, summary_nbytes

__all__ = ["StoreSpec", "TieredStore", "summary_nbytes"]
