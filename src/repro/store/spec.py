"""Declarative knobs for the tiered summary store.

A :class:`StoreSpec` travels on ``TreeConfig`` / ``BaseServiceConfig`` /
``PipelineConfig`` (all frozen, JSON-scalar fields) and controls two
orthogonal behaviors:

* **tiering** (``hot_levels`` / ``hot_bytes``): which merge-and-reduce
  levels stay resident in memory and which spill to the disk tier.  Unset
  both and nothing ever spills — the tree is exactly the in-memory one.
* **incremental refresh** (``incremental_refresh`` /
  ``warm_start_frac``): whether a serving refresh may skip the
  second-level k-means-- when the tree root has not changed since the
  last fit, and warm-start from the previous centers when little has.

Either way the tree root — and therefore every score — is bit-identical
to the untiered, always-refit configuration; the spec only moves bytes
and skips provably-redundant work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """Hot-budget + refresh-reuse policy for the stream tree.

    hot_levels: tree levels ``<= hot_levels`` stay resident; deeper
        (older, colder) summaries spill to disk.  ``None`` = no level rule.
    hot_bytes: resident summary payload budget in bytes; when exceeded the
        deepest-then-oldest resident summaries spill until under budget.
        ``None`` = no byte rule.  The leaf buffer is always resident.
    directory: spill root on disk.  ``None`` = a fresh temp directory per
        tree, removed when the tree is garbage-collected.
    incremental_refresh: skip the second-level fit entirely when no root
        changed since the last fit (the model would be bit-identical).
    warm_start_frac: when ``0 < changed mass fraction <= warm_start_frac``
        since the last fit, seed the second-level k-means-- from the
        previous centers instead of re-seeding.  0 (default) never
        warm-starts — warm starts trade bit-identity to always-refit for
        faster convergence, so they are strictly opt-in.
    """

    hot_levels: Optional[int] = None
    hot_bytes: Optional[int] = None
    directory: Optional[str] = None
    incremental_refresh: bool = True
    warm_start_frac: float = 0.0

    def __post_init__(self):
        if self.hot_levels is not None and (
                not isinstance(self.hot_levels, int)
                or isinstance(self.hot_levels, bool) or self.hot_levels < 0):
            raise ValueError(f"store.hot_levels must be an int >= 0 or None, "
                             f"got {self.hot_levels!r}")
        if self.hot_bytes is not None and (
                not isinstance(self.hot_bytes, int)
                or isinstance(self.hot_bytes, bool) or self.hot_bytes < 1):
            raise ValueError(f"store.hot_bytes must be an int >= 1 or None, "
                             f"got {self.hot_bytes!r}")
        if self.directory is not None and not isinstance(self.directory, str):
            raise ValueError(f"store.directory must be a string path or "
                             f"None, got {self.directory!r}")
        if not isinstance(self.incremental_refresh, bool):
            raise ValueError(f"store.incremental_refresh must be a bool, "
                             f"got {self.incremental_refresh!r}")
        wf = self.warm_start_frac
        if isinstance(wf, bool) or not isinstance(wf, (int, float)) \
                or not 0.0 <= float(wf) <= 1.0:
            raise ValueError(f"store.warm_start_frac must be a float in "
                             f"[0, 1], got {wf!r}")
        object.__setattr__(self, "warm_start_frac", float(wf))

    @property
    def tiered(self) -> bool:
        """True iff some hot budget is set, i.e. summaries may spill."""
        return self.hot_levels is not None or self.hot_bytes is not None
