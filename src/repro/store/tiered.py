"""Tiered summary store: hot (resident) summaries + a disk spill tier.

The merge-and-reduce tree's deep levels are cold, immutable, fixed-shape
blobs: once a level-l summary is built it is only ever read again when a
merge consumes it or a refresh gathers the root.  ``TieredStore`` keeps a
configurable hot set resident (:class:`repro.store.StoreSpec` — levels
``<= hot_levels`` and/or total payload ``<= hot_bytes``) and moves
everything else through the existing :class:`repro.checkpoint.manager.
CheckpointManager` machinery to disk: one checkpoint step per spilled
summary, crc-verified npy leaves, atomic publish, and the manager's
single async writer thread doubling as the spill worker (a spill enqueues
and returns; the write happens off the ingest path).

Demand paging is transient: ``page_in`` faults a spilled summary back
exactly when ``_merge_pair`` / ``root()`` / ``pack_state`` touch it and
returns it *without* re-admitting it to the hot set — the caller either
consumes it immediately (merge, then ``discard``) or drops the reference
(root gather), so resident bytes stay bounded by the hot budget plus one
summary.

Every byte that moves is accounted on the telemetry plane:
``store.spills`` / ``store.page_ins`` / ``store.spill_bytes`` /
``store.page_in_bytes`` counters, ``store.hot_bytes`` / ``store.hot_nodes``
/ ``store.cold_bytes`` / ``store.cold_nodes`` gauges, and
``trace(store.spill)`` / ``trace(store.page_in)`` spans.

The store never changes *values*: a paged-in summary is field-for-field
identical to what was spilled (float32/bool payloads round-trip exactly;
``n_rounds`` / ``total_weight`` are carried verbatim), so the tree root —
and every downstream score — is bit-identical to an untiered tree.
"""
from __future__ import annotations

import shutil
import tempfile
import weakref
from pathlib import Path
from typing import Optional

import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.store.spec import StoreSpec
from repro.stream.weighted import WeightedSummary

_COUNTERS = ("store.spills", "store.page_ins", "store.spill_bytes",
             "store.page_in_bytes")
_GAUGES = ("store.hot_bytes", "store.hot_nodes", "store.cold_bytes",
           "store.cold_nodes")


def summary_nbytes(summ: WeightedSummary) -> int:
    """Payload bytes a summary holds resident (points + weights + mask)."""
    return int(np.asarray(summ.points).nbytes
               + np.asarray(summ.weights).nbytes
               + np.asarray(summ.is_candidate).nbytes)


class TieredStore:
    """Spill/page-in engine for one tree's summaries.

    ``nodes`` passed to :meth:`enforce` / :meth:`sync` are
    ``repro.stream.tree.TreeNode`` objects (duck-typed: the store reads
    ``summary`` / ``level`` / ``n_records`` / ``nbytes`` and owns
    ``spill_step``).  Each spilled summary becomes one checkpoint step
    under a per-store temp subdirectory, so two trees (or a restore of
    the same tree) sharing ``spec.directory`` never collide.
    """

    def __init__(self, spec: StoreSpec, *, dim: int,
                 labels: Optional[dict] = None):
        self.spec = spec
        self.dim = dim
        self.labels = labels if labels is not None else {}
        if spec.directory is None:
            base = Path(tempfile.mkdtemp(prefix="repro-store-"))
            cleanup_root = base
        else:
            base = Path(spec.directory)
            base.mkdir(parents=True, exist_ok=True)
            cleanup_root = None
        self.dir = Path(tempfile.mkdtemp(prefix="tier-", dir=base))
        self.manager = CheckpointManager(self.dir, keep_last=0)
        self._next_step = 0
        # local tallies mirror the obs counters so tests/benches can read
        # them even with the metrics plane disabled
        self.spills = 0
        self.page_ins = 0
        self.spill_bytes = 0
        self.page_in_bytes = 0
        self._finalizer = weakref.finalize(
            self, shutil.rmtree, str(cleanup_root or self.dir),
            ignore_errors=True)

    # ------------------------------------------------------------ movement
    def spill(self, nd) -> None:
        """Serialize ``nd``'s summary to the disk tier (async) and drop the
        resident copy.  The manager's writer thread is the spill worker;
        enqueueing joins at most the one previous in-flight write."""
        summ = nd.summary
        with obs.trace("store.spill", **self.labels):
            payload = {
                "points": np.asarray(summ.points, np.float32),
                "weights": np.asarray(summ.weights, np.float32),
                "is_candidate": np.asarray(summ.is_candidate, bool),
                "n_rounds": np.int64(summ.n_rounds),
                "total_weight": np.float64(summ.total_weight),
            }
            step = self._next_step
            self._next_step += 1
            self.manager.save(step, payload, blocking=False)
        nd.spill_step = step
        nd.summary = None
        self.spills += 1
        self.spill_bytes += nd.nbytes
        obs.counter("store.spills", **self.labels).inc()
        obs.counter("store.spill_bytes", **self.labels).inc(nd.nbytes)

    def page_in(self, nd) -> WeightedSummary:
        """Fault ``nd``'s spilled summary back from disk (crc-verified).

        Transient: the node stays cold — the caller consumes the returned
        summary and drops it (or discards the node), so the hot budget is
        exceeded by at most one summary at a time."""
        n, d = nd.n_records, self.dim
        like = {
            "points": np.zeros((n, d), np.float32),
            "weights": np.zeros((n,), np.float32),
            "is_candidate": np.zeros((n,), bool),
            "n_rounds": np.int64(0),
            "total_weight": np.float64(0),
        }
        with obs.trace("store.page_in", **self.labels):
            state, _ = self.manager.restore(like, nd.spill_step)
        self.page_ins += 1
        self.page_in_bytes += nd.nbytes
        obs.counter("store.page_ins", **self.labels).inc()
        obs.counter("store.page_in_bytes", **self.labels).inc(nd.nbytes)
        return WeightedSummary(
            points=np.asarray(state["points"], np.float32),
            weights=np.asarray(state["weights"], np.float32),
            is_candidate=np.asarray(state["is_candidate"], bool),
            n_rounds=int(state["n_rounds"]),
            total_weight=float(state["total_weight"]))

    def discard(self, nd) -> None:
        """Forget a node the tree dropped (merged away or evicted): delete
        its spill blob, if any, so the disk tier never grows stale steps."""
        if getattr(nd, "spill_step", None) is None:
            return
        self.manager.wait()   # its write may still be in flight
        shutil.rmtree(self.dir / f"step_{nd.spill_step:09d}",
                      ignore_errors=True)
        nd.spill_step = None

    # ------------------------------------------------------------ policy
    def enforce(self, nodes) -> None:
        """Apply the hot budget: spill any resident summary the level rule
        marks cold, then — if a byte budget is set — spill
        deepest-then-oldest residents until under it.  Deepest first
        because level-0 nodes merge soonest: spilling them would fault
        straight back in on the next flush."""
        spec = self.spec
        if spec.hot_levels is not None:
            for nd in nodes:
                if nd.summary is not None and nd.level > spec.hot_levels:
                    self.spill(nd)
        if spec.hot_bytes is not None:
            resident = [nd for nd in nodes if nd.summary is not None]
            resident_bytes = sum(nd.nbytes for nd in resident)
            order = sorted(range(len(resident)),
                           key=lambda i: (-resident[i].level, i))
            for i in order:
                if resident_bytes <= spec.hot_bytes:
                    break
                resident_bytes -= resident[i].nbytes
                self.spill(resident[i])
        self.sync(nodes)

    def sync(self, nodes) -> None:
        """Recompute the residency gauges from the live node list (and make
        sure every store series exists, at zero, from the first flush on)."""
        reg = obs.get_default_registry()
        if not reg.enabled:
            return
        for name in _COUNTERS:
            reg.counter(name, **self.labels)
        hot = [nd for nd in nodes if nd.summary is not None]
        cold = [nd for nd in nodes if getattr(nd, "spill_step", None)
                is not None]
        reg.gauge("store.hot_bytes", **self.labels).set(
            sum(nd.nbytes for nd in hot))
        reg.gauge("store.hot_nodes", **self.labels).set(len(hot))
        reg.gauge("store.cold_bytes", **self.labels).set(
            sum(nd.nbytes for nd in cold))
        reg.gauge("store.cold_nodes", **self.labels).set(len(cold))

    # ------------------------------------------------------------ admin
    def stats(self) -> dict:
        """Movement tallies (metrics-plane-independent, for tests/benches)."""
        return {"spills": self.spills, "page_ins": self.page_ins,
                "spill_bytes": self.spill_bytes,
                "page_in_bytes": self.page_in_bytes}

    def flush(self) -> None:
        """Join the spill worker (re-raising any writer error)."""
        self.manager.wait()

    def close(self) -> None:
        """Join the writer and delete this store's on-disk tier."""
        try:
            self.manager.wait()
        finally:
            self._finalizer()
