"""Sharded, async, atomic checkpointing with cross-mesh restore.

Layout (one directory per step):
    <root>/step_000123.tmp/...        while writing
    <root>/step_000123/               after atomic rename (publish)
        manifest.json                 tree structure, shapes, dtypes, crcs
        arr_00000.npy ...             one file per leaf (full array)

Fault-tolerance properties:
  * atomic publish — a crashed writer never leaves a readable-but-corrupt
    checkpoint (readers only ever see fully-renamed directories);
  * async — save() returns immediately; the writer thread serializes
    device->host transfer + IO off the training path; wait() joins, and a
    writer-thread exception is captured and re-raised on the next
    wait()/save()/restore() instead of dying silently with the daemon;
  * integrity — crc32 per leaf, verified on restore;
  * cross-mesh restore — leaves are stored unsharded and re-placed with
    jax.device_put(leaf, sharding) for whatever mesh the restorer passes,
    so a 512-chip checkpoint restores onto 256 chips (elastic shrink) or 1
    CPU device (tests) unchanged;
  * retention — keep_last prunes old steps after each successful publish.

At true 1000-node scale each host would write only its addressable shards
(jax.experimental.multihost_utils); the manifest/atomic-rename/resume logic
here is host-count-agnostic and is exercised by the elastic tests.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np

from repro import obs


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str | Path, keep_last: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------ save
    def save(self, step: int, tree, *, blocking: bool = False, meta=None):
        """Snapshot `tree` (any pytree of arrays) at `step`.

        `meta`: optional JSON-serializable dict recorded in the manifest —
        writer-side facts a restorer must agree on before interpreting the
        leaves (e.g. the sharded stream service records its site count so a
        checkpoint cannot be silently restored onto a different topology).
        Read it back with `read_meta`."""
        try:
            # validate on the caller's thread (a bad meta on a non-blocking
            # save would otherwise die silently on the writer thread) and
            # normalize to the JSON image, so read_meta returns exactly what
            # a restorer will see (tuples become lists here, not at read).
            meta = json.loads(json.dumps(meta or {}))
        except (TypeError, ValueError) as e:
            raise TypeError(f"checkpoint meta is not JSON-serializable: {e}")
        leaves, treedef = _flatten(tree)
        # device -> host copy happens here (synchronously w.r.t. the arrays'
        # readiness) so training can donate/overwrite them right after.
        host_leaves = [np.asarray(x) for x in leaves]
        self.wait()

        def _write():
            # runs on the writer thread for async saves — the registry is
            # mutation-thread-safe, so recording from here is fine
            with obs.trace("checkpoint.save"):
                self._do_write(step, treedef, meta, host_leaves)
            obs.counter("checkpoint.saves").inc()
            obs.counter("checkpoint.bytes_written").inc(
                sum(arr.nbytes for arr in host_leaves))

        def _write_guarded():
            # an exception on the daemon writer thread would otherwise die
            # silently; park it for the next wait()/save()/restore() to
            # re-raise on a caller thread
            try:
                _write()
            except BaseException as e:
                self._error = e

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write_guarded, daemon=True)
            self._thread.start()

    def _do_write(self, step, treedef, meta, host_leaves):
        tmp = self.root / f"step_{step:09d}.tmp"
        final = self.root / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "treedef": str(treedef),
                    "meta": meta or {}, "leaves": []}
        for i, arr in enumerate(host_leaves):
            name = f"arr_{i:05d}.npy"
            np.save(tmp / name, arr)
            manifest["leaves"].append({
                "file": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(
                    np.ascontiguousarray(arr).tobytes()),
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._prune()

    def wait(self):
        """Join any in-flight async save.  Re-raises an exception the writer
        thread hit (here, on the caller's thread) — the failed step was never
        published, so the caller sees both the error and a consistent
        directory.  save()/restore()/read_meta() all wait first, so a lost
        write cannot be silently followed by dependent work."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    # ------------------------------------------------------------ restore
    def all_steps(self):
        out = []
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_meta(self, step: int | None = None) -> dict:
        """The `meta` dict `save` recorded at `step` (default: latest).
        Checkpoints written before meta existed read back as {}."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        manifest = json.loads(
            (self.root / f"step_{step:09d}" / "manifest.json").read_text())
        return manifest.get("meta", {})

    def restore(self, tree_like, step: int | None = None, *, shardings=None,
                verify: bool = True):
        """Restore into the structure of `tree_like` (shapes must match).
        `shardings`: optional matching pytree of Shardings for cross-mesh
        placement. Returns (tree, step)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:09d}"
        with obs.trace("checkpoint.restore"):
            manifest = json.loads((d / "manifest.json").read_text())
            leaves_like, treedef = _flatten(tree_like)
            if len(manifest["leaves"]) != len(leaves_like):
                raise ValueError(
                    f"checkpoint has {len(manifest['leaves'])} leaves, "
                    f"expected {len(leaves_like)}")
            shard_leaves = (_flatten(shardings)[0] if shardings is not None
                            else [None] * len(leaves_like))
            out = []
            read = 0
            for meta, like, sh in zip(manifest["leaves"], leaves_like,
                                      shard_leaves):
                arr = np.load(d / meta["file"])
                read += arr.nbytes
                if verify and zlib.crc32(
                        np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                    raise IOError(
                        f"crc mismatch in {meta['file']} (step {step})")
                if tuple(arr.shape) != tuple(like.shape):
                    raise ValueError(
                        f"shape mismatch {arr.shape} vs {like.shape}")
                arr = arr.astype(like.dtype)
                out.append(jax.device_put(arr, sh) if sh is not None
                           else jax.numpy.asarray(arr))
        obs.counter("checkpoint.restores").inc()
        obs.counter("checkpoint.bytes_read").inc(read)
        return jax.tree_util.tree_unflatten(treedef, out), step
