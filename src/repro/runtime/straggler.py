"""Straggler detection — the paper's own primitive, turned inward.

Per-site step durations are a 1-D clustering-with-outliers problem: the
healthy sites form one tight cluster, stragglers are the outliers.  We run
the paper's pipeline with k=1: summarize the duration history, then
(1,t)-means on it — sites repeatedly flagged become candidates for
re-dispatch (random repartition of their data, the paper's random-partition
model) or drop (the outlier budget t of the *clustering job itself* absorbs
the lost site's points — an option unique to clustering-with-outliers).

An EWMA fallback path is provided for the first few steps where the history
is too short to cluster.
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans_mm import kmeans_minus_minus


@dataclass
class StragglerMonitor:
    n_sites: int
    window: int = 32
    budget_frac: float = 0.1       # max fraction of sites flagged per step
    ewma_alpha: float = 0.2
    threshold: float = 2.0         # EWMA fallback: flag at 2x smoothed mean
    history: dict = field(default_factory=lambda: defaultdict(lambda: deque(maxlen=64)))
    _ewma: float | None = None

    def observe(self, durations: np.ndarray) -> np.ndarray:
        """durations: (n_sites,) seconds for the last step.
        Returns boolean straggler mask (n_sites,)."""
        durations = np.asarray(durations, np.float32)
        for i, d in enumerate(durations):
            self.history[i].append(float(d))
        mean = float(durations.mean())
        self._ewma = mean if self._ewma is None else \
            self.ewma_alpha * mean + (1 - self.ewma_alpha) * self._ewma

        n_hist = min(len(self.history[i]) for i in range(self.n_sites))
        if n_hist < 4:
            return durations > self.threshold * self._ewma

        # (1, t)-means on per-site mean durations: outliers = stragglers
        t = max(1, int(self.budget_frac * self.n_sites))
        pts = np.array([[np.mean(self.history[i])] for i in range(self.n_sites)],
                       np.float32)
        sol = kmeans_minus_minus(
            jnp.asarray(pts), jnp.ones((self.n_sites,), jnp.float32),
            jnp.ones((self.n_sites,), bool), jax.random.key(0),
            k=1, t=float(t), iters=8)
        out = np.asarray(sol.outlier)
        # only call someone a straggler if they are SLOW outliers AND
        # meaningfully far from the healthy cluster (k-means-- always labels
        # the farthest budget-mass as outliers; significance-gate it)
        center = float(np.asarray(sol.centers)[0, 0])
        inlier_std = float(pts[~out, 0].std()) if (~out).any() else 0.0
        gate = center + max(4.0 * inlier_std, 0.25 * center)
        return out & (pts[:, 0] > gate)

    def policy(self, mask: np.ndarray) -> dict:
        """Suggested mitigation per flagged site."""
        return {int(i): ("redispatch" if np.mean(self.history[i]) <
                         3.0 * (self._ewma or 1.0) else "drop")
                for i in np.nonzero(mask)[0]}
