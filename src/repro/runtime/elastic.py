"""Elastic training runtime: checkpoint/restart + mesh shrink/grow on
(simulated) node failure, deterministic data replay.

The contract with real hardware: a node failure surfaces as an exception
from the step function (XLA raises on a dead peer) or as a missing
heartbeat; the runner then (1) rebuilds the largest usable mesh from the
surviving devices, (2) re-jits the step for the new mesh, (3) restores the
last published checkpoint with cross-mesh resharding (checkpoint/manager
stores leaves unsharded), and (4) replays the data cursor — the pipeline is
stateless-addressable so `step` is the only cursor (data/tokens.py).

This module is hardware-agnostic: `DeviceFailure` is raised by the fault
injector in tests/examples, and by a heartbeat watchdog in a real
deployment.  Global batch is preserved across re-meshes (per-device batch
rescales), so the training trajectory stays comparable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


class DeviceFailure(RuntimeError):
    """Raised when a device/host is lost (injected in tests; mapped from
    runtime errors in deployment)."""


@dataclass
class ElasticConfig:
    ckpt_every: int = 20
    max_failures: int = 8
    min_devices: int = 1


@dataclass
class ElasticRunner:
    make_step: Callable          # (mesh) -> step_fn(state, batch) -> state, metrics
    init_state: Callable         # (mesh) -> state pytree
    state_shardings: Callable    # (mesh, state_like) -> shardings pytree
    data_fn: Callable            # (step) -> batch (numpy, global)
    ckpt: CheckpointManager
    cfg: ElasticConfig = field(default_factory=ElasticConfig)

    def _usable_devices(self, devices):
        """Largest power-of-two prefix (keeps meshes well-shaped)."""
        n = 1 << int(math.log2(max(len(devices), 1)))
        return devices[:n]

    def make_mesh(self, devices):
        devs = self._usable_devices(devices)
        return jax.make_mesh((len(devs),), ("data",), devices=devs)

    def run(self, n_steps: int, devices=None, fail_at: dict | None = None):
        """fail_at: {step: n_devices_to_kill} fault injection for tests.
        Returns (state, log)."""
        devices = list(devices or jax.devices())
        fail_at = fail_at or {}
        log = {"remesh_steps": [], "device_counts": [], "losses": []}

        mesh = self.make_mesh(devices)
        step_fn = self.make_step(mesh)
        state = self.init_state(mesh)
        start = 0
        if self.ckpt.latest_step() is not None:
            state, start = self.ckpt.restore(
                state, shardings=self.state_shardings(mesh, state))
            start += 1

        step = start
        failures = 0
        while step < n_steps:
            try:
                if step in fail_at:
                    kill = fail_at.pop(step)
                    devices = devices[: max(len(devices) - kill,
                                            self.cfg.min_devices)]
                    raise DeviceFailure(f"lost {kill} devices at step {step}")
                batch = self.data_fn(step)
                state, metrics = step_fn(state, batch)
                log["losses"].append(float(metrics.get("loss", np.nan)))
                log["device_counts"].append(mesh.devices.size)
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step, state)
                step += 1
            except DeviceFailure as e:
                failures += 1
                if failures > self.cfg.max_failures:
                    raise RuntimeError("too many failures") from e
                # --- elastic re-mesh ---
                mesh = self.make_mesh(devices)
                step_fn = self.make_step(mesh)
                state_like = self.init_state(mesh)
                try:
                    state, last = self.ckpt.restore(
                        state_like, shardings=self.state_shardings(mesh, state_like))
                    step = last + 1
                except FileNotFoundError:
                    state, step = state_like, 0
                log["remesh_steps"].append(step)
        self.ckpt.wait()
        return state, log
