"""Byzantine-robust gradient aggregation via the paper's outlier detection.

Each data-parallel replica sketches its gradient (fixed-seed Rademacher
projection of every leaf into R^PROJ, concatenated and normalized) — the
sketches of honest replicas concentrate, corrupted ones are outliers.  This
is exactly (k=1, t)-means over s points in R^PROJ, so we reuse the paper's
machinery: all replicas see all sketches after one all_gather (the paper's
one-round coordinator model again), each replica deterministically runs
k-means-- (k=1) on them, masks the flagged replicas, and psums only the
honest gradients (rescaled).

Runs inside shard_map over the data axis; deterministic across replicas so
no extra coordination round is needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kmeans_mm import kmeans_minus_minus

PROJ = 64


def _leaf_sketch(g, key):
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    # fixed Rademacher projection: chunked matmul-free sketch
    sign = jax.random.rademacher(key, (PROJ, min(n, 4096)), jnp.float32)
    take = flat[: sign.shape[1]]
    return sign @ take


def sketch(grads, seed: int = 0) -> jnp.ndarray:
    """(PROJ,) sketch of a gradient pytree. Same seed on every replica."""
    leaves = jax.tree_util.tree_leaves(grads)
    keys = jax.random.split(jax.random.key(seed), len(leaves))
    s = sum(_leaf_sketch(g, k) for g, k in zip(leaves, keys))
    return s / jnp.maximum(jnp.linalg.norm(s), 1e-9)


def robust_mean_grads(grads, axis: str, *, byzantine_budget: int = 1,
                      seed: int = 0):
    """Inside shard_map over `axis`: returns (robust mean grads, mask_info).

    mask_info = (honest_count, my_outlier_flag)."""
    s = sketch(grads, seed)
    all_s = jax.lax.all_gather(s, axis)           # (n_replicas, PROJ)
    n = all_s.shape[0]
    sol = kmeans_minus_minus(
        all_s, jnp.ones((n,), jnp.float32), jnp.ones((n,), bool),
        jax.random.key(seed + 1), k=1, t=float(byzantine_budget), iters=8)
    # significance gate: k-means-- always labels the farthest budget-mass as
    # outliers; only reject replicas well outside the honest concentration.
    d = sol.distances
    inl = ~sol.outlier
    nh0 = jnp.maximum(inl.sum(), 1)
    mu = jnp.sum(jnp.where(inl, d, 0.0)) / nh0
    sd = jnp.sqrt(jnp.sum(jnp.where(inl, (d - mu) ** 2, 0.0)) / nh0)
    gate = mu + 4.0 * sd + 1e-6
    honest = ~(sol.outlier & (d > gate))           # (n,) same on all replicas
    me = jax.lax.axis_index(axis)
    my_ok = honest[me]
    n_honest = jnp.maximum(honest.sum(), 1)
    masked = jax.tree.map(
        lambda g: jnp.where(my_ok, g.astype(jnp.float32), 0.0), grads)
    mean = jax.tree.map(
        lambda g: jax.lax.psum(g, axis) / n_honest.astype(jnp.float32), masked)
    return mean, (n_honest, ~my_ok)
