"""Pluggable summarizer subsystem — the summarize-layer twin of
``repro.kernels.dispatch``.

One protocol (weighted points in, mass-conserving ``WeightedSummary``
out), one ``SummarizerPolicy(name, params)`` threaded through
``distributed_cluster``, the stream tree's leaf/reduce steps and the
benchmarks, and a registry where each algorithm lands as one entry:
``paper`` (Algorithm 1/2, the auto default), ``uniform`` (reservoir
baseline), ``ball_cover`` (heavy-noise aggregation) and ``coreset``
(sensitivity sampling, any metric).  See ``base.py`` for the contract and
``benchmarks/summarizer_bench.py`` for the head-to-head.
"""
from repro.summarize.base import (  # noqa: F401
    SummarizerPolicy, SummarizerSpec, get_default_summarizer,
    get_summarizer, record_bound, reduce_summaries, register_summarizer,
    registered_summarizers, resolve_summarizer, select_summarizer,
    set_default_summarizer, site_summary, summarize, summarizer_policy,
    using_summarizer,
)
