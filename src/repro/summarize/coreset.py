"""The ``coreset`` summarizer: k-means||-seeded sensitivity sampling.

In the spirit of Dandolo et al. (arXiv:2202.08173): a coreset for
k-means/median with outliers in general metric spaces, built from any
distance oracle — here every metric the pdist registry serves, including
``cosine`` (which the paper's ball-growing was never run on).

Construction over weighted records (x_i, w_i):

1. **Seed** with a weighted k-means|| pass (Bahmani et al.): ``seed_rounds``
   rounds each drawing ``ceil(seed_budget / seed_rounds)`` records with
   probability ∝ w * D(x, S)^p, D refreshed once per round — the few-round
   distributed-friendly alternative to k-means++'s sequential seeding.
2. **Sensitivity** of record i with nearest seed j(i) and seed-cluster
   mass M_j:  s_i = w_i d_i / Σ w d  +  w_i / (|S| M_{j(i)})  — the
   standard upper bound on how much any single record can matter to any
   (k, t) solution.
3. **Sample** ``budget`` records with replacement ∝ s_i, weight each
   unique pick c_i w_i / (budget p_i), then rescale so the output mass
   equals the input mass *exactly* (the registry's composability
   contract; the rescale is a vanishing-variance correction).

No outlier candidates: sensitivity sampling keeps far records with high
probability but does not certify them, so ``paper`` remains the choice
when candidate provenance matters (preRec in the benchmark shows this).
"""
from __future__ import annotations

import math

import numpy as np
import jax

from repro.summarize.base import (clean_weighted_input, empty_summary,
                                  register_summarizer)

_EPS = 1e-30


def _summarize(points, weights, key, *, k, t, alpha, beta, metric,
               kernel_policy, budget=None, seed_budget=None,
               seed_rounds: int = 4):
    from repro.stream.weighted import (WeightedSummary, _min_argmin_bucketed,
                                       categorical_by_weight)

    x, w, orig, total = clean_weighted_input(points, weights)
    n = x.shape[0]
    if n == 0:
        return empty_summary(np.asarray(points, np.float32).shape[-1])
    b = int(budget) if budget is not None else default_budget(n, k, t)
    b = max(1, min(b, n))
    sb = int(seed_budget) if seed_budget is not None else max(2, 2 * k)
    sb = min(sb, n)
    rounds = max(1, min(int(seed_rounds), sb))
    ell = -(-sb // rounds)

    # --- 1. weighted k-means|| seeding ---
    mind = np.full((n,), np.inf, np.float32)
    seed_ids: list[np.ndarray] = []
    for r in range(rounds):
        key, sk = jax.random.split(key)
        score = w if r == 0 else w * mind
        if float(score.sum()) <= 0.0:
            score = w
        pick = categorical_by_weight(sk, np.maximum(score, _EPS), (ell,))
        seed_ids.append(pick)
        d_new, _ = _min_argmin_bucketed(x, x[pick], metric=metric,
                                       policy=kernel_policy)
        mind = np.minimum(mind, d_new)
    seeds = np.unique(np.concatenate(seed_ids))
    mind, amin = _min_argmin_bucketed(x, x[seeds], metric=metric,
                                     policy=kernel_policy)

    # --- 2. sensitivities ---
    cluster_mass = np.zeros((seeds.size,), np.float64)
    np.add.at(cluster_mass, amin, w.astype(np.float64))
    wd = w.astype(np.float64) * mind
    sens = (wd / max(wd.sum(), _EPS)
            + w / (seeds.size * np.maximum(cluster_mass[amin], _EPS)))
    probs = sens / sens.sum()

    # --- 3. importance-sample the coreset ---
    key, sk = jax.random.split(key)
    pick = categorical_by_weight(sk, np.maximum(probs.astype(np.float32),
                                                _EPS), (b,))
    uniq, counts = np.unique(pick, return_counts=True)
    wts = counts * w[uniq] / (b * np.maximum(probs[uniq], _EPS))
    wts = wts * (total / max(float(wts.sum()), _EPS))   # exact conservation
    return WeightedSummary(points=x[uniq].astype(np.float32),
                           weights=wts.astype(np.float32),
                           is_candidate=np.zeros(uniq.size, bool),
                           n_rounds=rounds,
                           total_weight=total,
                           indices=orig[uniq])


def default_budget(n: int, k: int, t: int) -> int:
    """Size-comparable with the paper summary: O(k log n) + the 8t slots
    Algorithm 1 would spend on candidates."""
    kappa = max(k, max(1, math.ceil(math.log(max(n, 2)))))
    return int(2 * kappa * max(1, math.ceil(math.log(max(n, 2)))) + 8 * t)


def _record_bound(params, *, k, t, alpha, beta, max_points, leaf_size):
    b = params.get("budget")
    if b is not None:
        return int(b) + 1
    return default_budget(int(max_points), k, t) + 1


register_summarizer(
    "coreset",
    summarize=_summarize,
    supports=lambda metric, k, t: True,
    priority=2,
    record_bound=_record_bound,
    description="k-means||-seeded sensitivity-sampling coreset "
                "(Dandolo et al. flavor); any metric incl. cosine",
    sized=True,
)
