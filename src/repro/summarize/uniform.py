"""The ``uniform`` summarizer: weighted reservoir sampling baseline.

Generalizes ``repro.core.rand_summary`` (the paper's ``rand`` baseline) to
weighted inputs: sample ``budget`` records without replacement with
inclusion probability ∝ weight (the Efraimidis–Spirakis exponential-key
reservoir, computed in log space), then assign every input record's full
mass to its nearest sample — so the output conserves mass exactly, like
every registered summarizer.

No outlier candidates: this is precisely why the baseline fails at outlier
detection in the paper's Tables 2–4, and why the quality benchmark
(`benchmarks/summarizer_bench.py`) expects ``paper`` to beat it on recall
at matched summary size.  Never auto-picked (priority < 0): you ask for a
baseline by name.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.summarize.base import (clean_weighted_input, empty_summary,
                                  register_summarizer)


def default_budget(n: int, k: int, t: int) -> int:
    """The paper's baseline budget O(k log n + t)."""
    from repro.core.kmeans_pp import pp_budget

    return pp_budget(n, k, t)


def _summarize(points, weights, key, *, k, t, alpha, beta, metric,
               kernel_policy, budget=None):
    from repro.stream.weighted import WeightedSummary, _min_argmin_bucketed

    x, w, orig, total = clean_weighted_input(points, weights)
    n = x.shape[0]
    if n == 0:
        return empty_summary(np.asarray(points, np.float32).shape[-1])
    b = int(budget) if budget is not None else default_budget(n, k, t)
    b = max(1, min(b, n))
    if b == n:
        idx = np.arange(n)
    else:
        # A-ES reservoir keys u^(1/w): maximize log(u)/w instead (log u < 0)
        u = np.asarray(jax.random.uniform(key, (n,), minval=1e-12,
                                          maxval=1.0), np.float64)
        keys = np.log(u) / w
        idx = np.sort(np.argpartition(-keys, b - 1)[:b])
    mind, amin = _min_argmin_bucketed(x, x[idx], metric=metric,
                                     policy=kernel_policy)
    acc = np.zeros((b,), np.float32)
    np.add.at(acc, amin, w)
    live = acc > 0   # coincident samples can tie to zero mass; drop them
    return WeightedSummary(points=x[idx[live]].astype(np.float32),
                           weights=acc[live],
                           is_candidate=np.zeros(int(live.sum()), bool),
                           n_rounds=1, total_weight=total,
                           indices=orig[idx[live]])


def _site_summary(x, key, *, k, t, alpha, beta, metric, kernel_policy,
                  budget=None):
    from repro.core.rand_summary import rand_summary

    n = int(x.shape[0])
    b = int(budget) if budget is not None else default_budget(n, k, t)
    return rand_summary(x, key, budget=max(1, min(b, n)), metric=metric,
                        policy=kernel_policy)


def _record_bound(params, *, k, t, alpha, beta, max_points, leaf_size):
    b = params.get("budget")
    if b is not None:
        return int(b) + 1
    return default_budget(int(max_points), k, t) + 1


register_summarizer(
    "uniform",
    summarize=_summarize,
    site_summary=_site_summary,
    supports=lambda metric, k, t: True,
    priority=-1,   # baseline: by name only, never auto-picked
    record_bound=_record_bound,
    description="weighted reservoir sample + nearest-sample mass "
                "(the paper's rand baseline); no outlier candidates",
    sized=True,
)
