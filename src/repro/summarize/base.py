"""Summarizer registry: pluggable summary construction for every layer.

The paper's whole pipeline is "build a small summary per site, cluster the
union" — and until this module, summary construction was the one layer
still hard-wired: ``distributed_cluster``, ``StreamTree`` and
``ShardedStreamService`` called Algorithm 1/2 directly.  This module is
the summarize-layer twin of ``repro.kernels.dispatch``:

* a **registry** of summarizers, each registered under a name with a
  capability predicate over (metric, k, t) and an auto-selection priority;
* one **``SummarizerPolicy``** frozen dataclass ``(name, params)`` — the
  single object threaded through ``core/distributed.py``, the stream tree
  reduce step and the benchmarks, or installed process-wide with
  ``set_default_summarizer``;
* a uniform **protocol**: weighted points in, mass-conserving
  ``repro.stream.weighted.WeightedSummary`` out.  Mass conservation is the
  contract that makes every implementation compose with merge-and-reduce
  (unions of summaries represent unions of data) and with Algorithm 3's
  second level (the union's total weight equals ``n``).

Registered implementations (see the sibling modules):

  ``paper``      — Algorithm 1 / Algorithm 2 / the weighted generalization;
                   the site path auto-selects augmented when t >> k.
  ``uniform``    — weighted reservoir sampling + nearest-sample mass
                   (the paper's cheap ``rand`` baseline, generalized).
  ``ball_cover`` — ball-cover aggregation robust to heavy noise
                   (Guo & Li, arXiv:1810.07852 flavor): per-round sample
                   balls, fold low-mass balls into heavy ones so noise
                   points never survive as centers.
  ``coreset``    — k-means||-seeded sensitivity-sampling coreset in the
                   spirit of Dandolo et al. (arXiv:2202.08173); any metric
                   with a distance oracle, including ``cosine``.

Unlike the kernel registry — where an explicit-but-unsupported backend
falls back to auto selection, because backends compute the same function —
an explicit summarizer that cannot serve a call **raises**: summarizers
are different algorithms with different outputs, so a silent substitution
would change results.

This module deliberately imports nothing from ``repro.stream`` at module
scope (the stream tree imports *us*); implementation modules are imported
lazily on first registry use, exactly like the kernel-op modules.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, NamedTuple, Optional, Sequence, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # avoid the stream <-> summarize import cycle at runtime
    from repro.core.summary import Summary
    from repro.stream.weighted import WeightedSummary


@dataclasses.dataclass(frozen=True)
class SummarizerPolicy:
    """The one summary-algorithm selection object threaded through layers.

    name    — "auto" (pick the best-supported registered summarizer for
              this (metric, k, t)), or an explicit registry name.
    params  — algorithm parameters as a sorted tuple of (key, value) pairs
              so the policy stays hashable (dicts are accepted and
              canonicalized).  Use :func:`summarizer_policy` for keyword
              ergonomics: ``summarizer_policy("coreset", budget=512)``.
    """

    name: str = "auto"
    params: tuple = ()

    def __post_init__(self):
        p = self.params
        if isinstance(p, dict):
            p = p.items()
        object.__setattr__(self, "params", tuple(sorted(tuple(p))))

    def params_dict(self) -> dict:
        return dict(self.params)

    def with_params(self, **updates) -> "SummarizerPolicy":
        merged = {**self.params_dict(), **updates}
        return SummarizerPolicy(self.name, tuple(sorted(merged.items())))


def summarizer_policy(name: str = "auto", **params) -> SummarizerPolicy:
    """Keyword-friendly constructor: ``summarizer_policy("uniform", budget=256)``."""
    return SummarizerPolicy(name, tuple(sorted(params.items())))


class SummarizerSpec(NamedTuple):
    """One registered summary algorithm.

    summarize     — (points, weights, key, *, k, t, alpha, beta, metric,
                    kernel_policy, **params) -> WeightedSummary.  Host-driven
                    (numpy set logic, jitted distance inner loops), mass
                    conserving, ``indices`` populated with input-row ids.
    site_summary  — optional fixed-shape unit-weight path
                    (x, key, *, k, t, alpha, beta, metric, kernel_policy,
                    **params) -> core.summary.Summary, jit/shard_map safe —
                    what ``distributed_cluster`` runs per site.  None when
                    the algorithm is host-driven only.
    supports      — (metric, k, t) -> bool capability predicate.
    priority      — auto-selection priority; < 0 means never auto-picked
                    (baselines you must ask for by name).
    record_bound  — (params, *, k, t, alpha, beta, max_points, leaf_size)
                    -> int static per-summary record capacity, used by the
                    stream tree for checkpoint packing.
    sized         — True when the algorithm accepts an external ``budget``
                    param (reservoir/coreset style); the benchmark uses
                    this to size-match baselines to the paper summary.
    """

    name: str
    summarize: Callable
    supports: Callable
    priority: int
    record_bound: Callable
    description: str
    site_summary: Optional[Callable] = None
    sized: bool = False


_REGISTRY: dict[str, SummarizerSpec] = {}
_default_policy = SummarizerPolicy()
_registered = False


def _ensure_registered() -> None:
    """Import the implementation modules so they land in the registry."""
    global _registered
    if _registered:
        return
    _registered = True
    from repro.summarize import ball_cover as _bc    # noqa: F401
    from repro.summarize import coreset as _cs       # noqa: F401
    from repro.summarize import paper as _paper      # noqa: F401
    from repro.summarize import uniform as _uni      # noqa: F401


def register_summarizer(
    name: str,
    *,
    summarize: Callable,
    supports: Callable,
    priority: int,
    record_bound: Callable,
    description: str,
    site_summary: Optional[Callable] = None,
    sized: bool = False,
) -> SummarizerSpec:
    spec = SummarizerSpec(name=name, summarize=summarize, supports=supports,
                          priority=priority, record_bound=record_bound,
                          description=description, site_summary=site_summary,
                          sized=sized)
    _REGISTRY[name] = spec
    return spec


def registered_summarizers() -> dict[str, SummarizerSpec]:
    _ensure_registered()
    return dict(_REGISTRY)


def get_summarizer(name: str) -> SummarizerSpec:
    _ensure_registered()
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(f"unknown summarizer {name!r}; "
                         f"registered: {sorted(_REGISTRY)}")
    return spec


# --------------------------------------------------------------- policy state
def get_default_summarizer() -> SummarizerPolicy:
    return _default_policy


def set_default_summarizer(policy: SummarizerPolicy) -> SummarizerPolicy:
    """Install ``policy`` process-wide; returns the previous default."""
    global _default_policy
    prev = _default_policy
    _default_policy = policy
    return prev


@contextlib.contextmanager
def using_summarizer(policy: SummarizerPolicy):
    """Context manager: scoped :func:`set_default_summarizer`."""
    prev = set_default_summarizer(policy)
    try:
        yield policy
    finally:
        set_default_summarizer(prev)


def resolve_summarizer(policy: Optional[SummarizerPolicy]) -> SummarizerPolicy:
    return policy if policy is not None else get_default_summarizer()


def select_summarizer(
    policy: Optional[SummarizerPolicy] = None,
    *,
    metric: str,
    k: int,
    t: int,
) -> SummarizerSpec:
    """Pick the spec serving this call under ``policy``.

    Explicit names raise when unsupported (a different summarizer is a
    different algorithm, not an interchangeable implementation).
    """
    policy = resolve_summarizer(policy)
    _ensure_registered()
    if policy.name != "auto":
        spec = get_summarizer(policy.name)
        if not spec.supports(metric, k, t):
            raise ValueError(
                f"summarizer {policy.name!r} does not support "
                f"metric={metric!r} (k={k}, t={t})")
        return spec
    candidates = [s for s in _REGISTRY.values()
                  if s.priority >= 0 and s.supports(metric, k, t)]
    if not candidates:
        raise ValueError(
            f"no registered summarizer supports metric={metric!r} "
            f"(k={k}, t={t})")
    return max(candidates, key=lambda s: s.priority)


# ----------------------------------------------------------------- entry points
def summarize(
    points,
    weights,
    key,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[SummarizerPolicy] = None,
    kernel_policy=None,
) -> "WeightedSummary":
    """Weighted records in -> mass-conserving ``WeightedSummary`` out.

    The one entry point the stream tree's leaf flush and reduce step, the
    host-side coordinator and the benchmarks all funnel through; ``policy``
    selects the algorithm, ``kernel_policy`` the distance backend.
    """
    policy = resolve_summarizer(policy)
    spec = select_summarizer(policy, metric=metric, k=k, t=t)
    return spec.summarize(points, weights, key, k=k, t=t, alpha=alpha,
                          beta=beta, metric=metric,
                          kernel_policy=kernel_policy, **policy.params_dict())


def reduce_summaries(
    summaries: Sequence["WeightedSummary"],
    key,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[SummarizerPolicy] = None,
    kernel_policy=None,
) -> "WeightedSummary":
    """Merge (concatenate; lossless) then re-summarize under ``policy``.

    The registry-dispatched generalization of
    ``repro.stream.weighted.resummarize``; with the default policy it is
    that function, bit for bit.
    """
    from repro.stream.weighted import merge_summaries

    merged = merge_summaries(summaries)
    if merged.points.shape[0] == 0:
        return merged
    return summarize(merged.points, merged.weights, key, k=k, t=t,
                     alpha=alpha, beta=beta, metric=metric, policy=policy,
                     kernel_policy=kernel_policy)


def site_summary(
    x,
    key,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[SummarizerPolicy] = None,
    kernel_policy=None,
) -> "Summary":
    """Fixed-shape unit-weight site path (jit / shard_map composable).

    Raises for summarizers without one (host-driven algorithms); those run
    through ``simulate_coordinator``'s host loop instead.
    """
    policy = resolve_summarizer(policy)
    spec = select_summarizer(policy, metric=metric, k=k, t=t)
    if spec.site_summary is None:
        raise ValueError(
            f"summarizer {spec.name!r} has no fixed-shape site path "
            f"(host-driven only); use simulate_coordinator or the weighted "
            f"summarize() entry point")
    return spec.site_summary(x, key, k=k, t=t, alpha=alpha, beta=beta,
                             metric=metric, kernel_policy=kernel_policy,
                             **policy.params_dict())


def record_bound(
    policy: Optional[SummarizerPolicy] = None,
    *,
    metric: str = "l2sq",
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    max_points: int,
    leaf_size: int,
) -> int:
    """Static per-summary record capacity under ``policy`` (tree packing)."""
    policy = resolve_summarizer(policy)
    spec = select_summarizer(policy, metric=metric, k=k, t=t)
    return int(spec.record_bound(policy.params_dict(), k=k, t=t, alpha=alpha,
                                 beta=beta, max_points=max_points,
                                 leaf_size=leaf_size))


# ------------------------------------------------------------- shared helpers
def clean_weighted_input(points, weights):
    """Canonicalize a weighted record set for the host-driven summarizers.

    Returns ``(x (n,d) f32, w (n,) f32, orig_ids (n,) i64, total float)``
    with zero-weight rows dropped; ``orig_ids`` maps kept rows back to the
    caller's row numbering so ``WeightedSummary.indices`` stays meaningful.
    """
    x = np.asarray(points, np.float32)
    w = np.asarray(weights, np.float32).reshape(-1)
    if x.ndim != 2 or x.shape[0] != w.shape[0]:
        raise ValueError(f"points {x.shape} / weights {w.shape} mismatch")
    keep = w > 0
    orig = np.nonzero(keep)[0]
    x, w = x[keep], w[keep]
    return x, w, orig, float(w.sum())


def empty_summary(d: int) -> "WeightedSummary":
    from repro.stream.weighted import WeightedSummary

    return WeightedSummary(points=np.zeros((0, d), np.float32),
                           weights=np.zeros((0,), np.float32),
                           is_candidate=np.zeros((0,), bool),
                           n_rounds=0, total_weight=0.0,
                           indices=np.zeros((0,), np.int64))
