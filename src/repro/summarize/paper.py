"""The ``paper`` summarizer: Algorithm 1 / Algorithm 2 / the weighted path.

Weighted path (stream tree leaves and merges, host-side coordinator):
``repro.stream.weighted.weighted_summary_outliers`` — Algorithm 1
generalized to weighted records (sampling ∝ weight, ball capture by weight
mass).  There is no weighted augmented variant (Algorithm 2's extra-center
reassignment needs the raw points, which a weighted record set no longer
has), so ``variant`` only affects the site path.

Site path (``distributed_cluster``'s fixed-shape per-site program):
``variant="auto"`` picks Algorithm 2 (augmented) when t >= 2k — the
t >> k regime where the 8t outlier candidates dwarf the O(k log n)
centers and augmentation provably lowers the information loss — and
Algorithm 1 otherwise.  ``variant="plain"``/``"augmented"`` force one.
Cosine always routes to Algorithm 1 (the augmented reassignment's
far-away padding sentinel is meaningless under a direction-only metric).
"""
from __future__ import annotations

import math

from repro.summarize.base import register_summarizer

AUGMENTED_T_OVER_K = 2  # variant="auto": augmented iff t >= this * k


def pick_augmented(variant: str, k: int, t: int, metric: str) -> bool:
    if variant not in ("auto", "plain", "augmented"):
        raise ValueError(f"unknown paper variant {variant!r}")
    if metric == "cosine":
        return False
    if variant != "auto":
        return variant == "augmented"
    return t >= AUGMENTED_T_OVER_K * k


def _summarize(points, weights, key, *, k, t, alpha, beta, metric,
               kernel_policy, variant: str = "auto"):
    from repro.stream.weighted import weighted_summary_outliers

    return weighted_summary_outliers(points, weights, key, k=k, t=t,
                                     alpha=alpha, beta=beta, metric=metric,
                                     policy=kernel_policy)


def _site_summary(x, key, *, k, t, alpha, beta, metric, kernel_policy,
                  variant: str = "auto"):
    from repro.core.augmented import augmented_summary_outliers
    from repro.core.summary import summary_outliers

    fn = (augmented_summary_outliers if pick_augmented(variant, k, t, metric)
          else summary_outliers)
    return fn(x, key, k=k, t=t, alpha=alpha, beta=beta, metric=metric,
              policy=kernel_policy)


def _record_bound(params, *, k, t, alpha, beta, max_points, leaf_size):
    """Centers <= rounds * m, candidates <= 8t (unit-or-heavier weights).

    Rounds depend only on the total mass (<= max_points); one fixed-point
    pass accounts for merges seeing up to 2*cap records, which can only
    grow kappa (and m) logarithmically.
    """
    from repro.stream.weighted import max_rounds

    rounds = max_rounds(float(max_points), t, beta)
    m = math.ceil(alpha * max(k, math.ceil(math.log(max(leaf_size, 2)))))
    cap = rounds * m + 8 * t + 1
    m = math.ceil(alpha * max(k, math.ceil(math.log(max(2 * cap, 2)))))
    return rounds * m + 8 * t + 1


register_summarizer(
    "paper",
    summarize=_summarize,
    site_summary=_site_summary,
    supports=lambda metric, k, t: True,
    priority=10,   # the paper's algorithm is the auto default everywhere
    record_bound=_record_bound,
    description="Summary-Outliers (Alg. 1/2; weighted for streams); "
                "site path auto-selects augmented when t >= 2k",
)
