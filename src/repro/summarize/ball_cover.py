"""The ``ball_cover`` summarizer: heavy-noise-robust ball-cover aggregation.

In the heavy-noise regime (t >> k, e.g. 10% scattered noise) Algorithm 1
has a known weakness: round samples are drawn uniformly from the remainder,
so noise points get sampled in proportion to their mass and *every sampled
point becomes a center* — the summary fills up with singleton noise balls.
Guo & Li (arXiv:1810.07852) fix this for distributed k-center/means with
outliers by aggregating the cover: only balls that capture a non-trivial
mass survive as centers.

This implementation keeps Algorithm 1's round structure (sample m records
∝ weight, grow the shared radius rho to the smallest value capturing a
beta fraction of the remaining mass — so the deterministic
ceil(log(W/8t)/-log(1-beta)) round bound is untouched) and adds the
aggregation step:

  * a sampled ball is **heavy** when it individually captures at least
    ``min_ball_frac * beta * W_i / m`` mass (its fair share of the round's
    capture, scaled down by ``min_ball_frac``);
  * captured records whose nearest sample is *light* are re-routed to
    their nearest **heavy** sample (one more tiny min_argmin over <= m
    centers), and only heavy samples survive as summary centers.

The captured set per round is identical to Algorithm 1's, so progress and
the round bound are unchanged; only center provenance differs.  Survivors
of the final round are outlier candidates (mass <= 8t), exactly like the
paper summarizer, so the second level still sees the true outliers.
"""
from __future__ import annotations

import math

import numpy as np
import jax

from repro.summarize.base import (clean_weighted_input, empty_summary,
                                  register_summarizer)


def _summarize(points, weights, key, *, k, t, alpha, beta, metric,
               kernel_policy, min_ball_frac: float = 0.5):
    from repro.stream.weighted import (_min_argmin_bucketed, WeightedSummary,
                                       categorical_by_weight, max_rounds)

    x, w, orig, total = clean_weighted_input(points, weights)
    n = x.shape[0]
    if n == 0:
        return empty_summary(np.asarray(points, np.float32).shape[-1])

    kappa = max(k, max(1, math.ceil(math.log(max(n, 2)))))
    m = max(1, int(math.ceil(alpha * kappa)))
    stop = max(8 * t, 1)
    bound = max_rounds(total, t, beta) + 4  # +4: fp slack on the mass sums

    remaining = np.arange(n, dtype=np.int64)
    acc_w = np.zeros(n, np.float32)
    center_ids: list[np.ndarray] = []
    rounds = 0
    while remaining.size and float(w[remaining].sum()) > stop and rounds < bound:
        key, sk = jax.random.split(key)
        wr = w[remaining]
        pick = categorical_by_weight(sk, wr, (m,))
        idx = remaining[pick]                 # global ids of this round's S_i
        mind, amin = _min_argmin_bucketed(x[remaining], x[idx], metric=metric,
                                          policy=kernel_policy)
        order = np.argsort(mind, kind="stable")
        cumw = np.cumsum(wr[order])
        kpos = int(np.searchsorted(cumw, beta * float(wr.sum())))
        kpos = min(kpos, order.size - 1)
        rho = mind[order[kpos]]
        captured = mind <= rho                # identical to Algorithm 1

        # --- aggregation: fold light balls into heavy ones ---
        ball_mass = np.zeros((m,), np.float32)
        np.add.at(ball_mass, amin[captured], wr[captured])
        heavy = ball_mass >= min_ball_frac * beta * float(wr.sum()) / m
        if heavy.any() and not heavy.all():
            light_pt = captured & ~heavy[amin]
            if light_pt.any():
                _, re_amin = _min_argmin_bucketed(
                    x[remaining[light_pt]], x[idx[heavy]], metric=metric,
                    policy=kernel_policy)
                np.add.at(acc_w, idx[heavy][re_amin], wr[light_pt])
            kept = captured & heavy[amin]
            np.add.at(acc_w, idx[amin[kept]], wr[kept])
            center_ids.append(np.unique(idx[heavy]))
        else:
            # no ball stands out (or all do): plain Algorithm 1 assignment
            np.add.at(acc_w, idx[amin[captured]], wr[captured])
            center_ids.append(np.unique(idx))
        remaining = remaining[~captured]
        rounds += 1

    centers = (np.unique(np.concatenate(center_ids)) if center_ids
               else np.empty(0, np.int64))
    centers = centers[acc_w[centers] > 0]
    pts = np.concatenate([x[centers], x[remaining]])
    wts = np.concatenate([acc_w[centers], w[remaining]])
    cand = np.concatenate([np.zeros(centers.size, bool),
                           np.ones(remaining.size, bool)])
    return WeightedSummary(points=pts.astype(np.float32),
                           weights=wts.astype(np.float32),
                           is_candidate=cand,
                           n_rounds=rounds,
                           total_weight=total,
                           indices=orig[np.concatenate([centers, remaining])])


def _record_bound(params, *, k, t, alpha, beta, max_points, leaf_size):
    # never more centers than the paper summarizer (a subset of its samples)
    from repro.summarize.paper import _record_bound as paper_bound

    return paper_bound({}, k=k, t=t, alpha=alpha, beta=beta,
                       max_points=max_points, leaf_size=leaf_size)


register_summarizer(
    "ball_cover",
    summarize=_summarize,
    supports=lambda metric, k, t: True,
    priority=5,    # auto falls back here only if paper ever opts out
    record_bound=_record_bound,
    description="Guo & Li-style ball-cover aggregation: light balls fold "
                "into heavy ones, robust to heavy (t >> k) noise",
)
