# Compute hot-spot kernels (pdist / lloyd / wkv): each op ships a Pallas
# TPU kernel, a chunked blocked path, and a pure-jnp reference oracle.
# Backend selection is centralized in `dispatch` — see KernelPolicy.
from repro.kernels.dispatch import (  # noqa: F401
    KernelPolicy, get_default_policy, set_default_policy, using_policy,
)
