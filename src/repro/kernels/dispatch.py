"""Unified kernel-dispatch layer: backend registry + autotuned block sizes.

Every compute hot-spot of the paper funnels through two ops — fused
nearest-center distance (``min_argmin``, Algorithm 1's ball-growing) and the
fused Lloyd step (``lloyd_step``, the coordinator's weighted k-means--).
Each op has several implementations (Pallas TPU kernel, chunked blocked
jnp, pure-jnp reference oracle) with different capabilities; historically
every caller hand-threaded ``use_pallas: bool`` + ``block_n: int`` and
re-implemented the same ``if use_pallas and metric in (...)`` dispatch
inline.  This module replaces that plumbing with:

* a **backend registry**: each implementation registers under a name
  (``"pallas"``, ``"blocked"``, ``"ref"``) with a capability predicate over
  (metric, platform, dtype, shape) and a platform-dependent auto-selection
  priority;
* one **``KernelPolicy``** frozen dataclass (backend, block_n, autotune) —
  the single object threaded through the algorithm layers, or installed
  process-wide with ``set_default_policy``.  ``backend="auto"`` picks the
  best supported implementation for the current platform (Pallas on TPU,
  blocked elsewhere) without the caller knowing;
* an **autotuner** that benchmarks candidate ``block_n`` tile sizes per
  (op, backend, metric, shape-bucket, platform) and caches the winner in a
  JSON file under ``~/.cache/repro_kernels/`` (override the location with
  ``$REPRO_KERNELS_CACHE``), so CPU blocked paths and TPU Pallas paths each
  get measured tiles instead of one hard-coded constant.

Resolution happens at trace time (shapes are concrete under ``jax.jit``),
so a jitted caller taking ``policy`` as a static argument compiles exactly
one registry decision per (shape, policy) — no runtime branching.

The pre-registry ``use_pallas=``/``block_n=`` keyword aliases survived one
release as deprecated warnings at the public API edges; they are now
removed — :func:`resolve_policy` raises a ``TypeError`` pointing at
``KernelPolicy`` when either is passed.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import numpy as np

from repro import obs

BACKENDS = ("auto", "pallas", "blocked", "ref")

OPS = ("min_argmin", "lloyd_step")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """The one kernel-selection object threaded through the algorithm layers.

    backend   — "auto" (pick per platform/capability), or an explicit
                registry name.  An explicit backend that cannot serve a
                particular call (e.g. the Pallas lloyd kernel under the l1
                metric) falls back to auto selection for that call, exactly
                like the inline ``if use_pallas and metric in (...)``
                branches it replaces.
    block_n   — row-tile size; None means "backend default, or autotuned
                when ``autotune`` is set".
    autotune  — measure candidate block_n values for this op/shape-bucket
                (cached on disk) instead of using the backend default.
    """

    backend: str = "auto"
    block_n: Optional[int] = None
    autotune: bool = False

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKENDS}")
        bn = self.block_n
        if bn is not None and (not isinstance(bn, int)
                               or isinstance(bn, bool) or bn < 1):
            raise ValueError(
                f"block_n must be None or an int >= 1, got {bn!r}")


class Registration(NamedTuple):
    """One backend implementation of one op."""

    op: str
    name: str
    impl: Callable                 # op-specific signature, kw block_n
    supports: Callable             # (metric, platform, dtype, n, m, d) -> bool
    priority: Callable             # platform -> int; < 0 means never auto-picked
    default_block_n: Callable      # platform -> int
    tune_candidates: tuple         # candidate block_n values for the autotuner
    make_args: Callable            # (n, m, d, rng) -> positional args for impl


_REGISTRY: dict[str, dict[str, Registration]] = {}
_default_policy = KernelPolicy()
_registered = False


def _ensure_registered() -> None:
    """Import the op modules so their backends land in the registry."""
    global _registered
    if _registered:
        return
    _registered = True
    from repro.kernels.lloyd import ops as _lloyd_ops   # noqa: F401
    from repro.kernels.pdist import ops as _pdist_ops   # noqa: F401


def register(
    op: str,
    name: str,
    *,
    supports: Callable,
    priority: Callable,
    default_block_n: Callable,
    tune_candidates: Sequence[int] = (),
    make_args: Callable = None,
):
    """Decorator: register ``fn`` as the ``name`` backend of ``op``."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")

    def deco(fn):
        _REGISTRY.setdefault(op, {})[name] = Registration(
            op=op, name=name, impl=fn, supports=supports, priority=priority,
            default_block_n=default_block_n,
            tune_candidates=tuple(tune_candidates),
            make_args=make_args)
        return fn

    return deco


def registered_backends(op: str) -> dict[str, Registration]:
    _ensure_registered()
    if op not in _REGISTRY:
        raise ValueError(f"no backends registered for op {op!r}")
    return _REGISTRY[op]


# --------------------------------------------------------------- policy state
def get_default_policy() -> KernelPolicy:
    return _default_policy


def set_default_policy(policy: KernelPolicy) -> KernelPolicy:
    """Install ``policy`` process-wide; returns the previous default."""
    global _default_policy
    prev = _default_policy
    _default_policy = policy
    return prev


@contextlib.contextmanager
def using_policy(policy: KernelPolicy):
    """Context manager: scoped :func:`set_default_policy`."""
    prev = set_default_policy(policy)
    try:
        yield policy
    finally:
        set_default_policy(prev)


def resolve_policy(
    policy: Optional[KernelPolicy] = None,
    *,
    use_pallas: Optional[bool] = None,
    block_n: Optional[int] = None,
    caller: str = "",
) -> KernelPolicy:
    """Resolve ``policy`` (default: the process policy) at a public edge.

    The pre-registry ``use_pallas=``/``block_n=`` keyword aliases were
    deprecated for one release and are now removed: passing either raises
    a ``TypeError`` naming the replacement (``use_pallas=True`` was
    ``KernelPolicy(backend="pallas")``; ``block_n=N`` was
    ``KernelPolicy(backend="blocked", block_n=N)``).
    """
    if use_pallas is not None or block_n is not None:
        raise TypeError(
            f"{caller or 'kernel op'}: the use_pallas=/block_n= keyword "
            f"aliases were removed; pass "
            f"policy=KernelPolicy(backend=..., block_n=...) or install a "
            f"process default with set_default_policy()")
    return policy if policy is not None else get_default_policy()


# ----------------------------------------------------------------- resolution
def select_backend(
    op: str,
    policy: Optional[KernelPolicy] = None,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    dtype=np.float32,
    platform: Optional[str] = None,
) -> Registration:
    """Pick the registration serving this call under ``policy``."""
    policy = policy if policy is not None else get_default_policy()
    platform = platform or jax.default_backend()
    regs = registered_backends(op)
    if policy.backend != "auto":
        reg = regs.get(policy.backend)
        if reg is None:
            raise ValueError(
                f"op {op!r} has no backend {policy.backend!r}; "
                f"registered: {sorted(regs)}")
        if reg.supports(metric, platform, dtype, n, m, d):
            return reg
        # Explicit-but-unsupported falls back to auto selection for this
        # call (the old inline `if use_pallas and metric in (...)` shape).
    candidates = [
        r for r in regs.values()
        if r.priority(platform) >= 0
        and r.supports(metric, platform, dtype, n, m, d)
    ]
    if not candidates:
        raise ValueError(
            f"no backend of op {op!r} supports metric={metric!r} on "
            f"platform {platform!r} for shape (n={n}, m={m}, d={d})")
    return max(candidates, key=lambda r: r.priority(platform))


def resolve(
    op: str,
    policy: Optional[KernelPolicy] = None,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    dtype=np.float32,
    platform: Optional[str] = None,
) -> tuple[Registration, int]:
    """Registry lookup: (registration, block_n) for one concrete call."""
    policy = policy if policy is not None else get_default_policy()
    platform = platform or jax.default_backend()
    reg = select_backend(op, policy, metric=metric, n=n, m=m, d=d,
                         dtype=dtype, platform=platform)
    # resolution happens at trace time, so under jit this counts compiled
    # registry decisions (one per shape/policy), not per-element calls
    obs.counter("kernels.dispatch", op=op, backend=reg.name).inc()
    bn = policy.block_n
    if bn is None:
        if policy.autotune and reg.tune_candidates:
            bn = autotune_block_n(op, reg.name, metric=metric, n=n, m=m, d=d,
                                  platform=platform)
        else:
            bn = reg.default_block_n(platform)
    return reg, int(bn)


# ------------------------------------------------------------------ autotuner
_TUNE_VERSION = 1
# Shapes at/above this row bucket share one measurement (bounds tuner cost).
_MAX_MEASURE_ROWS = 1 << 17
_tune_cache: Optional[dict] = None
_tuning = False   # re-entrancy guard: the measurement itself calls resolve()


def cache_dir() -> Path:
    return Path(os.environ.get(
        "REPRO_KERNELS_CACHE", "~/.cache/repro_kernels")).expanduser()


def _cache_path() -> Path:
    return cache_dir() / "autotune.json"


def _bucket(v: int, lo: int = 1) -> int:
    b = max(lo, 1)
    while b < v:
        b <<= 1
    return b


def _load_cache() -> dict:
    global _tune_cache
    if _tune_cache is None:
        try:
            _tune_cache = json.loads(_cache_path().read_text())
        except (OSError, ValueError):
            _tune_cache = {}
    return _tune_cache


def _store_cache(key: str, entry: dict) -> None:
    cache = _load_cache()
    cache[key] = entry
    try:
        cache_dir().mkdir(parents=True, exist_ok=True)
        tmp = _cache_path().with_suffix(".tmp")
        tmp.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")
        tmp.replace(_cache_path())
    except OSError:
        pass   # cache is an optimization; never fail the caller over it


def clear_autotune_cache(*, on_disk: bool = False) -> None:
    """Drop the in-memory autotune cache (and optionally the JSON file)."""
    global _tune_cache
    _tune_cache = None
    if on_disk:
        try:
            _cache_path().unlink()
        except OSError:
            pass


def _default_make_args(n: int, m: int, d: int, rng: np.random.Generator):
    x = rng.standard_normal((n, d)).astype(np.float32)
    c = rng.standard_normal((m, d)).astype(np.float32)
    return (x, c)


def measure_block_ns(
    op: str,
    backend: str,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    candidates: Optional[Sequence[int]] = None,
    repeats: int = 3,
) -> dict[int, float]:
    """Time ``op``'s ``backend`` impl at each candidate block_n (seconds)."""
    reg = registered_backends(op)[backend]
    cands = list(candidates if candidates is not None else reg.tune_candidates)
    if not cands:
        cands = [reg.default_block_n(jax.default_backend())]
    rng = np.random.default_rng(0)
    make = reg.make_args or _default_make_args
    args = make(n, m, d, rng)
    timings: dict[int, float] = {}
    for bn in cands:
        out = reg.impl(*args, metric=metric, block_n=bn)   # compile + warm
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = reg.impl(*args, metric=metric, block_n=bn)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        timings[bn] = best
    return timings


def autotune_block_n(
    op: str,
    backend: str,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    platform: Optional[str] = None,
    repeats: int = 3,
) -> int:
    """Best block_n for (op, backend, metric, shape-bucket, platform).

    Cached in ``cache_dir()/autotune.json``; one measurement per bucket.
    """
    global _tuning
    platform = platform or jax.default_backend()
    reg = registered_backends(op)[backend]
    if not reg.tune_candidates or _tuning:
        return reg.default_block_n(platform)
    bn_rows = min(_bucket(n), _MAX_MEASURE_ROWS)
    bm, bd = _bucket(m), _bucket(d)
    key = (f"v{_TUNE_VERSION}/{op}/{backend}/{platform}/{metric}/"
           f"n{bn_rows}/m{bm}/d{bd}")
    cache = _load_cache()
    hit = cache.get(key)
    if isinstance(hit, dict) and "block_n" in hit:
        obs.counter("kernels.autotune_cache", result="hit").inc()
        return int(hit["block_n"])
    obs.counter("kernels.autotune_cache", result="miss").inc()
    _tuning = True
    try:
        cands = sorted({min(c, bn_rows) for c in reg.tune_candidates})
        timings = measure_block_ns(op, backend, metric=metric, n=bn_rows,
                                   m=bm, d=bd, candidates=cands,
                                   repeats=repeats)
    finally:
        _tuning = False
    best = min(timings, key=timings.get)
    _store_cache(key, {
        "block_n": int(best),
        "timings_us": {str(bn): round(t * 1e6, 2)
                       for bn, t in timings.items()},
        "measured_shape": [bn_rows, bm, bd],
    })
    return int(best)
