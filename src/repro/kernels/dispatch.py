"""Unified kernel-dispatch layer: backend registry + autotuned block sizes.

Every compute hot-spot of the paper funnels through two ops — fused
nearest-center distance (``min_argmin``, Algorithm 1's ball-growing) and the
fused Lloyd step (``lloyd_step``, the coordinator's weighted k-means--).
Each op has several implementations (Pallas TPU kernel, chunked blocked
jnp, pure-jnp reference oracle) with different capabilities; historically
every caller hand-threaded ``use_pallas: bool`` + ``block_n: int`` and
re-implemented the same ``if use_pallas and metric in (...)`` dispatch
inline.  This module replaces that plumbing with:

* a **backend registry**: each implementation registers under a name
  (``"pallas"``, ``"blocked"``, ``"ref"``) with a capability predicate over
  (metric, platform, dtype, shape) and a platform-dependent auto-selection
  priority;
* one **``KernelPolicy``** frozen dataclass (backend, block_n, autotune) —
  the single object threaded through the algorithm layers, or installed
  process-wide with ``set_default_policy``.  ``backend="auto"`` picks the
  best supported implementation for the current platform (Pallas on TPU,
  blocked elsewhere) without the caller knowing;
* an **autotuner** that benchmarks candidate ``block_n`` tile sizes per
  (op, backend, metric, shape-bucket, platform) and caches the winner in a
  JSON file under ``~/.cache/repro_kernels/`` (override the location with
  ``$REPRO_KERNELS_CACHE``), so CPU blocked paths and TPU Pallas paths each
  get measured tiles instead of one hard-coded constant.

Resolution happens at trace time (shapes are concrete under ``jax.jit``),
so a jitted caller taking ``policy`` as a static argument compiles exactly
one registry decision per (shape, policy) — no runtime branching.

The pre-registry ``use_pallas=``/``block_n=`` keyword aliases survived one
release as deprecated warnings at the public API edges; they are now
removed — :func:`resolve_policy` raises a ``TypeError`` pointing at
``KernelPolicy`` when either is passed.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import logging
import os
import time
from pathlib import Path
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import numpy as np

from repro import obs

_log = logging.getLogger("repro.kernels.dispatch")

# "int8" is the quantized-center score backend: it changes results (bounded
# quantization error, measured in benchmarks/stream_bench.py), so it is
# never auto-picked — callers must name it explicitly.
BACKENDS = ("auto", "pallas", "blocked", "ref", "int8")

OPS = ("min_argmin", "lloyd_step", "score")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """The one kernel-selection object threaded through the algorithm layers.

    backend   — "auto" (pick per platform/capability), or an explicit
                registry name.  An explicit backend that cannot serve a
                particular call (e.g. the Pallas lloyd kernel under the l1
                metric) falls back to auto selection for that call, exactly
                like the inline ``if use_pallas and metric in (...)``
                branches it replaces.
    block_n   — row-tile size; None means "backend default, or autotuned
                when ``autotune`` is set".
    autotune  — measure candidate block_n values for this op/shape-bucket
                (cached on disk) instead of using the backend default.
    """

    backend: str = "auto"
    block_n: Optional[int] = None
    autotune: bool = False

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKENDS}")
        bn = self.block_n
        if bn is not None and (not isinstance(bn, int)
                               or isinstance(bn, bool) or bn < 1):
            raise ValueError(
                f"block_n must be None or an int >= 1, got {bn!r}")


class Registration(NamedTuple):
    """One backend implementation of one op.

    2-D ops (the fused ``score`` path) additionally register a center-tile
    dimension: ``default_block_m`` (platform -> int) plus
    ``tune_candidates_m``, and their ``impl`` accepts a ``block_m``
    keyword.  1-D ops leave both at their defaults and resolve through
    :func:`resolve` exactly as before.
    """

    op: str
    name: str
    impl: Callable                 # op-specific signature, kw block_n
    supports: Callable             # (metric, platform, dtype, n, m, d) -> bool
    priority: Callable             # platform -> int; < 0 means never auto-picked
    default_block_n: Callable      # platform -> int
    tune_candidates: tuple         # candidate block_n values for the autotuner
    make_args: Callable            # (n, m, d, rng) -> positional args for impl
    default_block_m: Optional[Callable] = None   # platform -> int (2-D ops)
    tune_candidates_m: tuple = ()  # candidate block_m values (2-D ops)


_REGISTRY: dict[str, dict[str, Registration]] = {}
_default_policy = KernelPolicy()
_registered = False


def _ensure_registered() -> None:
    """Import the op modules so their backends land in the registry."""
    global _registered
    if _registered:
        return
    _registered = True
    from repro.kernels.lloyd import ops as _lloyd_ops   # noqa: F401
    from repro.kernels.pdist import ops as _pdist_ops   # noqa: F401
    from repro.kernels.score import ops as _score_ops   # noqa: F401


def register(
    op: str,
    name: str,
    *,
    supports: Callable,
    priority: Callable,
    default_block_n: Callable,
    tune_candidates: Sequence[int] = (),
    make_args: Callable = None,
    default_block_m: Callable = None,
    tune_candidates_m: Sequence[int] = (),
):
    """Decorator: register ``fn`` as the ``name`` backend of ``op``."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")

    def deco(fn):
        _REGISTRY.setdefault(op, {})[name] = Registration(
            op=op, name=name, impl=fn, supports=supports, priority=priority,
            default_block_n=default_block_n,
            tune_candidates=tuple(tune_candidates),
            make_args=make_args,
            default_block_m=default_block_m,
            tune_candidates_m=tuple(tune_candidates_m))
        return fn

    return deco


def registered_backends(op: str) -> dict[str, Registration]:
    _ensure_registered()
    if op not in _REGISTRY:
        raise ValueError(f"no backends registered for op {op!r}")
    return _REGISTRY[op]


# --------------------------------------------------------------- policy state
def get_default_policy() -> KernelPolicy:
    return _default_policy


def set_default_policy(policy: KernelPolicy) -> KernelPolicy:
    """Install ``policy`` process-wide; returns the previous default."""
    global _default_policy
    prev = _default_policy
    _default_policy = policy
    return prev


@contextlib.contextmanager
def using_policy(policy: KernelPolicy):
    """Context manager: scoped :func:`set_default_policy`."""
    prev = set_default_policy(policy)
    try:
        yield policy
    finally:
        set_default_policy(prev)


def resolve_policy(
    policy: Optional[KernelPolicy] = None,
    *,
    use_pallas: Optional[bool] = None,
    block_n: Optional[int] = None,
    caller: str = "",
) -> KernelPolicy:
    """Resolve ``policy`` (default: the process policy) at a public edge.

    The pre-registry ``use_pallas=``/``block_n=`` keyword aliases were
    deprecated for one release and are now removed: passing either raises
    a ``TypeError`` naming the replacement (``use_pallas=True`` was
    ``KernelPolicy(backend="pallas")``; ``block_n=N`` was
    ``KernelPolicy(backend="blocked", block_n=N)``).
    """
    if use_pallas is not None or block_n is not None:
        raise TypeError(
            f"{caller or 'kernel op'}: the use_pallas=/block_n= keyword "
            f"aliases were removed; pass "
            f"policy=KernelPolicy(backend=..., block_n=...) or install a "
            f"process default with set_default_policy()")
    return policy if policy is not None else get_default_policy()


# ----------------------------------------------------------------- resolution
def select_backend(
    op: str,
    policy: Optional[KernelPolicy] = None,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    dtype=np.float32,
    platform: Optional[str] = None,
) -> Registration:
    """Pick the registration serving this call under ``policy``."""
    policy = policy if policy is not None else get_default_policy()
    platform = platform or jax.default_backend()
    regs = registered_backends(op)
    if policy.backend != "auto":
        reg = regs.get(policy.backend)
        if reg is None:
            raise ValueError(
                f"op {op!r} has no backend {policy.backend!r}; "
                f"registered: {sorted(regs)}")
        if reg.supports(metric, platform, dtype, n, m, d):
            return reg
        # Explicit-but-unsupported falls back to auto selection for this
        # call (the old inline `if use_pallas and metric in (...)` shape).
    candidates = [
        r for r in regs.values()
        if r.priority(platform) >= 0
        and r.supports(metric, platform, dtype, n, m, d)
    ]
    if not candidates:
        raise ValueError(
            f"no backend of op {op!r} supports metric={metric!r} on "
            f"platform {platform!r} for shape (n={n}, m={m}, d={d})")
    return max(candidates, key=lambda r: r.priority(platform))


def resolve(
    op: str,
    policy: Optional[KernelPolicy] = None,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    dtype=np.float32,
    platform: Optional[str] = None,
) -> tuple[Registration, int]:
    """Registry lookup: (registration, block_n) for one concrete call."""
    policy = policy if policy is not None else get_default_policy()
    platform = platform or jax.default_backend()
    reg = select_backend(op, policy, metric=metric, n=n, m=m, d=d,
                         dtype=dtype, platform=platform)
    # resolution happens at trace time, so under jit this counts compiled
    # registry decisions (one per shape/policy), not per-element calls
    obs.counter("kernels.dispatch", op=op, backend=reg.name).inc()
    bn = policy.block_n
    if bn is None:
        if policy.autotune and reg.tune_candidates:
            bn = autotune_block_n(op, reg.name, metric=metric, n=n, m=m, d=d,
                                  platform=platform)
        else:
            bn = reg.default_block_n(platform)
    return reg, int(bn)


def resolve_tiles(
    op: str,
    policy: Optional[KernelPolicy] = None,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    dtype=np.float32,
    platform: Optional[str] = None,
) -> tuple[Registration, int, int]:
    """Registry lookup for a 2-D-tiled op: (registration, block_n, block_m).

    Like :func:`resolve`, but also resolves the center-tile size for ops
    registered with ``default_block_m``.  An explicit ``policy.block_n``
    pins the row tile (and disables the joint tuner — exactly the 1-D
    semantics); otherwise, under ``policy.autotune``, the (block_n,
    block_m) pair is measured *jointly* per shape bucket and cached.
    """
    policy = policy if policy is not None else get_default_policy()
    platform = platform or jax.default_backend()
    reg = select_backend(op, policy, metric=metric, n=n, m=m, d=d,
                         dtype=dtype, platform=platform)
    obs.counter("kernels.dispatch", op=op, backend=reg.name).inc()
    if reg.default_block_m is None:
        # a 1-D backend serving a 2-D op entry point: column tile unused
        bn = policy.block_n
        if bn is None:
            bn = (autotune_block_n(op, reg.name, metric=metric, n=n, m=m,
                                   d=d, platform=platform)
                  if policy.autotune and reg.tune_candidates
                  else reg.default_block_n(platform))
        return reg, int(bn), 0
    bn, bm = policy.block_n, None
    if bn is None and policy.autotune and reg.tune_candidates:
        bn, bm = autotune_tiles(op, reg.name, metric=metric, n=n, m=m, d=d,
                                platform=platform)
    if bn is None:
        bn = reg.default_block_n(platform)
    if bm is None:
        bm = reg.default_block_m(platform)
    return reg, int(bn), int(bm)


# ------------------------------------------------------------------ autotuner
# v2: 2-D ops cache the jointly-tuned (block_n, block_m) pair.  The bump
# changes the key prefix, so pre-bump entries simply never match — and any
# entry that *does* match a key but lacks the fields its reader needs
# (e.g. a single-block_n record left under a 2-D op's key) is skipped with
# a debug log and re-measured, never a KeyError.
_TUNE_VERSION = 2
# Shapes at/above this row bucket share one measurement (bounds tuner cost).
_MAX_MEASURE_ROWS = 1 << 17
_tune_cache: Optional[dict] = None
_tuning = False   # re-entrancy guard: the measurement itself calls resolve()


def cache_dir() -> Path:
    return Path(os.environ.get(
        "REPRO_KERNELS_CACHE", "~/.cache/repro_kernels")).expanduser()


def _cache_path() -> Path:
    return cache_dir() / "autotune.json"


def _bucket(v: int, lo: int = 1) -> int:
    b = max(lo, 1)
    while b < v:
        b <<= 1
    return b


def _load_cache() -> dict:
    global _tune_cache
    if _tune_cache is None:
        try:
            _tune_cache = json.loads(_cache_path().read_text())
        except (OSError, ValueError):
            _tune_cache = {}
        stale = [k for k in _tune_cache
                 if not k.startswith(f"v{_TUNE_VERSION}/")]
        if stale:
            _log.debug("autotune cache %s holds %d entr%s from older schema "
                       "versions (e.g. %s); they are ignored, not migrated",
                       _cache_path(), len(stale),
                       "y" if len(stale) == 1 else "ies", stale[0])
    return _tune_cache


def _cache_hit(key: str, required: Sequence[str]) -> Optional[dict]:
    """Cached entry for ``key`` iff it carries every ``required`` field.

    A matching key with missing fields (a stale single-``block_n`` record
    under a 2-D op's key, or a hand-edited file) is skipped with a debug
    log and re-measured — the schema bump must never surface as a
    KeyError in a caller.
    """
    hit = _load_cache().get(key)
    if not isinstance(hit, dict):
        return None
    missing = [f for f in required if f not in hit]
    if missing:
        _log.debug("stale autotune entry %s (missing %s); re-measuring",
                   key, ", ".join(missing))
        return None
    return hit


def _store_cache(key: str, entry: dict) -> None:
    cache = _load_cache()
    cache[key] = entry
    try:
        cache_dir().mkdir(parents=True, exist_ok=True)
        tmp = _cache_path().with_suffix(".tmp")
        tmp.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")
        tmp.replace(_cache_path())
    except OSError:
        pass   # cache is an optimization; never fail the caller over it


def clear_autotune_cache(*, on_disk: bool = False) -> None:
    """Drop the in-memory autotune cache (and optionally the JSON file)."""
    global _tune_cache
    _tune_cache = None
    if on_disk:
        try:
            _cache_path().unlink()
        except OSError:
            pass


def _default_make_args(n: int, m: int, d: int, rng: np.random.Generator):
    x = rng.standard_normal((n, d)).astype(np.float32)
    c = rng.standard_normal((m, d)).astype(np.float32)
    return (x, c)


def _time_call(fn, *, repeats: int) -> float:
    out = fn()                       # compile + warm outside the clock
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_block_ns(
    op: str,
    backend: str,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    candidates: Optional[Sequence[int]] = None,
    repeats: int = 3,
) -> dict[int, float]:
    """Time ``op``'s ``backend`` impl at each candidate block_n (seconds)."""
    reg = registered_backends(op)[backend]
    cands = list(candidates if candidates is not None else reg.tune_candidates)
    if not cands:
        cands = [reg.default_block_n(jax.default_backend())]
    rng = np.random.default_rng(0)
    make = reg.make_args or _default_make_args
    args = make(n, m, d, rng)
    return {bn: _time_call(
        functools.partial(reg.impl, *args, metric=metric, block_n=bn),
        repeats=repeats) for bn in cands}


def measure_tiles(
    op: str,
    backend: str,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    candidates: Sequence[tuple[int, int]],
    repeats: int = 3,
) -> dict[tuple[int, int], float]:
    """Time a 2-D op's impl at each candidate (block_n, block_m) pair."""
    reg = registered_backends(op)[backend]
    rng = np.random.default_rng(0)
    make = reg.make_args or _default_make_args
    args = make(n, m, d, rng)
    return {(bn, bm): _time_call(
        functools.partial(reg.impl, *args, metric=metric,
                          block_n=bn, block_m=bm),
        repeats=repeats) for bn, bm in candidates}


def autotune_block_n(
    op: str,
    backend: str,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    platform: Optional[str] = None,
    repeats: int = 3,
) -> int:
    """Best block_n for (op, backend, metric, shape-bucket, platform).

    Cached in ``cache_dir()/autotune.json``; one measurement per bucket.
    """
    global _tuning
    platform = platform or jax.default_backend()
    reg = registered_backends(op)[backend]
    if not reg.tune_candidates or _tuning:
        return reg.default_block_n(platform)
    bn_rows = min(_bucket(n), _MAX_MEASURE_ROWS)
    bm, bd = _bucket(m), _bucket(d)
    key = (f"v{_TUNE_VERSION}/{op}/{backend}/{platform}/{metric}/"
           f"n{bn_rows}/m{bm}/d{bd}")
    hit = _cache_hit(key, ("block_n",))
    if hit is not None:
        obs.counter("kernels.autotune_cache", result="hit").inc()
        return int(hit["block_n"])
    obs.counter("kernels.autotune_cache", result="miss").inc()
    _tuning = True
    try:
        with obs.trace("kernels.autotune", op=op, backend=backend):
            cands = sorted({min(c, bn_rows) for c in reg.tune_candidates})
            timings = measure_block_ns(op, backend, metric=metric, n=bn_rows,
                                       m=bm, d=bd, candidates=cands,
                                       repeats=repeats)
    finally:
        _tuning = False
    best = min(timings, key=timings.get)
    _store_cache(key, {
        "block_n": int(best),
        "timings_us": {str(bn): round(t * 1e6, 2)
                       for bn, t in timings.items()},
        "measured_shape": [bn_rows, bm, bd],
    })
    return int(best)


def autotune_tiles(
    op: str,
    backend: str,
    *,
    metric: str,
    n: int,
    m: int,
    d: int,
    platform: Optional[str] = None,
    repeats: int = 3,
) -> tuple[int, int]:
    """Best jointly-tuned (block_n, block_m) pair for a 2-D op.

    The candidate grid is the cross product of the backend's registered
    row-tile and center-tile candidates (each clipped to its shape bucket
    — no point tiling wider than the data); tiles interact through cache
    and VMEM residency, so the pair is measured together rather than each
    dimension in isolation.  Shares the v2 cache keyspace with
    :func:`autotune_block_n`; an entry lacking ``block_m`` (written by the
    1-D tuner for the same bucket) is treated as stale and re-measured.
    """
    global _tuning
    platform = platform or jax.default_backend()
    reg = registered_backends(op)[backend]
    if reg.default_block_m is None:
        raise ValueError(f"op {op!r} backend {backend!r} registered no "
                         f"block_m dimension; use autotune_block_n")
    if not reg.tune_candidates or _tuning:
        return (reg.default_block_n(platform), reg.default_block_m(platform))
    bn_rows = min(_bucket(n), _MAX_MEASURE_ROWS)
    bm_cols, bd = _bucket(m), _bucket(d)
    key = (f"v{_TUNE_VERSION}/{op}/{backend}/{platform}/{metric}/"
           f"n{bn_rows}/m{bm_cols}/d{bd}")
    hit = _cache_hit(key, ("block_n", "block_m"))
    if hit is not None:
        obs.counter("kernels.autotune_cache", result="hit").inc()
        return int(hit["block_n"]), int(hit["block_m"])
    obs.counter("kernels.autotune_cache", result="miss").inc()
    _tuning = True
    try:
        with obs.trace("kernels.autotune", op=op, backend=backend):
            bns = sorted({min(c, bn_rows) for c in reg.tune_candidates})
            bms = sorted({min(c, bm_cols) for c in (reg.tune_candidates_m
                                                    or (reg.default_block_m(
                                                        platform),))})
            pairs = [(bn, bm) for bn in bns for bm in bms]
            timings = measure_tiles(op, backend, metric=metric, n=bn_rows,
                                    m=bm_cols, d=bd, candidates=pairs,
                                    repeats=repeats)
    finally:
        _tuning = False
    best = min(timings, key=timings.get)
    _store_cache(key, {
        "block_n": int(best[0]),
        "block_m": int(best[1]),
        "timings_us": {f"{bn}x{bm}": round(t * 1e6, 2)
                       for (bn, bm), t in timings.items()},
        "measured_shape": [bn_rows, bm_cols, bd],
    })
    return int(best[0]), int(best[1])
