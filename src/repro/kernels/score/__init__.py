"""Fused serving score op: one-pass pdist + argmin + outlier score."""
from repro.kernels.score.ops import score  # noqa: F401
