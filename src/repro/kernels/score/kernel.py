"""Pallas TPU kernel: fused min-distance + argmin + outlier score.

The serving hot path (``repro.stream.service`` / ``repro.serve``) scores
every query batch as ``dist = d(x, nearest center); score = dist /
threshold`` — after PR 7 the scheduler coalesces many clients into one
micro-batch, but that batch still paid separate pdist / argmin / divide
work with the (n,) intermediates round-tripping through HBM between
steps.  This kernel is the pdist kernel (``repro.kernels.pdist.kernel``)
extended with the scoring epilogue, so one launch covers the whole read
path:

  grid = (n_tiles, m_tiles)  -- m innermost; the automatic Pallas grid
  pipeline double-buffers the HBM->VMEM DMA of the next x row tile while
  the current one computes, and the (tiny) center tiles stay VMEM-resident
  across the row loop.
  running (min, argmin) live in the output blocks (same index_map for all
  j); on the LAST center tile the score output is written in-register as
  dmin / max(threshold, 1e-30) — the distance never returns to HBM just
  to be divided.

The threshold is a (1, 1) block broadcast to every grid step.  Metrics,
padding sentinels, and tie-breaking (strict ``<`` keeps the earliest
center tile; ``jnp.argmin`` keeps the first minimum within a tile) match
the pdist kernel exactly, so the fused outputs agree with the composed
``min_argmin`` + divide path within float tolerance with bit-equal
argmins (asserted in tests/test_dispatch.py).  Cosine is excluded for the
same reason as pdist: a far-away padding sentinel is a direction, not a
distance, under a normalized metric.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 3.0e38  # python float: jnp scalars would be captured as kernel consts
_PAD_COORD = 1.0e15  # padded center rows sit absurdly far away
_EPS = 1e-30  # threshold guard, matches the composed serving path


def _l2_score_kernel(x_ref, c_ref, thr_ref, dmin_ref, amin_ref, score_ref,
                     *, bm: int, nm: int, sqrt: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dmin_ref[...] = jnp.full_like(dmin_ref, _BIG)
        amin_ref[...] = jnp.zeros_like(amin_ref)

    x = x_ref[...].astype(jnp.float32)           # (BN, d)
    c = c_ref[...].astype(jnp.float32)           # (BM, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (BN, 1)
    c2 = jnp.sum(c * c, axis=-1)                 # (BM,)
    # MXU: (BN, d) @ (d, BM)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dist = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)  # (BN, BM)
    if sqrt:
        dist = jnp.sqrt(dist)
    dloc = jnp.min(dist, axis=1, keepdims=True)            # (BN, 1)
    aloc = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None] + j * bm

    better = dloc < dmin_ref[...]
    dmin_ref[...] = jnp.where(better, dloc, dmin_ref[...])
    amin_ref[...] = jnp.where(better, aloc, amin_ref[...])

    @pl.when(j == nm - 1)
    def _score():
        thr = jnp.maximum(thr_ref[0, 0], _EPS)
        score_ref[...] = dmin_ref[...] / thr


def _l1_score_kernel(x_ref, c_ref, thr_ref, dmin_ref, amin_ref, score_ref,
                     acc_ref, *, bm: int, nm: int, nd: int):
    j = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when((j == 0) & (kd == 0))
    def _init():
        dmin_ref[...] = jnp.full_like(dmin_ref, _BIG)
        amin_ref[...] = jnp.zeros_like(amin_ref)

    @pl.when(kd == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)           # (BN, BD)
    c = c_ref[...].astype(jnp.float32)           # (BM, BD)
    acc_ref[...] += jnp.abs(x[:, None, :] - c[None, :, :]).sum(-1)

    @pl.when(kd == nd - 1)
    def _reduce():
        dist = acc_ref[...]
        dloc = jnp.min(dist, axis=1, keepdims=True)
        aloc = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None] + j * bm
        better = dloc < dmin_ref[...]
        dmin_ref[...] = jnp.where(better, dloc, dmin_ref[...])
        amin_ref[...] = jnp.where(better, aloc, amin_ref[...])

    @pl.when((j == nm - 1) & (kd == nd - 1))
    def _score():
        thr = jnp.maximum(thr_ref[0, 0], _EPS)
        score_ref[...] = dmin_ref[...] / thr


def _pad_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit,
                   static_argnames=("metric", "bn", "bm", "bd", "interpret"))
def score_pallas(
    x: jnp.ndarray,
    c: jnp.ndarray,
    threshold: jnp.ndarray,
    *,
    metric: str = "l2sq",
    bn: int = 512,
    bm: int = 128,
    bd: int = 512,
    interpret: bool | None = None,
):
    """Fused (min distance, argmin, dist/threshold) — Pallas path."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    m = c.shape[0]
    bn = min(bn, _pad_to(n, 8))
    bm = min(bm, _pad_to(m, 128))
    np_, mp = _pad_to(n, bn), _pad_to(m, bm)
    xp = jnp.pad(x, ((0, np_ - n), (0, 0)))
    cp = jnp.pad(c, ((0, mp - m), (0, 0)), constant_values=_PAD_COORD)
    thr = jnp.reshape(threshold, (1, 1)).astype(jnp.float32)
    out_shape = [
        jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        jax.ShapeDtypeStruct((np_, 1), jnp.int32),
        jax.ShapeDtypeStruct((np_, 1), jnp.float32),
    ]

    if metric in ("l2sq", "l2"):
        dp = _pad_to(d, 128)
        xp = jnp.pad(xp, ((0, 0), (0, dp - d)))
        cp = jnp.pad(cp, ((0, 0), (0, dp - d)))  # both pad w/ same const -> dist 0
        nm = mp // bm
        grid = (np_ // bn, nm)
        dmin, amin, score = pl.pallas_call(
            functools.partial(_l2_score_kernel, bm=bm, nm=nm,
                              sqrt=(metric == "l2")),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, dp), lambda i, j: (i, 0)),
                pl.BlockSpec((bm, dp), lambda i, j: (j, 0)),
                pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            ],
            out_shape=out_shape,
            interpret=interpret,
        )(xp, cp, thr)
    elif metric == "l1":
        dp = _pad_to(d, 128)
        bd = min(bd, dp)
        dp = _pad_to(dp, bd)
        xp = jnp.pad(xp, ((0, 0), (0, dp - d)))
        cp = jnp.pad(cp, ((0, 0), (0, dp - d)))
        nd = dp // bd
        nm = mp // bm
        grid = (np_ // bn, nm, nd)
        dmin, amin, score = pl.pallas_call(
            functools.partial(_l1_score_kernel, bm=bm, nm=nm, nd=nd),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, bd), lambda i, j, kd: (i, kd)),
                pl.BlockSpec((bm, bd), lambda i, j, kd: (j, kd)),
                pl.BlockSpec((1, 1), lambda i, j, kd: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bn, 1), lambda i, j, kd: (i, 0)),
                pl.BlockSpec((bn, 1), lambda i, j, kd: (i, 0)),
                pl.BlockSpec((bn, 1), lambda i, j, kd: (i, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
            interpret=interpret,
        )(xp, cp, thr)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return dmin[:n, 0], amin[:n, 0], score[:n, 0]
