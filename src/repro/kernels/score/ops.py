"""Production entry point for the fused serving score op.

``score(x, c, threshold, metric=..., policy=KernelPolicy(...))``
computes, in ONE dispatch, what the serving read path previously
composed from three: distance to the nearest center (``min_argmin``),
the winning center index, and the outlier score ``dist /
max(threshold, eps)``.  Returns ``(dist (n,), idx (n,) int32,
score (n,))``.

Backends (registry: ``repro.kernels.dispatch``):

  * ``ref``     — composed oracle: ``min_argmin_ref`` + divide.  Exactly
    yesterday's semantics; the parity target for everything below.
  * ``blocked`` — chunked single pass.  Rows are tiled by ``block_n`` as
    in pdist; centers are additionally tiled by ``block_m`` with a
    running (min, argmin) carried across center tiles, so peak memory is
    ``block_n × block_m`` distances no matter how many centers.  When
    the centers fit one tile (the serving case: t ≪ n, m = k ~ tens) the
    tile loop collapses to the ref computation — bit-identical to the
    composed path.
  * ``pallas``  — one TPU kernel (``kernel.py``): double-buffered
    HBM→VMEM DMA over row tiles, centers VMEM-resident, score epilogue
    in-register.  Interpret mode off-TPU (test-only, never auto-picked).
  * ``int8``    — quantized-center variant: per-center symmetric scale
    (``max|c_i| / 127``), centers stored int8, rescaled to fp32 at the
    accumulate, then the blocked single pass.  It CHANGES results
    (bounded quantization error, measured — not assumed — in
    ``benchmarks/stream_bench.py`` and gated by ``quant_max_score_err``),
    so its auto-priority is negative: callers opt in by name.

``score`` is the registry's first 2-D-tiled op: ``blocked``/``pallas``/
``int8`` register a ``block_m`` center-tile dimension, resolved through
``dispatch.resolve_tiles`` and jointly autotuned as a (block_n, block_m)
pair under the v2 cache schema.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.pdist import ref as _ref

_DEFAULT_BLOCK_N = 16384
_TUNE_BLOCK_NS = (4096, 8192, 16384, 32768, 65536)
_DEFAULT_BLOCK_M = 128
_TUNE_BLOCK_MS = (64, 128, 256, 512)
_EPS = 1e-30  # threshold guard — matches the historical serving divide


def _finish(dist: jnp.ndarray, amin: jnp.ndarray, threshold):
    return dist, amin, dist / jnp.maximum(threshold, _EPS)


def _score_args(n: int, m: int, d: int, rng: np.random.Generator):
    """Autotuner argument factory (score takes a threshold, pdist doesn't)."""
    x = rng.standard_normal((n, d)).astype(np.float32)
    c = rng.standard_normal((m, d)).astype(np.float32)
    return (x, c, np.float32(1.0))


def _tile_min_argmin(xb: jnp.ndarray, c: jnp.ndarray, metric: str,
                     block_m: int):
    """One row block against all centers, center-tiled by ``block_m``.

    Running (min, argmin) across tiles with strict ``<`` (ties keep the
    earliest tile; ``argmin`` keeps the first minimum within a tile), so
    the result is bit-equal to the untiled ``min_argmin_ref`` argmin.
    Padded center columns are masked with +inf AFTER the distance
    computation — safe for every metric including cosine, where a padding
    *sentinel coordinate* would normalize into a legal direction.
    """
    m = c.shape[0]
    if m <= block_m:
        return _ref.min_argmin_ref(xb, c, metric)
    pad_m = (-m) % block_m
    cp = jnp.pad(c, ((0, pad_m), (0, 0)))
    n_tiles = cp.shape[0] // block_m

    def body(carry, ci):
        best_d, best_i = carry
        cc = jax.lax.dynamic_slice_in_dim(cp, ci * block_m, block_m, axis=0)
        dist = _ref.pairwise(xb, cc, metric)                  # (bn, bm)
        col = ci * block_m + jnp.arange(block_m)
        dist = jnp.where(col[None, :] < m, dist, jnp.inf)
        dmin = dist.min(axis=1)
        darg = dist.argmin(axis=1).astype(jnp.int32) + ci * block_m
        take = dmin < best_d
        return (jnp.where(take, dmin, best_d),
                jnp.where(take, darg, best_i)), None

    init = (jnp.full((xb.shape[0],), jnp.inf, xb.dtype),
            jnp.zeros((xb.shape[0],), jnp.int32))
    (bd, bi), _ = jax.lax.scan(body, init, jnp.arange(n_tiles))
    return bd, bi


def _score_rows(x, c, threshold, metric, block_n, block_m):
    """Shared blocked compute (float centers in, used by blocked + int8)."""
    n = x.shape[0]
    if n <= block_n:
        dist, amin = _tile_min_argmin(x, c, metric, block_m)
        return _finish(dist, amin, threshold)
    pad = (-n) % block_n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xs = xp.reshape(-1, block_n, x.shape[1])
    md, ai = jax.lax.map(
        lambda xb: _tile_min_argmin(xb, c, metric, block_m), xs)
    return _finish(md.reshape(-1)[:n], ai.reshape(-1)[:n], threshold)


@dispatch.register(
    "score", "ref",
    supports=lambda metric, platform, dtype, n, m, d: metric in _ref.METRICS,
    priority=lambda platform: 0,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
    make_args=_score_args,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n"))
def score_reference(x: jnp.ndarray, c: jnp.ndarray, threshold, *,
                    metric: str = "l2sq", block_n: int = 0):
    """Oracle: the composed three-step path as one function (tiles unused)."""
    dist, amin = _ref.min_argmin_ref(x, c, metric)
    return _finish(dist, amin, threshold)


@dispatch.register(
    "score", "blocked",
    supports=lambda metric, platform, dtype, n, m, d: metric in _ref.METRICS,
    priority=lambda platform: 1,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
    tune_candidates=_TUNE_BLOCK_NS,
    make_args=_score_args,
    default_block_m=lambda platform: _DEFAULT_BLOCK_M,
    tune_candidates_m=_TUNE_BLOCK_MS,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n", "block_m"))
def score_blocked(x: jnp.ndarray, c: jnp.ndarray, threshold, *,
                  metric: str = "l2sq",
                  block_n: int = _DEFAULT_BLOCK_N,
                  block_m: int = _DEFAULT_BLOCK_M):
    """Chunked single pass; ≤ ``block_n × block_m`` distances live at once."""
    return _score_rows(x, c, threshold, metric, block_n, block_m)


@dispatch.register(
    "score", "pallas",
    # cosine is blocked/ref-only, matching pdist: a far-away padding
    # sentinel is a direction under a normalized metric
    supports=lambda metric, platform, dtype, n, m, d: (
        metric in _ref.PALLAS_METRICS),
    # interpret mode off-TPU is test-only: never auto-picked there
    priority=lambda platform: 10 if platform == "tpu" else -1,
    default_block_n=lambda platform: 512,
    tune_candidates=(256, 512, 1024, 2048),
    make_args=_score_args,
    default_block_m=lambda platform: 128,
    tune_candidates_m=(128, 256, 512),
)
def score_pallas_backend(x: jnp.ndarray, c: jnp.ndarray, threshold, *,
                         metric: str = "l2sq", block_n: int = 512,
                         block_m: int = 128):
    from . import kernel as _kernel  # deferred: pallas import is optional
    return _kernel.score_pallas(x, c, threshold, metric=metric,
                                bn=block_n, bm=block_m)


@dispatch.register(
    "score", "int8",
    supports=lambda metric, platform, dtype, n, m, d: metric in _ref.METRICS,
    # changes results (quantization error): explicit opt-in only
    priority=lambda platform: -1,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
    tune_candidates=_TUNE_BLOCK_NS,
    make_args=_score_args,
    default_block_m=lambda platform: _DEFAULT_BLOCK_M,
    tune_candidates_m=_TUNE_BLOCK_MS,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n", "block_m"))
def score_int8(x: jnp.ndarray, c: jnp.ndarray, threshold, *,
               metric: str = "l2sq",
               block_n: int = _DEFAULT_BLOCK_N,
               block_m: int = _DEFAULT_BLOCK_M):
    """Quantized-center score: per-center symmetric int8, fp32 rescale.

    ``scale_i = max|c_i| / 127`` per center row; centers round to int8
    and are rescaled to fp32 at the accumulate, then the blocked single
    pass runs unchanged.  Queries stay fp32 — only the (tiny, reusable)
    summary is quantized, the coreset-tolerance argument for bounded
    per-point distance error.  Max score error is MEASURED in
    benchmarks/stream_bench.py (``quant_max_score_err``), not assumed.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(c), axis=1) / 127.0, 1e-12)
    cq = jnp.round(c / scale[:, None]).astype(jnp.int8)
    cdq = cq.astype(jnp.float32) * scale[:, None]
    return _score_rows(x, cdq, threshold, metric, block_n, block_m)


def score(
    x: jnp.ndarray,
    c: jnp.ndarray,
    threshold,
    *,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    block_n: Optional[int] = None,      # removed alias: raises TypeError
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
):
    """Fused serving score: one dispatch for pdist → argmin → dist/thr.

    For each row of ``x`` (n, d): distance to the nearest row of ``c``
    (m, d), that row's index, and ``dist / max(threshold, 1e-30)``.
    Returns ``(dist (n,), idx (n,) int32, score (n,))``; ``score > 1``
    is the paper's outlier predicate.

    Backend/tile selection comes from ``policy`` (default: the process
    policy).  Resolution happens at trace time, so calls inside
    ``jax.jit`` cost nothing at runtime.
    """
    policy = dispatch.resolve_policy(policy, use_pallas=use_pallas,
                                     block_n=block_n, caller="score")
    n, d = x.shape
    reg, bn, bm = dispatch.resolve_tiles("score", policy, metric=metric,
                                         n=n, m=c.shape[0], d=d,
                                         dtype=x.dtype)
    if reg.default_block_m is None:
        return reg.impl(x, c, threshold, metric=metric, block_n=bn)
    return reg.impl(x, c, threshold, metric=metric, block_n=bn, block_m=bm)
