"""Pure-jnp oracle for the WKV6 recurrence (flattened batch*heads layout).

    s_t = diag(w_t) s_{t-1} + k_t v_t^T
    o_t = r_t^T (s_{t-1} + diag(u) k_t v_t^T)

r,k,v: (BH, T, K); lw = log w (<= 0): (BH, T, K); u: (K,); s0: (BH, K, V).
The Pallas kernel (kernel.py) evaluates this chunkwise with the intra-chunk
decay tensor held in VMEM; this oracle is the step-by-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, lw, u, s0):
    BH, _, K = r.shape
    u2 = jnp.broadcast_to(u.reshape(1, K) if u.ndim == 1 else u, (BH, K))

    def step(s, inp):
        rr, kk, vv, ll = inp                                   # (BH, K)
        kv = kk[:, :, None] * vv[:, None, :]                   # (BH, K, V)
        o = jnp.einsum("bi,biv->bv", rr, s + u2[:, :, None] * kv)
        s = s * jnp.exp(ll)[..., None] + kv
        return s, o

    xs = tuple(a.transpose(1, 0, 2) for a in (r, k, v, lw))    # (T, BH, K)
    sT, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return o.transpose(1, 0, 2), sT
