"""Public wrapper: WKV6 forward with custom VJP.

Forward: the Pallas chunk kernel (VMEM-resident intra tensors).
Backward: recompute via the tested jnp chunked path (models/rwkv6) —
equivalent math, already validated against the step oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv.kernel import wkv_forward_pallas
from repro.kernels.wkv.ref import wkv_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def wkv_forward(r, k, v, lw, u, s0, chunk: int = 16):
    o, sT = wkv_forward_pallas(r, k, v, lw, u, s0, chunk=chunk)
    return o, sT


def _fwd(r, k, v, lw, u, s0, chunk):
    out = wkv_forward_pallas(r, k, v, lw, u, s0, chunk=chunk)
    return out, (r, k, v, lw, u, s0)


def _bwd(chunk, res, cts):
    r, k, v, lw, u, s0 = res

    def f(r, k, v, lw, u, s0):
        return wkv_ref(r, k, v, lw, u, s0)  # recompute in jnp for the VJP

    _, vjp = jax.vjp(f, r, k, v, lw, u, s0)
    return vjp(cts)


wkv_forward.defvjp(_fwd, _bwd)
