"""Pallas TPU kernel: chunked WKV6 forward (the rwkv6 §Perf lever).

The jnp chunked evaluation (models/rwkv6.py) materializes the intra-chunk
decay tensor A (c, c, K) to HBM as a dot operand — at 7B scale that is the
dominant memory-roofline term (EXPERIMENTS §Perf rwkv6 iteration 4). This
kernel keeps everything chunk-local in VMEM:

  grid = (BH_tiles, T/c)    chunk axis innermost ("arbitrary"), the running
                            state S (bbh, K, V) lives in a VMEM scratch that
                            persists across the chunk sweep
  per step:  lin   = cumsum(lw_chunk)                    (bbh, c, K)  f32
             A     = exp(lprev[t] - lin[tau]) masked     (bbh, c, c, K) VMEM
             w_ts  = (r*A*k) summed over K               MXU-friendly einsum
             o     = w_ts @ v + bonus + (r exp(lprev)) @ S
             S     = exp(lin[-1]) * S + (k exp(lin[-1]-lin))^T @ v

VMEM budget at bbh=8, c=16, K=V=64: A = 0.5 MB, S scratch = 128 KB, chunk
tiles 4x256 KB — comfortably resident.

Backward: jax.custom_vjp with the pure-jnp chunked recompute
(models/rwkv6.wkv_chunked with inner_remat) — forward speed is what the
roofline needs; the backward shares its math with the tested oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr,
            *, nc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    rr = r_ref[...].astype(jnp.float32)     # (bbh, c, K)
    kk = k_ref[...].astype(jnp.float32)
    vv = v_ref[...].astype(jnp.float32)
    ll = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)      # (bbh, K) — per (batch, head) row
    s = s_scr[...]                          # (bbh, K, V)

    c = rr.shape[1]
    lin = jnp.cumsum(ll, axis=1)
    lprev = lin - ll
    # A[t, tau, i] = exp(lprev[t,i] - lin[tau,i]) for tau < t
    a = jnp.exp(lprev[:, :, None, :] - lin[:, None, :, :])
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    a = jnp.where(tri[None, :, :, None], a, 0.0)
    w_ts = jnp.einsum("bti,btsi,bsi->bts", rr, a, kk,
                      preferred_element_type=jnp.float32)
    o = jnp.einsum("bts,bsv->btv", w_ts, vv,
                   preferred_element_type=jnp.float32)
    # bonus (current token)
    o += (rr * u[:, None, :] * kk).sum(-1, keepdims=True) * vv
    # inter-chunk from carried state
    o += jnp.einsum("bti,biv->btv", rr * jnp.exp(lprev), s,
                    preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)

    # state update
    dec_all = jnp.exp(lin[:, -1:, :])                     # (bbh, 1, K)
    s_new = s * dec_all.transpose(0, 2, 1) + jnp.einsum(
        "bsi,bsv->biv", kk * jnp.exp(lin[:, -1:, :] - lin), vv,
        preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(j == nc - 1)
    def _final():
        sT_ref[...] = s_new.astype(sT_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_bh", "interpret"))
def wkv_forward_pallas(r, k, v, lw, u, s0, *, chunk: int = 16,
                       block_bh: int = 8, interpret: bool | None = None):
    """r,k,v,lw: (BH, T, K); u: (K,) shared or (BH, K) per-row;
    s0: (BH, K, V) -> (o, sT)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    BH, T, K = r.shape
    V = s0.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, "pad T to a chunk multiple (models/rwkv6 does)"
    nc = T // c
    bbh = min(block_bh, BH)
    assert BH % bbh == 0
    grid = (BH // bbh, nc)
    u2 = jnp.broadcast_to(u.reshape(1, K), (BH, K)) if u.ndim == 1 else u

    o, sT = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bbh, c, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, c, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, c, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, c, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bbh, K, V), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bbh, c, K), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, K, V), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, K), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bbh, K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u2, s0)
    return o, sT
