"""Pure-jnp oracle for the fused min-distance + argmin primitive.

``min_argmin_ref(x, c, metric)`` computes, for every row of ``x``, the
distance to the nearest row of ``c`` and the index of that row.  This is the
compute hot-spot of the paper's Algorithm 1 (Summary-Outliers): every round
computes d(x, S_i) for all remaining points.  The Pallas kernel in
``kernel.py`` must match this oracle bit-for-bit up to float tolerance
(ties broken toward the smaller index in both).

Metrics:
  * ``l2sq``   — squared Euclidean distance (used for (k,t)-means).
  * ``l2``     — Euclidean distance (used for (k,t)-median).
  * ``l1``     — Manhattan distance (the paper notes any metric with a
                 distance oracle works).
  * ``cosine`` — 1 - cos(x, c) in [0, 2]; rows are normalized internally so
                 callers may pass unnormalized data.  Served by the blocked
                 and ref backends only (the Pallas kernel's far-away padding
                 sentinel is meaningless under a direction-only metric, so
                 its capability predicate excludes cosine and auto selection
                 routes around it).
"""
from __future__ import annotations

import jax.numpy as jnp

METRICS = ("l2sq", "l2", "l1", "cosine")

# metrics the Pallas pdist kernel implements (see kernel.py); keep in sync
PALLAS_METRICS = ("l2sq", "l2", "l1")


def _unit(v: jnp.ndarray) -> jnp.ndarray:
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-30)


def pairwise(x: jnp.ndarray, c: jnp.ndarray, metric: str = "l2sq") -> jnp.ndarray:
    """Full (n, m) pairwise distance matrix. O(n*m*d) memory-free form for
    l2*, O(n*m*d) materialized for l1 — oracle only, not the production path."""
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}")
    if metric == "l1":
        return jnp.abs(x[:, None, :] - c[None, :, :]).sum(-1)
    if metric == "cosine":
        sim = _unit(x) @ _unit(c).T
        return jnp.clip(1.0 - sim, 0.0, 2.0)
    x2 = (x * x).sum(-1)
    c2 = (c * c).sum(-1)
    d2 = x2[:, None] + c2[None, :] - 2.0 * (x @ c.T)
    d2 = jnp.maximum(d2, 0.0)
    return d2 if metric == "l2sq" else jnp.sqrt(d2)


def min_argmin_ref(x: jnp.ndarray, c: jnp.ndarray, metric: str = "l2sq"):
    """(min distance, argmin index) per row of x. Ties -> smallest index."""
    d = pairwise(x, c, metric)
    return d.min(axis=1), d.argmin(axis=1).astype(jnp.int32)
