"""Production entry point for fused min-distance + argmin.

``min_argmin(x, c, metric=..., block_n=..., use_pallas=...)``

Dispatches to:
  * the Pallas TPU kernel (``kernel.py``) when requested / on TPU, or
  * a chunked pure-jnp path that never materializes more than
    ``block_n × m`` distances at once (the (n, m) matrix for the paper's
    datasets would be ~GBs; chunking keeps the working set cache-sized on
    CPU and VMEM-sized on TPU).

Both paths agree with ``ref.min_argmin_ref`` (tested in
tests/test_kernels_pdist.py, incl. interpret=True kernel sweeps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_BLOCK_N = 16384


def _block_min_argmin(xb: jnp.ndarray, c: jnp.ndarray, metric: str):
    """One n-block against all centers. For l1, chunk centers to bound the
    (bn, mc, d) broadcast."""
    if metric == "l1":
        m = c.shape[0]
        mc = min(m, 64)
        pad_m = (-m) % mc
        cp = jnp.pad(c, ((0, pad_m), (0, 0)), constant_values=jnp.inf)
        n_chunks = cp.shape[0] // mc

        def body(carry, ci):
            best_d, best_i = carry
            cc = jax.lax.dynamic_slice_in_dim(cp, ci * mc, mc, axis=0)
            d = jnp.abs(xb[:, None, :] - cc[None, :, :]).sum(-1)  # (bn, mc)
            dmin = d.min(axis=1)
            darg = d.argmin(axis=1).astype(jnp.int32) + ci * mc
            take = dmin < best_d
            return (jnp.where(take, dmin, best_d), jnp.where(take, darg, best_i)), None

        init = (jnp.full((xb.shape[0],), jnp.inf, xb.dtype),
                jnp.zeros((xb.shape[0],), jnp.int32))
        (bd, bi), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
        return bd, bi
    return _ref.min_argmin_ref(xb, c, metric)


@functools.partial(jax.jit, static_argnames=("metric", "block_n", "use_pallas"))
def min_argmin(x: jnp.ndarray, c: jnp.ndarray, *, metric: str = "l2sq",
               block_n: int = _DEFAULT_BLOCK_N, use_pallas: bool = False):
    """For each row of ``x`` (n, d): distance to nearest row of ``c`` (m, d)
    and its index. Returns (dist (n,), idx (n,) int32)."""
    if use_pallas:
        from . import kernel as _kernel  # deferred: pallas import is optional
        return _kernel.min_argmin_pallas(x, c, metric=metric)
    n = x.shape[0]
    if n <= block_n:
        return _block_min_argmin(x, c, metric)
    pad = (-n) % block_n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xs = xp.reshape(-1, block_n, x.shape[1])
    md, ai = jax.lax.map(lambda xb: _block_min_argmin(xb, c, metric), xs)
    return md.reshape(-1)[:n], ai.reshape(-1)[:n]
