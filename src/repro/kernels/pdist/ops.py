"""Production entry point for fused min-distance + argmin.

``min_argmin(x, c, metric=..., policy=KernelPolicy(...))``

Dispatches through the backend registry (``repro.kernels.dispatch``):

  * ``pallas``  — the TPU kernel (``kernel.py``); interpret mode off-TPU,
  * ``blocked`` — a chunked pure-jnp path that never materializes more than
    ``block_n × m`` distances at once (the (n, m) matrix for the paper's
    datasets would be ~GBs; chunking keeps the working set cache-sized on
    CPU and VMEM-sized on TPU),
  * ``ref``     — the oracle in ``ref.py`` (full (n, m) matrix).

``backend="auto"`` picks Pallas on TPU and blocked elsewhere; ``block_n``
comes from the policy, the autotuner's measured tile, or the backend
default.  All paths agree with ``ref.min_argmin_ref`` (tested in
tests/test_kernels.py and tests/test_dispatch.py, incl. interpret=True
kernel sweeps).

The pre-registry ``use_pallas=``/``block_n=`` keyword aliases are removed;
passing either raises a ``TypeError`` naming the ``KernelPolicy``
replacement.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from . import ref as _ref

_DEFAULT_BLOCK_N = 16384
_TUNE_BLOCK_NS = (4096, 8192, 16384, 32768, 65536)


def _block_min_argmin(xb: jnp.ndarray, c: jnp.ndarray, metric: str):
    """One n-block against all centers. For l1, chunk centers to bound the
    (bn, mc, d) broadcast."""
    if metric == "l1":
        m = c.shape[0]
        mc = min(m, 64)
        pad_m = (-m) % mc
        cp = jnp.pad(c, ((0, pad_m), (0, 0)), constant_values=jnp.inf)
        n_chunks = cp.shape[0] // mc

        def body(carry, ci):
            best_d, best_i = carry
            cc = jax.lax.dynamic_slice_in_dim(cp, ci * mc, mc, axis=0)
            d = jnp.abs(xb[:, None, :] - cc[None, :, :]).sum(-1)  # (bn, mc)
            dmin = d.min(axis=1)
            darg = d.argmin(axis=1).astype(jnp.int32) + ci * mc
            take = dmin < best_d
            return (jnp.where(take, dmin, best_d), jnp.where(take, darg, best_i)), None

        init = (jnp.full((xb.shape[0],), jnp.inf, xb.dtype),
                jnp.zeros((xb.shape[0],), jnp.int32))
        (bd, bi), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
        return bd, bi
    return _ref.min_argmin_ref(xb, c, metric)


@dispatch.register(
    "min_argmin", "blocked",
    supports=lambda metric, platform, dtype, n, m, d: metric in _ref.METRICS,
    priority=lambda platform: 1,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
    tune_candidates=_TUNE_BLOCK_NS,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n"))
def min_argmin_blocked(x: jnp.ndarray, c: jnp.ndarray, *,
                       metric: str = "l2sq",
                       block_n: int = _DEFAULT_BLOCK_N):
    """Chunked jnp path: at most ``block_n × m`` distances live at once."""
    n = x.shape[0]
    if n <= block_n:
        return _block_min_argmin(x, c, metric)
    pad = (-n) % block_n
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xs = xp.reshape(-1, block_n, x.shape[1])
    md, ai = jax.lax.map(lambda xb: _block_min_argmin(xb, c, metric), xs)
    return md.reshape(-1)[:n], ai.reshape(-1)[:n]


@dispatch.register(
    "min_argmin", "ref",
    supports=lambda metric, platform, dtype, n, m, d: metric in _ref.METRICS,
    priority=lambda platform: 0,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n"))
def min_argmin_reference(x: jnp.ndarray, c: jnp.ndarray, *,
                         metric: str = "l2sq", block_n: int = 0):
    """Oracle backend; materializes the full (n, m) matrix (block_n unused)."""
    return _ref.min_argmin_ref(x, c, metric)


@dispatch.register(
    "min_argmin", "pallas",
    # cosine is blocked/ref-only: the kernel's far-away padding sentinel is
    # a direction under a normalized metric, not "infinitely far"
    supports=lambda metric, platform, dtype, n, m, d: metric in _ref.PALLAS_METRICS,
    # interpret mode off-TPU is test-only: never auto-picked there
    priority=lambda platform: 10 if platform == "tpu" else -1,
    default_block_n=lambda platform: 512,
    tune_candidates=(256, 512, 1024, 2048),
)
def min_argmin_pallas_backend(x: jnp.ndarray, c: jnp.ndarray, *,
                              metric: str = "l2sq", block_n: int = 512):
    from . import kernel as _kernel  # deferred: pallas import is optional
    return _kernel.min_argmin_pallas(x, c, metric=metric, bn=block_n)


def min_argmin(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    block_n: Optional[int] = None,      # removed alias: raises TypeError
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
):
    """For each row of ``x`` (n, d): distance to nearest row of ``c`` (m, d)
    and its index. Returns (dist (n,), idx (n,) int32).

    Backend/tile selection comes from ``policy`` (default: the process
    policy, see ``dispatch.set_default_policy``).  Resolution happens at
    trace time, so calls inside ``jax.jit`` cost nothing at runtime.
    """
    policy = dispatch.resolve_policy(policy, use_pallas=use_pallas,
                                     block_n=block_n, caller="min_argmin")
    n, d = x.shape
    reg, bn = dispatch.resolve("min_argmin", policy, metric=metric,
                               n=n, m=c.shape[0], d=d, dtype=x.dtype)
    return reg.impl(x, c, metric=metric, block_n=bn)
