"""Pallas TPU kernel: fused min-distance + argmin (the paper's hot-spot).

The paper's Algorithm 1 spends all of its time computing d(x, S_i) for every
remaining point — an (n x m x d) problem with tiny m (= alpha*max{k, log n})
and small-to-moderate d.  A naive implementation materializes the (n, m)
distance matrix in HBM (n can be millions); this kernel never does:

  grid = (n_tiles, m_tiles)   -- m innermost ("arbitrary"), n "parallel"
  x tile  (BN, d)  in VMEM    -- revisited across the m loop
  c tile  (BM, d)  in VMEM
  dist tile = x2 + c2 - 2 * x @ c^T   (MXU matmul, f32 accumulate)
  running (min, argmin) held in the OUTPUT blocks, which pallas keeps
  resident in VMEM across the inner m loop (same index_map for all j).

Arithmetic intensity: 2*BN*BM*d FLOPs per (BN*d + BM*d) * 4 bytes moved,
i.e. ~2*BM FLOPs/byte for BM >= BN — MXU-bound for BM >= ~128, which is why
BM defaults to 128 and BN to 512 (8 sublane-tiles of f32).

Tie-breaking matches ref.py: strict `<` updates keep the earliest m-tile;
within a tile jnp.argmin returns the first minimum.

The l1 metric adds a d grid axis (no MXU for |x-c|): partial sums accumulate
into a VMEM scratch and the min-update fires on the last d step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 3.0e38  # python float: jnp scalars would be captured as kernel consts
_PAD_COORD = 1.0e15  # padded center rows sit absurdly far away


def _l2_kernel(x_ref, c_ref, dmin_ref, amin_ref, *, bm: int, sqrt: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dmin_ref[...] = jnp.full_like(dmin_ref, _BIG)
        amin_ref[...] = jnp.zeros_like(amin_ref)

    x = x_ref[...].astype(jnp.float32)           # (BN, d)
    c = c_ref[...].astype(jnp.float32)           # (BM, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (BN, 1)
    c2 = jnp.sum(c * c, axis=-1)                 # (BM,)
    # MXU: (BN, d) @ (d, BM)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dist = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)  # (BN, BM)
    if sqrt:
        dist = jnp.sqrt(dist)
    dloc = jnp.min(dist, axis=1, keepdims=True)            # (BN, 1)
    aloc = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None] + j * bm

    better = dloc < dmin_ref[...]
    dmin_ref[...] = jnp.where(better, dloc, dmin_ref[...])
    amin_ref[...] = jnp.where(better, aloc, amin_ref[...])


def _l1_kernel(x_ref, c_ref, dmin_ref, amin_ref, acc_ref, *, bm: int, nd: int):
    j = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when((j == 0) & (kd == 0))
    def _init():
        dmin_ref[...] = jnp.full_like(dmin_ref, _BIG)
        amin_ref[...] = jnp.zeros_like(amin_ref)

    @pl.when(kd == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)           # (BN, BD)
    c = c_ref[...].astype(jnp.float32)           # (BM, BD)
    acc_ref[...] += jnp.abs(x[:, None, :] - c[None, :, :]).sum(-1)

    @pl.when(kd == nd - 1)
    def _reduce():
        dist = acc_ref[...]
        dloc = jnp.min(dist, axis=1, keepdims=True)
        aloc = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None] + j * bm
        better = dloc < dmin_ref[...]
        dmin_ref[...] = jnp.where(better, dloc, dmin_ref[...])
        amin_ref[...] = jnp.where(better, aloc, amin_ref[...])


def _pad_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("metric", "bn", "bm", "bd", "interpret"))
def min_argmin_pallas(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    metric: str = "l2sq",
    bn: int = 512,
    bm: int = 128,
    bd: int = 512,
    interpret: bool | None = None,
):
    """Fused (min distance, argmin) — Pallas path. See module docstring."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    m = c.shape[0]
    bn = min(bn, _pad_to(n, 8))
    bm = min(bm, _pad_to(m, 128))
    np_, mp = _pad_to(n, bn), _pad_to(m, bm)
    xp = jnp.pad(x, ((0, np_ - n), (0, 0)))
    cp = jnp.pad(c, ((0, mp - m), (0, 0)), constant_values=_PAD_COORD)

    if metric in ("l2sq", "l2"):
        dp = _pad_to(d, 128)
        xp = jnp.pad(xp, ((0, 0), (0, dp - d)))
        cp = jnp.pad(cp, ((0, 0), (0, dp - d)))  # both pad w/ same const -> dist 0
        grid = (np_ // bn, mp // bm)
        dmin, amin = pl.pallas_call(
            functools.partial(_l2_kernel, bm=bm, sqrt=(metric == "l2")),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, dp), lambda i, j: (i, 0)),
                pl.BlockSpec((bm, dp), lambda i, j: (j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((np_, 1), jnp.float32),
                jax.ShapeDtypeStruct((np_, 1), jnp.int32),
            ],
            interpret=interpret,
        )(xp, cp)
    elif metric == "l1":
        dp = _pad_to(d, 128)
        bd = min(bd, dp)
        dp = _pad_to(dp, bd)
        xp = jnp.pad(xp, ((0, 0), (0, dp - d)))
        cp = jnp.pad(cp, ((0, 0), (0, dp - d)))
        nd = dp // bd
        grid = (np_ // bn, mp // bm, nd)
        dmin, amin = pl.pallas_call(
            functools.partial(_l1_kernel, bm=bm, nd=nd),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, bd), lambda i, j, kd: (i, kd)),
                pl.BlockSpec((bm, bd), lambda i, j, kd: (j, kd)),
            ],
            out_specs=[
                pl.BlockSpec((bn, 1), lambda i, j, kd: (i, 0)),
                pl.BlockSpec((bn, 1), lambda i, j, kd: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((np_, 1), jnp.float32),
                jax.ShapeDtypeStruct((np_, 1), jnp.int32),
            ],
            scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
            interpret=interpret,
        )(xp, cp)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return dmin[:n, 0], amin[:n, 0]
