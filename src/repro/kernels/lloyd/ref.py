"""Pure-jnp oracle for the fused Lloyd step.

Given points x (n,d), weights w (n,), centers c (k,d), one Lloyd step needs:
  assignment a_i = argmin_j d(x_i, c_j)
  dist_i     = d(x_i, c_{a_i})
  sums_j     = sum_{i: a_i=j} w_i * x_i        (weighted centroid numerators)
  counts_j   = sum_{i: a_i=j} w_i

The TPU kernel fuses all four so the (n,k) distance matrix never leaves
VMEM and the scatter-add becomes a per-tile one-hot matmul on the MXU.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pdist.ref import pairwise


def lloyd_step_ref(x, w, c, metric: str = "l2sq"):
    d = pairwise(x, c, metric)
    a = d.argmin(axis=1).astype(jnp.int32)
    dist = d.min(axis=1)
    k = c.shape[0]
    sums = jnp.zeros((k, x.shape[1]), jnp.float32).at[a].add(x * w[:, None])
    counts = jnp.zeros((k,), jnp.float32).at[a].add(w)
    return sums, counts, a, dist
