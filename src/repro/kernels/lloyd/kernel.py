"""Pallas TPU kernel: fused Lloyd step (assign + weighted accumulate).

Second-level k-means-- iterates Lloyd steps on the summary; on TPU the
naive version is two scatter-adds (bad: serialized on the scalar core) plus
an HBM-resident (n, k) distance matrix.  This kernel instead:

  grid = (n_tiles,)  sequential ("arbitrary") so the (k, d) accumulator
  output blocks are revisited and stay resident in VMEM across the sweep
  (constant index_map), initialized on the first step.

  per tile:  dist  = x2 + c2 - 2 x @ cT      (MXU)
             aloc  = argmin(dist, axis=1)
             onehot(bn, k) = iota_k == aloc  (VPU compare)
             sums   += (onehot * w)^T @ x    (MXU again — the scatter-add
                                              becomes a matmul)
             counts += column-sum(onehot * w)

k is kept whole in one block (k <= ~2048 for the paper's workloads: the
coordinator clusters k=O(100) centers out of the summary).  Padded center
rows sit at 1e15 so they never win an argmin; padded x rows carry weight 0
so they contribute nothing.

Metrics: l2sq / l2 (assignment distance; the update is the weighted mean in
both cases — k-means-- is a means algorithm).  l1 assignment falls back to
the pdist kernel + jnp scatter in ops.py (no MXU win to be had).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 3.0e38
_PAD_COORD = 1.0e15


def _kernel(x_ref, w_ref, c_ref, sums_ref, counts_ref, assign_ref, dist_ref,
            *, sqrt: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.float32)            # (BN, d)
    w = w_ref[...].astype(jnp.float32)            # (BN, 1)
    c = c_ref[...].astype(jnp.float32)            # (K, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dist = jnp.maximum(x2 + c2[None, :] - 2.0 * xc, 0.0)   # (BN, K)
    if sqrt:
        dist = jnp.sqrt(dist)
    aloc = jnp.argmin(dist, axis=1).astype(jnp.int32)      # (BN,)
    dloc = jnp.min(dist, axis=1, keepdims=True)            # (BN, 1)
    assign_ref[...] = aloc[:, None]
    dist_ref[...] = dloc

    k = c.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
    onehot = (iota == aloc[:, None]).astype(jnp.float32) * w   # (BN, K)
    # scatter-add as MXU matmul: (K, BN) @ (BN, d)
    sums_ref[...] += jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).T  # (K, 1)


def _pad_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("metric", "bn", "interpret"))
def lloyd_step_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    c: jnp.ndarray,
    *,
    metric: str = "l2sq",
    bn: int = 1024,
    interpret: bool | None = None,
):
    if metric not in ("l2sq", "l2"):
        raise ValueError("lloyd kernel supports l2sq/l2; l1 uses the ops.py fallback")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    k = c.shape[0]
    bn = min(bn, _pad_to(n, 8))
    np_, kp, dp = _pad_to(n, bn), _pad_to(k, 128), _pad_to(d, 128)
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    wp = jnp.pad(w.reshape(-1, 1), ((0, np_ - n), (0, 0)))
    cp = jnp.pad(c, ((0, kp - k), (0, dp - d)), constant_values=_PAD_COORD)
    # keep genuine feature columns zero-padded (pad value applies everywhere,
    # so re-zero the d-padding for real rows):
    cp = cp.at[:k, d:].set(0.0)

    grid = (np_ // bn,)
    sums, counts, assign, dist = pl.pallas_call(
        functools.partial(_kernel, sqrt=(metric == "l2")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, dp), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
            pl.BlockSpec((kp, 1), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((np_, 1), jnp.int32),
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, wp, cp)
    return sums[:k, :d], counts[:k, 0], assign[:n, 0], dist[:n, 0]
