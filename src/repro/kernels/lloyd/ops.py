"""Public wrapper for the fused Lloyd step (assign + weighted accumulate).

Dispatch: Pallas kernel for l2sq/l2 (on TPU, or interpret mode for tests);
pure-jnp fallback otherwise (l1, or CPU production path where interpret mode
would be slow).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lloyd.ref import lloyd_step_ref


@functools.partial(jax.jit, static_argnames=("metric", "use_pallas"))
def lloyd_step(x, w, c, *, metric: str = "l2sq", use_pallas: bool = False):
    """Returns (sums (k,d), counts (k,), assignment (n,), dist (n,))."""
    if use_pallas and metric in ("l2sq", "l2"):
        from repro.kernels.lloyd.kernel import lloyd_step_pallas
        return lloyd_step_pallas(x, w, c, metric=metric)
    return lloyd_step_ref(x, w, c, metric)
