"""Public wrapper for the fused Lloyd step (assign + weighted accumulate).

Backends (registered with ``repro.kernels.dispatch``):

  * ``pallas``  — the fused TPU kernel (l2sq/l2 only: the assignment is an
    MXU matmul and the scatter-add becomes a one-hot matmul),
  * ``blocked`` — chunked ``min_argmin`` for the assignment + a one-hot
    matmul accumulate (any metric; bounded memory),
  * ``ref``     — the pure-jnp oracle in ``ref.py``.

``backend="auto"`` picks Pallas on TPU for l2sq/l2 and blocked elsewhere;
an explicit ``pallas`` policy under the l1 metric falls back the same way
the old inline ``if use_pallas and metric in ("l2sq", "l2")`` branch did.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.lloyd.ref import lloyd_step_ref
from repro.kernels.pdist.ops import min_argmin_blocked

_DEFAULT_BLOCK_N = 16384


def _lloyd_args(n: int, m: int, d: int, rng: np.random.Generator):
    x = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.uniform(0.0, 2.0, size=(n,)).astype(np.float32)
    c = rng.standard_normal((m, d)).astype(np.float32)
    return (x, w, c)


def accumulate_by_assignment(x, w, amin, k: int):
    """(sums (k,d), counts (k,)) of ``w``-weighted rows grouped by ``amin``.

    One-hot matmul instead of scatter-add: MXU-friendly on TPU, vectorized
    on CPU, and backend-agnostic — the re-accumulate half of k-means--'s
    outlier-corrected step for every backend.
    """
    onehot = (amin[:, None] == jnp.arange(k, dtype=amin.dtype)[None, :])
    onehot = onehot.astype(jnp.float32) * w[:, None]           # (n, k)
    sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    return sums, onehot.sum(axis=0)


@dispatch.register(
    "lloyd_step", "blocked",
    # cosine: the weighted-mean center update is the spherical k-means step
    # (only the mean's direction matters — distances normalize the center)
    supports=lambda metric, platform, dtype, n, m, d: metric in ("l2sq", "l2", "l1", "cosine"),
    priority=lambda platform: 1,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
    tune_candidates=(4096, 8192, 16384, 32768, 65536),
    make_args=_lloyd_args,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n"))
def lloyd_step_blocked(x, w, c, *, metric: str = "l2sq",
                       block_n: int = _DEFAULT_BLOCK_N):
    """Chunked assignment + one-hot matmul accumulate (bounded memory)."""
    dist, amin = min_argmin_blocked(x, c, metric=metric, block_n=block_n)
    sums, counts = accumulate_by_assignment(x, w, amin, c.shape[0])
    return sums, counts, amin, dist


@dispatch.register(
    "lloyd_step", "ref",
    supports=lambda metric, platform, dtype, n, m, d: metric in ("l2sq", "l2", "l1", "cosine"),
    priority=lambda platform: 0,
    default_block_n=lambda platform: _DEFAULT_BLOCK_N,
    make_args=_lloyd_args,
)
@functools.partial(jax.jit, static_argnames=("metric", "block_n"))
def lloyd_step_reference(x, w, c, *, metric: str = "l2sq", block_n: int = 0):
    return lloyd_step_ref(x, w, c, metric)


@dispatch.register(
    "lloyd_step", "pallas",
    supports=lambda metric, platform, dtype, n, m, d: metric in ("l2sq", "l2"),
    priority=lambda platform: 10 if platform == "tpu" else -1,
    default_block_n=lambda platform: 1024,
    tune_candidates=(512, 1024, 2048),
    make_args=_lloyd_args,
)
def lloyd_step_pallas_backend(x, w, c, *, metric: str = "l2sq",
                              block_n: int = 1024):
    from repro.kernels.lloyd.kernel import lloyd_step_pallas
    return lloyd_step_pallas(x, w, c, metric=metric, bn=block_n)


def lloyd_step(
    x,
    w,
    c,
    *,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
):
    """Returns (sums (k,d), counts (k,), assignment (n,), dist (n,))."""
    policy = dispatch.resolve_policy(policy, use_pallas=use_pallas,
                                     caller="lloyd_step")
    n, d = x.shape
    reg, bn = dispatch.resolve("lloyd_step", policy, metric=metric,
                               n=n, m=c.shape[0], d=d, dtype=x.dtype)
    return reg.impl(x, w, c, metric=metric, block_n=bn)
