"""``python -m repro`` — run a declarative pipeline config file."""
from repro.api.cli import main

main()
