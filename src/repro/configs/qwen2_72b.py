"""qwen2-72b — dense GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = FULL.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=512, dtype="float32")
