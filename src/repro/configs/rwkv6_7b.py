"""rwkv6-7b — Finch, attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="rwkv6-7b", family="rwkv6",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536, rwkv_head_dim=64,
)

SMOKE = FULL.replace(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                     head_dim=32, rwkv_head_dim=32, d_ff=128, vocab=512,
                     dtype="float32")
