"""llava-next-mistral-7b — Mistral backbone, anyres tiling stub
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]. Frontend is a stub:
input_specs() supplies 2880 precomputed patch embeddings (5 x 576 anyres
tiles, SigLIP/CLIP-dim 1152) projected + prepended to the text tokens."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, rope_theta=1e6,
    frontend="vlm_patches", frontend_tokens=2880, frontend_dim=1152,
)

SMOKE = FULL.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=512, frontend_tokens=8, frontend_dim=16,
                     dtype="float32")
