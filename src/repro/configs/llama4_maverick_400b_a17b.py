"""llama4-maverick-400b-a17b — MoE 128e top-1 + shared expert, early-fusion
image stub [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

16 GB/chip HBM at 256 chips requires bf16 optimizer moments (DESIGN §6);
recorded as part of the §Perf memory-term iteration."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=16384, vocab=202048,
    # assignment's d_ff=8192 is the EXPERT width (moe_d_ff); Maverick
    # interleaves MoE every other layer with dense d_ff=16384 between —
    # this is what lands the advertised 400B total / 17B active.
    n_experts=128, top_k=1, moe_d_ff=8192, moe_every=2, shared_expert_d_ff=8192,
    frontend="vlm_patches", frontend_tokens=1024, frontend_dim=1152,
    opt_state_dtype="bfloat16",
)

SMOKE = FULL.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=512, n_experts=8, top_k=1, moe_d_ff=64,
                     moe_every=2, shared_expert_d_ff=64, frontend_tokens=8, frontend_dim=16,
                     moe_group_tokens=32, dtype="float32",
                     opt_state_dtype="float32")
