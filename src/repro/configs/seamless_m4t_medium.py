"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596; hf].
The audio frontend is a stub: input_specs() supplies precomputed fbank-frame
embeddings (dim 80); encoder length = seq_len // 4 (conv downsampling)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    frontend="audio_frames", frontend_tokens=1024, frontend_dim=80,
)

SMOKE = FULL.replace(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=4, d_ff=128, vocab=512, frontend_tokens=8,
                     frontend_dim=16, dtype="float32")
