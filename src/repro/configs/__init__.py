"""Architecture registry: one module per assigned architecture.

get_config(arch_id, smoke=False) returns the exact assigned config (FULL)
or the reduced same-family config used by CPU smoke tests (SMOKE).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen2-72b": "qwen2_72b",
    "granite-20b": "granite_20b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS = list(_MODULES)

# SPerf winners (EXPERIMENTS.md): per-arch beyond-baseline settings found by
# the hillclimbing loop: cfg overrides + logical (data, model) re-mesh of
# the same 256-chip pod. Applied by `dryrun --optimized`.
OPTIMIZED = {
    "qwen2-72b": ({"attn_chunk_remat": True}, (128, 2)),
    "rwkv6-7b": ({"wkv_inner_remat": True, "wkv_chunk": 64}, (128, 2)),
    "qwen3-moe-235b-a22b": ({"attn_chunk_remat": True, "moe_group_tokens": 512}, (128, 2)),
    # sensible defaults for the non-hillclimbed archs (same levers):
    "qwen2.5-32b": ({"attn_chunk_remat": True}, (128, 2)),
    "granite-20b": ({"attn_chunk_remat": True}, (128, 2)),
    "llava-next-mistral-7b": ({"attn_chunk_remat": True}, (128, 2)),
    "h2o-danube-1.8b": ({"attn_chunk_remat": True}, (128, 2)),
    "seamless-m4t-medium": ({"attn_chunk_remat": True}, (128, 2)),
    "llama4-maverick-400b-a17b": ({"attn_chunk_remat": True}, (64, 4)),
    "recurrentgemma-9b": ({"attn_chunk_remat": True}, (128, 2)),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.FULL
