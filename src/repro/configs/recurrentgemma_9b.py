"""recurrentgemma-9b — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427; unverified]. Sub-quadratic -> long_500k runs."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b", family="rglru_hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    rec_per_attn=2, local_window=2048, lru_width=4096,
)

SMOKE = FULL.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=1,
                     head_dim=16, d_ff=128, vocab=512, rec_per_attn=2,
                     local_window=16, lru_width=64, dtype="float32")
