"""qwen3-moe-235b-a22b — 128 experts top-8, head_dim=128 (64x128 != d_model)
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    n_experts=128, top_k=8, moe_d_ff=1536,
    opt_state_dtype="bfloat16",
)

SMOKE = FULL.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=64, vocab=512, n_experts=8, top_k=2,
                     moe_d_ff=64, moe_group_tokens=32, dtype="float32",
                     opt_state_dtype="float32")
