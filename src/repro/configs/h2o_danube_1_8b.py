"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf]. SWA makes it sub-quadratic -> long_500k runs."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, sliding_window=4096, rope_theta=10000.0,
)

SMOKE = FULL.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=512, sliding_window=16, dtype="float32")
