"""Gradient compression for cross-pod data parallelism.

Two schemes, both with error feedback:

* bf16 — cast grads to bf16 before the all-reduce (2x wire bytes saved);
  residual = fp32 - bf16 accumulates locally and is re-added next step.
* int8 — per-leaf symmetric quantization (scale = max|g|/127); 4x saved.

On a GSPMD train_step the data-parallel all-reduce is implicit, so the
compression hook is exposed as a pair (encode, decode) applied around the
`jax.lax.pmean`/psum in the shard_map training path (runtime/robust_agg,
examples/robust_training) and is lowered in the dry-run's multi-pod mesh via
the `grad_compression` train-step option (cast -> pseudo-allreduce -> cast).

Error feedback keeps the scheme unbiased over time: e_{t+1} = g_t - Q(g_t + e_t).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # same structure as grads, fp32


def init_ef(grads_like) -> EFState:
    return EFState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def encode_bf16(grads, ef: EFState):
    def enc(g, r):
        gf = g.astype(jnp.float32) + r
        q = gf.astype(jnp.bfloat16)
        return q, gf - q.astype(jnp.float32)
    pairs = jax.tree.map(enc, grads, ef.residual)
    q = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return q, EFState(r)


def decode_bf16(q):
    return jax.tree.map(lambda g: g.astype(jnp.float32), q)


def encode_int8(grads, ef: EFState):
    def enc(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.abs(gf).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), gf - deq
    pairs = jax.tree.map(enc, grads, ef.residual)
    q = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return q, EFState(r)


def decode_int8(q):
    def dec(pair):
        qq, scale = pair
        return qq.astype(jnp.float32) * scale
    return jax.tree.map(dec, q, is_leaf=lambda x: isinstance(x, tuple))
