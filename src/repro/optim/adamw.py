"""AdamW with dtype-configurable moments, global-norm clipping, cosine
schedule, and optional gradient compression — hand-rolled (no optax in the
offline container), pytree-generic.

Moments dtype matters at 400B scale: fp32 m+v is 8 bytes/param; at 256
chips llama4-maverick would not fit 16 GB HBM with fp32 moments + fp32
master params (DESIGN §6), so cfg.opt_state_dtype="bfloat16" stores moments
in bf16 with stochastic-free simple rounding (error feedback absorbed by
Adam's own EMA smoothing).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def lr_schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    t = (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = c.lr_min_ratio + (1 - c.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return c.lr_peak * jnp.where(step < c.warmup_steps, warm, cos)


def init(params, c: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(c.state_dtype)
    def zeros(p):
        return jnp.zeros_like(p, dtype=dt)
    return AdamWState(step=jnp.int32(0),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalar gates."""
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    flat = "/".join(str(n) for n in names)
    return not any(s in flat for s in ("scale", "ln", "bias", "b_", "mu", "u", "lam",
                                       "gate_", "w0", "kpos"))


def apply(params, grads, state: AdamWState, c: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, c.clip_norm)
    step = state.step + 1
    lr = lr_schedule(c, step)
    b1, b2 = c.b1, c.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(c.state_dtype)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + c.eps)
        if _decay_mask(path):
            delta = delta + c.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    out = jax.tree_util.tree_map_with_path(upd, params, grads, state.m, state.v)
    treedef = jax.tree.structure(params)
    flat = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
