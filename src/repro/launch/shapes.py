"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

Four shapes per LM arch (40 cells):
  train_4k     seq 4096,   batch 256  -> train_step
  prefill_32k  seq 32768,  batch 32   -> prefill_step
  decode_32k   seq 32768,  batch 128  -> serve_step (1 token, cache = seq)
  long_500k    seq 524288, batch 1    -> serve_step; SUB-QUADRATIC archs only
               (rwkv6 / rglru hybrid / SWA); full-attention archs record the
               skip (DESIGN §5).

``[audio]``/``[vlm]`` frontends are stubs: specs provide precomputed frame /
patch embeddings. Encoder frames = seq_len // 4 (conv downsampling).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

S = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention is O(S^2): long_500k runs only for SSM/hybrid/SWA archs"
    return True, ""


def input_structs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the model-input batch of a train/prefill cell."""
    B, L = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {
            "frames": S((B, max(L // 4, 8), cfg.frontend_dim), jnp.float32),
            "tokens": S((B, L), jnp.int32),
        }
    if cfg.frontend == "vlm_patches":
        s_text = L - cfg.frontend_tokens
        assert s_text > 0
        return {
            "patches": S((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32),
            "tokens": S((B, s_text), jnp.int32),
        }
    return {"tokens": S((B, L), jnp.int32)}


def decode_structs(cfg: ModelConfig, shape: ShapeSpec) -> tuple[dict, dict]:
    """(cache structs, token struct) for a decode cell: one new token with a
    cache that has already absorbed seq_len tokens."""
    from repro.models.transformer import init_cache
    B, L = shape.global_batch, shape.seq_len
    cfg_d = cfg
    if cfg.family == "encdec":
        cfg_d = cfg.replace(frontend_tokens=max(L // 4, 8))
    cache = jax.eval_shape(lambda: init_cache(cfg_d, B, L))
    tokens = S((B, 1), jnp.int32)
    return cache, tokens


def concrete_batch(cfg: ModelConfig, seq_len: int, batch: int, key) -> dict:
    """Concrete small batch for smoke tests/examples (same layout as
    input_structs)."""
    structs = input_structs(cfg, ShapeSpec("adhoc", seq_len, batch, "train"))
    out = {}
    for k, st in structs.items():
        key, sk = jax.random.split(key)
        if st.dtype == jnp.int32:
            out[k] = jax.random.randint(sk, st.shape, 0, cfg.vocab)
        else:
            out[k] = jax.random.normal(sk, st.shape, st.dtype)
    return out
