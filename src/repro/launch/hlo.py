"""Trip-count-aware cost model over the optimized, SPMD-partitioned HLO.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
but jax lowers ``lax.scan`` (our layer stack, q-chunked attention, WKV
chunks) to while loops, so FLOPs/bytes/collectives would be off by a factor
of n_layers (verified in EXPERIMENTS §Dry-run).  XLA annotates every scan
loop with ``backend_config={"known_trip_count":{"n": L}}``, so an
HLO-text walk can do the multiplication properly:

  flops(while)  = trip * flops(body) + (trip+1) * flops(cond)
  flops(fusion) = sum of arithmetic inside the fused computation
  flops(dot)    = 2 * prod(output dims) * prod(contracting dims)

  bytes: TWO models are reported.  ``hbm_bytes_raw`` bills operands+outputs
  of every scheduled instruction — an upper bound, badly inflated on the
  CPU backend whose scheduler barely fuses elementwise chains a TPU would
  fuse.  ``hbm_bytes`` (the roofline input) emulates TPU fusion: traffic is
  billed only at MATERIALIZATION points — dot/conv/reduce operands+outputs,
  copies (sharding transitions), dynamic-(update-)slice, gather/scatter,
  concatenate, sort, collectives, and explicit fusion boundaries; pure
  elementwise/layout ops are treated as fused into their consumers.  The
  truth on real hardware lies between the two; both appear in EXPERIMENTS
  §Roofline and the gap is listed per cell.

  collectives: per-op ring-model wire bytes (see _WIRE below), multiplied
  by enclosing loop trip counts — this is what makes per-layer all-gathers
  visible in the roofline.

The parser handles the stable HLO text format: computations headed by
``%name (params) -> type {`` / ``ENTRY``, instructions ``%n = type op(...)``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# pure data movement — no arithmetic
_FREE_FLOPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "transpose", "reshape", "broadcast",
    "concatenate", "slice", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "pad", "reverse", "iota", "convert",
    "after-all", "custom-call", "optimization-barrier", "rng-get-and-update-state",
    "infeed", "outfeed", "partition-id", "replica-id", "domain",
}
# ops that don't touch HBM themselves
_FREE_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "optimization-barrier", "partition-id",
               "replica-id", "domain", "iota"}

# materialization points for the fusion-emulating byte model (see docstring)
_MATERIALIZE = {"dot", "convolution", "reduce", "reduce-window", "sort",
                "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
                "concatenate", "copy", "select-and-scatter", "fft",
                "triangular-solve", "cholesky", "rng", "rng-bit-generator"}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OPCALL = re.compile(r"^(.*?)\s([a-z][a-z0-9\-]*)\((.*)$", re.S)
_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP = re.compile(r'known_trip_count[^\d]*(\d+)')
_IOTA_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_LIST_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_ATTR = re.compile(r"condition=%([\w.\-]+)")
_BODY_ATTR = re.compile(r"body=%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes(type_str: str) -> int:
    """Size of a (possibly tuple) type string."""
    return sum(_numel(m) * _DTYPE_BYTES.get(m.group(1), 0)
               for m in _SHAPE_TOKEN.finditer(type_str))


def _numel(m) -> int:
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _type_elems(type_str: str) -> int:
    return sum(_numel(m) for m in _SHAPE_TOKEN.finditer(type_str))


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # name -> type string


def _split_top(args: str) -> list:
    """Split operand list at depth 0 (handles nested parens/braces)."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _HEADER.match(line)
            if m and line.endswith("{"):
                cur = Computation(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    comps["__entry__"] = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        mo = _OPCALL.match(rest)
        if not mo:
            continue
        ty, op, tail = mo.group(1).strip(), mo.group(2), mo.group(3)
        # split tail into (operand args up to matching paren, attrs)
        depth, j = 1, 0
        while j < len(tail) and depth:
            if tail[j] == "(":
                depth += 1
            elif tail[j] == ")":
                depth -= 1
            j += 1
        args, attrs = tail[: j - 1], tail[j:]
        operands = [a.split()[-1].lstrip("%") for a in _split_top(args)
                    if a and "%" in a]
        cur.instrs.append(Instr(name, ty, op, operands, attrs, line))
        cur.types[name] = ty
    return comps


def _group_size(attrs: str) -> int:
    m = _IOTA_GROUPS.search(attrs)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS.search(attrs)
    if m:
        return len(m.group(1).split(","))
    if "source_target_pairs" in attrs:
        return 2
    return 1


def _wire_bytes(op: str, s: float, n: int) -> float:
    if op == "all-gather":
        return s * (n - 1)
    if op == "reduce-scatter":
        return s * (n - 1) / max(n, 1)
    if op == "all-reduce":
        return 2.0 * s * (n - 1) / max(n, 1)
    if op == "all-to-all":
        return s * (n - 1) / max(n, 1)
    return float(s)  # collective-permute


class HloCost:
    """Recursive, memoized cost over the computation graph."""

    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict[str, tuple] = {}
        self._ew_memo: dict[str, bool] = {}

    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        return float(sum(_type_bytes(comp.types.get(o, "")) for o in ins.operands))

    def _pure_elementwise(self, cname: str) -> bool:
        """True if a computation contains only elementwise/layout ops — the
        CPU backend wraps every such op in its own kLoop fusion, which a TPU
        would fuse into neighbours, so these don't count as HBM traffic in
        the fusion-emulating byte model."""
        if cname in self._ew_memo:
            return self._ew_memo[cname]
        comp = self.comps.get(cname)
        ok = comp is not None
        heavy = {"dot", "convolution", "reduce", "reduce-window", "sort",
                 "gather", "scatter", "dynamic-update-slice", "while",
                 "fusion", "call", "conditional",
                 "select-and-scatter"} | set(_COLLECTIVES)
        if comp is not None:
            for ins in comp.instrs:
                base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
                if base in heavy:
                    ok = False
                    break
        self._ew_memo[cname] = ok
        return ok

    def cost(self, cname: str) -> tuple:
        """-> (flops, hbm_bytes_fused, hbm_bytes_raw,
               {op: {count, wire_bytes, operand_bytes}})"""
        if cname in self._memo:
            return self._memo[cname]
        comp = self.comps.get(cname)
        if comp is None:
            return 0.0, 0.0, 0.0, {}
        flops = 0.0
        bf = 0.0   # fusion-emulating byte model (roofline input)
        br = 0.0   # raw every-op upper bound
        coll: dict = defaultdict(lambda: defaultdict(float))

        def acc(sub, mult=1.0):
            nonlocal flops, bf, br
            f, b1, b2, c = sub
            flops += mult * f
            bf += mult * b1
            br += mult * b2
            for k, v in c.items():
                for kk, vv in v.items():
                    coll[k][kk] += mult * vv

        for ins in comp.instrs:
            op = ins.opcode
            out_b = _type_bytes(ins.type_str)
            out_e = _type_elems(ins.type_str)
            io_b = self._operand_bytes(comp, ins) + out_b
            if op == "while":
                trip = 1
                mt = _TRIP.search(ins.attrs)
                if mt:
                    trip = max(int(mt.group(1)), 1)
                body = _BODY_ATTR.search(ins.attrs)
                cond = _COND_ATTR.search(ins.attrs)
                if body:
                    acc(self.cost(body.group(1)), trip)
                if cond:
                    acc(self.cost(cond.group(1)), trip + 1)
                continue
            if op in ("fusion", "call", "async-start"):
                mcall = _CALL_ATTR.search(ins.attrs)
                fusable = False
                if mcall:
                    f, _, _, c = self.cost(mcall.group(1))
                    flops += f
                    for k, v in c.items():
                        for kk, vv in v.items():
                            coll[k][kk] += vv
                    fusable = op == "fusion" and self._pure_elementwise(mcall.group(1))
                if not fusable:  # real materialization boundary
                    bf += io_b
                br += io_b
                continue
            if op == "conditional":
                for mm in re.finditer(r"%([\w.\-]+)", ins.attrs):
                    if mm.group(1) in self.comps:
                        acc(self.cost(mm.group(1)))
                continue
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                s = self._operand_bytes(comp, ins)
                n = _group_size(ins.attrs)
                coll[base_op]["count"] += 1
                coll[base_op]["operand_bytes"] += s
                coll[base_op]["wire_bytes"] += _wire_bytes(base_op, s, n)
                bf += s + out_b
                br += s + out_b
                continue
            if op == "dot":
                k = 1
                mc = _CONTRACT.search(ins.attrs)
                if mc and ins.operands:
                    lhs_ty = comp.types.get(ins.operands[0], "")
                    ms = _SHAPE_TOKEN.search(lhs_ty)
                    if ms:
                        dims = [int(d) for d in ms.group(2).split(",") if d]
                        for ci in mc.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                flops += 2.0 * out_e * k
                bf += io_b
                br += io_b
                continue
            if op in ("reduce", "reduce-window", "select-and-scatter"):
                flops += float(sum(_type_elems(comp.types.get(o, ""))
                                   for o in ins.operands))
                bf += io_b
                br += io_b
                continue
            if op in _MATERIALIZE:
                flops += 2.0 * out_e if op == "convolution" else 0.0
                bf += io_b
                br += io_b
                continue
            if op in _FREE_FLOPS:
                if op not in _FREE_BYTES:
                    br += io_b
                continue
            # generic elementwise arithmetic: flops yes, fused-bytes no
            flops += float(out_e)
            br += io_b
        res = (flops, bf, br, {k: dict(v) for k, v in coll.items()})
        self._memo[cname] = res
        return res


def analyze(text: str) -> dict:
    hc = HloCost(text)
    entry = "__entry__"
    if entry not in hc.comps:  # fall back: biggest computation
        entry = max(hc.comps, key=lambda c: len(hc.comps[c].instrs))
    flops, bf, br, coll = hc.cost(entry)
    total_wire = sum(v.get("wire_bytes", 0.0) for v in coll.values())
    return {"flops": flops, "hbm_bytes": bf, "hbm_bytes_raw": br,
            "collectives": coll, "total_wire_bytes": total_wire}


def collective_stats(hlo_text: str) -> dict:
    """Back-compat shim over analyze()."""
    a = analyze(hlo_text)
    out = dict(a["collectives"])
    out["total_wire_bytes"] = a["total_wire_bytes"]
    return out
