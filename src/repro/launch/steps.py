"""Step builders: train_step (fwd+bwd+AdamW), prefill_step, serve_step.

These are the programs the dry-run lowers and the launchers run; the same
builders serve single-device smoke tests (mesh=None).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx
from repro.models.sharding import fsdp_axes
from repro.models.transformer import (forward_decode, forward_prefill,
                                      forward_train)
from repro.optim import adamw


def make_ctx(cfg: ModelConfig, mesh: Mesh | None) -> ShardCtx:
    if mesh is None:
        return ShardCtx(mesh=None)
    return ShardCtx(mesh=mesh, batch=fsdp_axes(mesh), model="model",
                    seq_shard=cfg.seq_shard_activations)


def make_train_step(cfg: ModelConfig, mesh: Mesh | None,
                    optc: adamw.AdamWConfig | None = None):
    optc = optc or adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    ctx = make_ctx(cfg, mesh)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = forward_train(p, batch, cfg, ctx)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_state, om = adamw.apply(params, grads, opt_state, optc)
        return new_params, new_state, dict(metrics, loss=loss, **om)

    return train_step, optc


def make_prefill_step(cfg: ModelConfig, mesh: Mesh | None):
    ctx = make_ctx(cfg, mesh)

    def prefill_step(params, batch):
        return forward_prefill(params, batch, cfg, ctx)

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh: Mesh | None):
    ctx = make_ctx(cfg, mesh)

    def serve_step(params, cache, tokens):
        return forward_decode(params, cache, tokens, cfg, ctx)

    return serve_step
