"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch <id> [--smoke] \
        [--steps N] [--mesh auto|single|multi] [--ckpt-dir DIR] \
        [--set key=value ...]

On this container (1 CPU device) use --smoke for the reduced config; on a
real slice the same entry point builds the production mesh, shards params
with models/sharding.py, and runs the jit'd train step with async
checkpointing, straggler monitoring, and (optionally) the paper's data
curation in the loop (see examples/train_curated_lm.py for the wired-up
curation flow).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import PipelineConfig, TokenPipeline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step
from repro.models.sharding import param_specs
from repro.models.transformer import init_params
from repro.optim import adamw
from repro.runtime.straggler import StragglerMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="auto", choices=["auto", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    if overrides:
        cfg = cfg.replace(**overrides)

    n_dev = len(jax.devices())
    mesh = None
    if args.mesh != "auto" or n_dev >= 256:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    print(f"arch={cfg.name} devices={n_dev} mesh="
          f"{dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else None}")

    key = jax.random.key(args.seed)
    params = init_params(cfg, key)
    step_fn, optc = make_train_step(cfg, mesh)
    opt = adamw.init(params, optc)
    if mesh is not None:
        pspecs = param_specs(jax.eval_shape(lambda: params), mesh,
                             fsdp_params=(cfg.zero_stage >= 3))
        params = jax.device_put(params, pspecs)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=args.seq,
                                        global_batch=args.batch,
                                        seed=args.seed))
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=3)
    monitor = StragglerMonitor(n_sites=max(n_dev, 1))

    start = 0
    if ckpt.latest_step() is not None:
        (params, opt), start = ckpt.restore((params, opt))
        start += 1
        print(f"resumed from step {start - 1}")

    for step in range(start, args.steps):
        batch = {"tokens": jnp.asarray(pipe.global_batch(step)["tokens"])}
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        monitor.observe(np.full(max(n_dev, 1), dt, np.float32))
        if step % 10 == 0:
            print(f"step {step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms")
        if step % args.ckpt_every == args.ckpt_every - 1:
            ckpt.save(step, (params, opt))
    ckpt.wait()
    print(f"done; checkpoints at {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
