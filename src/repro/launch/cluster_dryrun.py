import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run the PAPER'S OWN JOB on the production pod: Algorithm 3 with one
site per chip (256 sites single-pod / 512 multi-pod), lowering the full
summary-construction + all_gather + second-level program and extracting the
same roofline terms as the LM cells.

This is the cell "most representative of the paper's technique": it shows
the technique's signature — per-site O(max{k,log n}·n) compute against ONE
all-gather of O(k log n + t/s) records — as a compute-vs-collective ratio
on real mesh geometry.

  PYTHONPATH=src python -m repro.launch.cluster_dryrun [--n-per-site 65536]
      [--k 100] [--t 131072] [--d 32] [--multi]
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import distributed_cluster
from repro.kernels.dispatch import KernelPolicy
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS, _jsonable
from repro.launch.hlo import analyze as analyze_hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-per-site", type=int, default=65536)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--t", type=int, default=131072)  # ~0.8% of 16.7M points
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    s = 512 if args.multi else 256
    mesh = jax.make_mesh((s,), ("sites",), devices=jax.devices()[:s])
    n, d = args.n_per_site, args.d
    x_s = jax.ShapeDtypeStruct((s, n, d), jnp.float32)
    key_s = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)

    def job(x, key):
        return distributed_cluster(x, key, mesh, k=args.k, t=args.t,
                                   summary_alg="plain",
                                   policy=KernelPolicy(block_n=16384))

    t0 = time.time()
    lowered = jax.jit(job, in_shardings=(NamedSharding(mesh, P("sites")),
                                         None)).lower(x_s, key_s)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    an = analyze_hlo(compiled.as_text())
    flops, bts, wire = an["flops"], an["hbm_bytes"], an["total_wire_bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    collective_s = wire / LINK_BW
    rec = {
        "arch": "cluster-job(paper)", "shape": f"s{s}_n{n}_k{args.k}_t{args.t}",
        "mesh": ("multi" if args.multi else "single"),
        "chips": s, "status": "ok", "compile_s": round(t_compile, 2),
        "hlo_flops": flops, "hlo_bytes": bts, "wire_bytes": wire,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max((("compute", compute_s), ("memory", memory_s),
                           ("collective", collective_s)),
                          key=lambda kv: kv[1])[0],
        "collectives": an["collectives"],
    }
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    tag = f"cluster-job__{rec['shape']}__{rec['mesh']}"
    (out / f"{tag}.json").write_text(json.dumps(_jsonable(rec), indent=1))
    print(f"compiled in {t_compile:.1f}s on {s} sites")
    print(f"compute {compute_s:.4f}s  memory {memory_s:.4f}s  "
          f"collective {collective_s:.6f}s  -> {rec['bottleneck']}-bound")
    print({k: (v['count'], round(v['wire_bytes'] / 1e6, 2))
           for k, v in an["collectives"].items()})


if __name__ == "__main__":
    main()
