"""Standalone distributed clustering job — the paper's algorithm on a mesh.

    PYTHONPATH=src python -m repro.launch.cluster_job --sites 8 \
        --dataset gauss --k 20 --t 400

Each device is a site (Algorithm 3). On one device it degrades to s=1.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import distributed_cluster
from repro.core.metrics import clustering_losses, outlier_scores
from repro.data.synthetic import gauss, kdd_like, partition, susy_like
from repro.launch.mesh import make_site_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="gauss",
                    choices=["gauss", "kdd", "susy"])
    ap.add_argument("--sites", type=int, default=0, help="0 = all devices")
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--t", type=int, default=400)
    ap.add_argument("--n", type=int, default=40_000)
    ap.add_argument("--partition", default="random",
                    choices=["random", "adversarial"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.dataset == "gauss":
        x, out_ids = gauss(n_centers=args.k, per_center=args.n // args.k,
                           t=args.t, seed=args.seed)
    elif args.dataset == "kdd":
        x, out_ids = kdd_like(n=args.n, seed=args.seed)
    else:
        x, out_ids = susy_like(n=args.n, t=args.t, seed=args.seed)

    s = args.sites or len(jax.devices())
    mesh = make_site_mesh(s)
    parts, gids = partition(x, s, args.partition, seed=args.seed,
                            outlier_ids=out_ids)
    xs = jnp.asarray(np.stack(parts))

    t0 = time.perf_counter()
    res = distributed_cluster(xs, jax.random.key(args.seed), mesh,
                              k=args.k, t=args.t, partition=args.partition)
    jax.block_until_ready(res.centers)
    dt = time.perf_counter() - t0

    conc = np.concatenate(gids)
    oi = np.asarray(res.outlier_ids)
    reported = conc[oi[oi >= 0]]
    si = np.asarray(res.summary_ids)
    sc = outlier_scores(out_ids, conc[si[si >= 0]], reported)
    mask = np.zeros(x.shape[0], bool)
    mask[reported] = True
    l1, l2 = clustering_losses(jnp.asarray(x), res.centers, jnp.asarray(mask))

    print(f"sites={s} n={x.shape[0]} partition={args.partition} "
          f"wall={dt:.2f}s (incl. jit)")
    print(f"communication: {float(res.comm_records):.0f} records "
          f"({100 * float(res.comm_records) / x.shape[0]:.2f}% of data)")
    print(f"l1={float(l1):.5g} l2={float(l2):.5g}")
    print(f"preRec={sc.pre_recall:.4f} prec={sc.precision:.4f} "
          f"recall={sc.recall:.4f}")


if __name__ == "__main__":
    main()
