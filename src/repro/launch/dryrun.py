import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any other import: jax locks the device count on first
# init, and the dry-run needs 512 placeholder devices for the 2x16x16 mesh.

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, GSPMD-partitions, and compiles — and extract the roofline terms.

Per cell:
  jax.jit(step, in_shardings=..., out_shardings=..., donate).lower(structs)
  .compile() -> memory_analysis() + cost_analysis() + collective parse of
  the partitioned HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 512-chip
Artifacts: one JSON per cell under artifacts/dryrun/.
"""
import argparse
import functools
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo import analyze as analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported, decode_structs, input_structs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.sharding import batch_specs, cache_specs, param_specs
from repro.models.transformer import init_params
from repro.optim import adamw

# TPU v5e per-chip peaks (roofline constants; see EXPERIMENTS §Roofline)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link


def _jsonable(x):
    if isinstance(x, (int, float, str, bool)) or x is None:
        return x
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return str(x)


def replicated(mesh):
    return NamedSharding(mesh, P())


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               dump_hlo: str | None = None, cfg_overrides: dict | None = None,
               dp_tp: tuple | None = None):
    """Lower + compile one cell; returns the stats dict."""
    mesh = make_production_mesh(multi_pod=multi_pod, dp_tp=dp_tp)
    chips = mesh.devices.size
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    t0 = time.time()
    key = jax.random.key(0)
    params_s = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = param_specs(params_s, mesh, fsdp_params=(cfg.zero_stage >= 3))

    if shape.kind == "train":
        step, optc = make_train_step(cfg, mesh)
        opt_s = jax.eval_shape(functools.partial(adamw.init, c=optc), params_s)
        ospecs = adamw.AdamWState(step=replicated(mesh),
                                  m=param_specs(opt_s.m, mesh),
                                  v=param_specs(opt_s.v, mesh))
        batch_s = input_structs(cfg, shape)
        bspecs = batch_specs(batch_s, mesh)
        jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                         out_shardings=(pspecs, ospecs, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_s, opt_s, batch_s)
    elif shape.kind == "prefill":
        batch_s = input_structs(cfg, shape)
        bspecs = batch_specs(batch_s, mesh)
        jitted = jax.jit(make_prefill_step(cfg, mesh),
                         in_shardings=(pspecs, bspecs))
        lowered = jitted.lower(params_s, batch_s)
    else:  # decode
        cache_s, tok_s = decode_structs(cfg, shape)
        cfg_d = cfg.replace(frontend_tokens=max(shape.seq_len // 4, 8)) \
            if cfg.family == "encdec" else cfg
        step = make_serve_step(cfg_d, mesh)
        cspecs = cache_specs(cache_s, mesh)
        from repro.models.sharding import fix_divisibility
        tspec = NamedSharding(mesh, fix_divisibility(
            P(tuple(a for a in mesh.axis_names if a != "model"), None),
            tok_s.shape, mesh))
        jitted = jax.jit(step, in_shardings=(pspecs, cspecs, tspec),
                         out_shardings=(None, cspecs), donate_argnums=(1,))
        lowered = jitted.lower(params_s, cache_s, tok_s)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    if dump_hlo:
        Path(dump_hlo).write_text(hlo)
    an = analyze_hlo(hlo)   # trip-count-aware (cost_analysis counts scan
    coll = an["collectives"]  # bodies once — see launch/hlo.py docstring)

    flops = float(an["flops"])
    bytes_accessed = float(an["hbm_bytes"])
    wire = float(an["total_wire_bytes"])

    # roofline terms (seconds). cost_analysis is per-partition (the compiled
    # module is the per-device SPMD program), so divide only where global.
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = wire / LINK_BW

    model_flops = 6 * cfg.param_count(active_only=True) * _tokens(shape, cfg)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": int(chips),
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_flops": flops, "hlo_bytes": bytes_accessed,
        "hlo_bytes_raw": float(an["hbm_bytes_raw"]),
        "wire_bytes": wire,
        "raw_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                              "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(
            (("compute", compute_s), ("memory", memory_s),
             ("collective", collective_s)), key=lambda kv: kv[1])[0],
        "model_flops_global": float(model_flops),
        "model_flops_per_chip": float(model_flops / chips),
        "useful_flops_ratio": float(model_flops / chips / flops) if flops else None,
        "memory": mem_d,
        "collectives": coll,
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    return rec


def _tokens(shape, cfg) -> int:
    """Tokens processed per step (for MODEL_FLOPS = 6*N*D):
    train/prefill: B*S (prefill is forward-only: 2*N*D, folded via factor);
    decode: B tokens."""
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        # forward only = 2ND of the 6ND -> scale token count by 1/3
        return shape.global_batch * shape.seq_len // 3
    return shape.global_batch // 3 or 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--optimized", action="store_true",
                    help="apply the per-arch §Perf winners (configs.OPTIMIZED)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from repro.configs import OPTIMIZED
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                suffix = "-opt" if args.optimized else ""
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}{suffix}"
                path = out_dir / f"{tag}.json"
                ov, dp_tp = (OPTIMIZED.get(arch, ({}, None))
                             if args.optimized else ({}, None))
                try:
                    rec = lower_cell(arch, shape, mp, cfg_overrides=ov or None,
                                     dp_tp=dp_tp)
                    if args.optimized and isinstance(rec, dict):
                        rec["mesh"] = rec.get("mesh", "single") + "-opt"
                        rec["optimized"] = {"overrides": ov, "dp_tp": dp_tp}
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "fail", "error": str(e),
                           "trace": traceback.format_exc()[-2000:]}
                path.write_text(json.dumps(_jsonable(rec), indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "fail"
                msg = {"ok": f"compile {rec.get('compile_s')}s flops/chip {rec.get('hlo_flops', 0):.3g}",
                       "skipped": rec.get("reason", ""),
                       "fail": rec.get("error", "")[:200]}[st]
                print(f"[{st:7s}] {tag}: {msg}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
