import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimbing helper: lower one cell (optionally with config overrides),
dump the partitioned HLO, and print the top collectives / dots / copies by
trip-count-weighted bytes.

  PYTHONPATH=src python -m repro.launch.inspect_cell qwen2-72b train_4k \
      [--multi] [--set remat_policy=dots] [--top 15]
"""
import argparse
import json

from repro.launch import hlo as H
from repro.launch.dryrun import lower_cell


def walk_detail(text: str, kinds=("collective", "dot", "copy", "fusion")):
    hc = H.HloCost(text)
    rows = []

    def walk(cname, mult, depth=0):
        comp = hc.comps.get(cname)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            out_b = H._type_bytes(ins.type_str)
            io_b = hc._operand_bytes(comp, ins) + out_b
            if op == "while":
                trip = 1
                mt = H._TRIP.search(ins.attrs)
                if mt:
                    trip = max(int(mt.group(1)), 1)
                b = H._BODY_ATTR.search(ins.attrs)
                if b:
                    walk(b.group(1), mult * trip, depth + 1)
                continue
            if op in ("fusion", "call"):
                m = H._CALL_ATTR.search(ins.attrs)
                if m and not hc._pure_elementwise(m.group(1)):
                    rows.append(("fusion", mult * io_b, mult, ins.line[:170]))
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in H._COLLECTIVES:
                s = hc._operand_bytes(comp, ins)
                n = H._group_size(ins.attrs)
                wire = H._wire_bytes(base, s, n)
                rows.append((base, mult * wire, mult, ins.line[:170]))
            elif op == "dot":
                rows.append(("dot", mult * io_b, mult, ins.line[:170]))
            elif op == "copy":
                rows.append(("copy", mult * io_b, mult, ins.line[:170]))

    walk("__entry__", 1.0)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--dp-tp", default=None,
                    help="logical mesh reshape, e.g. 64,4")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--kind", default=None,
                    help="filter: all-gather/all-reduce/dot/copy/fusion/...")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    hlo_path = f"/tmp/{args.arch}_{args.shape}.hlo"
    dp_tp = tuple(int(v) for v in args.dp_tp.split(",")) if args.dp_tp else None
    rec = lower_cell(args.arch, args.shape, args.multi, dump_hlo=hlo_path,
                     cfg_overrides=overrides or None, dp_tp=dp_tp)
    for k in ("hlo_flops", "hlo_bytes", "wire_bytes", "compute_s", "memory_s",
              "collective_s", "bottleneck", "useful_flops_ratio"):
        print(f"{k:22s} {rec.get(k)}")
    print(f"collectives: { {k: (v['count'], round(v['wire_bytes']/1e9, 2)) for k, v in rec.get('collectives', {}).items()} }")
    print(f"\nHLO at {hlo_path}; top-{args.top} contributors:")
    rows = walk_detail(open(hlo_path).read())
    if args.kind:
        rows = [r for r in rows if r[0] == args.kind]
    rows.sort(key=lambda r: -r[1])
    for kind, b, mult, line in rows[: args.top]:
        print(f"  {kind:12s} {b/1e9:9.2f} GB x{mult:<5.0f} {line[:130]}")


if __name__ == "__main__":
    main()
