"""Production meshes.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod slice)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips; the ``pod`` axis is
the slow (DCN/ICI-bridge) axis — only data parallelism (gradient
all-reduce, optionally compressed) crosses it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, dp_tp: tuple | None = None):
    """dp_tp: optional (data, model) LOGICAL reshape of the same chips —
    the §Perf re-mesh lever (e.g. (64, 4) trades TP degree for DP width on
    the identical 256-chip pod; both embed on the 2D ICI torus)."""
    if dp_tp is not None:
        d, m = dp_tp
        shape = (2, d, m) if multi_pod else (d, m)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_site_mesh(n_sites: int | None = None):
    """1-D mesh over ``sites`` for the paper's distributed clustering job
    (Algorithm 3) and the sharded streaming service: one site per device.
    Delegates to ``repro.core.collective`` so the one-shot and streaming
    paths share one definition of the sites axis."""
    from repro.core.collective import sites_mesh
    return sites_mesh(n_sites)
