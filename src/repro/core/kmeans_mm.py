"""k-means-- (Chawla & Gionis 2013), weighted, as the second-level clusterer.

Lloyd-style alternation that jointly optimizes k centers and t outliers:
each iteration assigns points to nearest centers, marks the farthest mass
(total weight <= t) as outliers, and recomputes centers from the inliers.
The paper adopts exactly this as the coordinator-side algorithm: it returns
exactly k centers + t outliers and works well in practice (no worst-case
guarantee, as they note).

This version is weighted so it can consume summary points: a summary record
(q, w_q) acts as w_q coincident points.  Outlier selection is the natural
weighted generalization — greedily take farthest records while the
cumulative weight stays <= t.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kmeans_pp import kmeanspp_seed
from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.lloyd.ops import accumulate_by_assignment, lloyd_step
from repro.kernels.pdist.ops import min_argmin


class OutlierClustering(NamedTuple):
    centers: jnp.ndarray       # (k, d)
    assignment: jnp.ndarray    # (n,) int32 — nearest-center index
    outlier: jnp.ndarray       # (n,) bool
    cost: jnp.ndarray          # () weighted objective over inliers
    distances: jnp.ndarray     # (n,) distance to assigned center


def _mark_outliers(dist, w_eff, t):
    """Greedy farthest-first: True for records whose cumulative weight
    (in decreasing-distance order) stays within the budget t."""
    order = jnp.argsort(-dist)
    cumw = jnp.cumsum(w_eff[order])
    out_sorted = (cumw <= t) & (w_eff[order] > 0)
    return jnp.zeros_like(out_sorted).at[order].set(out_sorted)


def kmeans_minus_minus(
    points: jnp.ndarray,
    weights: jnp.ndarray,
    valid: jnp.ndarray,
    key: jax.Array,
    *,
    k: int,
    t: float,
    iters: int = 25,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    init_centers: Optional[jnp.ndarray] = None,
    block_n: Optional[int] = None,      # removed alias: raises TypeError
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
) -> OutlierClustering:
    """``init_centers`` (k, d): warm-start the Lloyd loop from these
    centers instead of k-means++ seeding (``key`` is then unused) — the
    incremental-refresh path re-fits from the previous model when little
    of the root changed.  ``None`` (default) seeds as usual and is
    bit-identical to every prior release."""
    policy = resolve_policy(policy, use_pallas=use_pallas, block_n=block_n,
                            caller="kmeans_minus_minus")
    if init_centers is None:
        return _kmeans_minus_minus(points, weights, valid, key, k=k, t=t,
                                   iters=iters, metric=metric, policy=policy)
    init_centers = jnp.asarray(init_centers, jnp.float32)
    if init_centers.shape != (k, points.shape[1]):
        raise ValueError(
            f"init_centers must have shape ({k}, {points.shape[1]}), "
            f"got {tuple(init_centers.shape)}")
    return _kmeans_minus_minus_warm(points, weights, valid, init_centers,
                                    t=t, iters=iters, metric=metric,
                                    policy=policy)


@functools.partial(jax.jit,
                   static_argnames=("k", "iters", "metric", "policy"))
def _kmeans_minus_minus(
    points: jnp.ndarray,
    weights: jnp.ndarray,
    valid: jnp.ndarray,
    key: jax.Array,
    *,
    k: int,
    t: float,
    iters: int,
    metric: str,
    policy: KernelPolicy,
) -> OutlierClustering:
    w = weights.astype(jnp.float32) * valid
    seed_idx, _ = kmeanspp_seed(points, w, key, budget=k, metric=metric)
    centers0 = points[seed_idx]
    return _lloyd_outlier_loop(points, w, valid, centers0, k=k, t=t,
                               iters=iters, metric=metric, policy=policy)


@functools.partial(jax.jit,
                   static_argnames=("iters", "metric", "policy"))
def _kmeans_minus_minus_warm(
    points: jnp.ndarray,
    weights: jnp.ndarray,
    valid: jnp.ndarray,
    centers0: jnp.ndarray,
    *,
    t: float,
    iters: int,
    metric: str,
    policy: KernelPolicy,
) -> OutlierClustering:
    w = weights.astype(jnp.float32) * valid
    return _lloyd_outlier_loop(points, w, valid, centers0,
                               k=centers0.shape[0], t=t, iters=iters,
                               metric=metric, policy=policy)


def _lloyd_outlier_loop(points, w, valid, centers0, *, k, t, iters, metric,
                        policy) -> OutlierClustering:
    """The alternation after seeding — shared by the cold (k-means++
    seeded) and warm (previous-centers) paths; traced inline, so the cold
    path's compiled program is exactly the pre-refactor one."""

    def step(centers, _):
        # One registry-dispatched fused Lloyd step (assign + accumulate);
        # the outlier mask then corrects the accumulators with a one-hot
        # matmul over the inlier weights — no second distance pass.
        _, _, amin, dist = lloyd_step(points, w, centers, metric=metric,
                                      policy=policy)
        dist = jnp.where(valid, dist, -jnp.inf)   # padding: never an outlier
        out = _mark_outliers(dist, w, t)
        w_in = w * ~out
        sums, cnts = accumulate_by_assignment(points, w_in, amin, k)
        new_centers = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1e-9)[:, None], centers)
        return new_centers, None

    centers, _ = jax.lax.scan(step, centers0, None, length=iters)
    dist, amin = min_argmin(points, centers, metric=metric, policy=policy)
    dist = jnp.where(valid, dist, -jnp.inf)
    out = _mark_outliers(dist, w, t)
    cost = jnp.sum(jnp.where(valid & ~out, dist, 0.0) * w)
    return OutlierClustering(
        centers=centers,
        assignment=amin.astype(jnp.int32),
        outlier=out & valid,
        cost=cost,
        distances=jnp.where(valid, dist, jnp.inf),
    )
