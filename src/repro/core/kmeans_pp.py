"""Weighted k-means++ (Arthur & Vassilvitskii 2007) D^p seeding.

Used in three roles:
  * seeding for the second-level k-means-- at the coordinator,
  * the paper's `k-means++` *baseline summary*: run seeding with a budget of
    B = O(k log n + t) centers on the local data, weight each center by the
    number of points nearest to it,
  * seeding inside k-means|| post-processing.

p = 2 for (k,t)-means, p = 1 for (k,t)-median.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.summary import Summary
from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.pdist.ops import min_argmin


def _dist_to(x, c, metric):
    if metric == "l1":
        return jnp.abs(x - c[None, :]).sum(-1)
    if metric == "cosine":
        xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)
        cn = c / jnp.maximum(jnp.linalg.norm(c), 1e-30)
        return jnp.clip(1.0 - xn @ cn, 0.0, 2.0)
    sq = ((x - c[None, :]) ** 2).sum(-1)
    return sq if metric == "l2sq" else jnp.sqrt(sq)


@functools.partial(jax.jit, static_argnames=("budget", "metric"))
def kmeanspp_seed(
    x: jnp.ndarray,
    w: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    metric: str = "l2sq",
):
    """Pick ``budget`` rows of ``x`` by weighted D^p sampling.

    Returns (center indices (budget,) int32, min-dist of every point to the
    chosen set).  Zero-weight rows are never chosen.
    """
    n = x.shape[0]
    w = w.astype(jnp.float32)

    def body(carry, _):
        key, mind, chosen_any = carry
        key, sk = jax.random.split(key)
        score = w * mind
        # first pick: plain weighted sampling (mind starts at +inf -> use w)
        score = jnp.where(jnp.isinf(mind), w, score)
        score = jnp.where(score.sum() > 0, score, w)
        logits = jnp.log(jnp.maximum(score, 1e-30))
        logits = jnp.where(w > 0, logits, -jnp.inf)
        idx = jax.random.categorical(sk, logits).astype(jnp.int32)
        d = _dist_to(x, x[idx], metric)
        mind = jnp.minimum(mind, d)
        return (key, mind, chosen_any | True), idx

    # x-derived init keeps the scan carry's shard_map vma tag consistent.
    mind0 = jnp.full((n,), jnp.inf, jnp.float32) + x[:, 0] * 0
    init = (key, mind0, False)
    (_, mind, _), idx = jax.lax.scan(body, init, None, length=budget)
    return idx, mind


def pp_budget(n: int, k: int, t: int) -> int:
    """The paper's baseline budget O(k log n + t)."""
    return int(k * max(1, math.ceil(math.log(max(n, 2)))) + t)


def kmeanspp_summary(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
) -> Summary:
    """The `k-means++` baseline summary: budgeted seeding + nearest counts."""
    # resolve the process default eagerly: a jitted policy=None would freeze
    # whatever default the first trace saw into the compile cache
    policy = resolve_policy(policy)
    return _kmeanspp_summary(x, key, budget=budget, metric=metric,
                             policy=policy)


@functools.partial(jax.jit, static_argnames=("budget", "metric", "policy"))
def _kmeanspp_summary(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    metric: str,
    policy: KernelPolicy,
) -> Summary:
    n, d = x.shape
    w1 = jnp.ones((n,), jnp.float32)
    idx, _ = kmeanspp_seed(x, w1, key, budget=budget, metric=metric)
    centers = x[idx]
    _, amin = min_argmin(x, centers, metric=metric, policy=policy)
    counts = jnp.zeros((budget,), jnp.float32).at[amin].add(1.0)
    sigma = idx[amin]
    return Summary(
        indices=idx,
        points=centers,
        weights=counts,
        is_candidate=jnp.zeros((budget,), bool),
        valid=jnp.ones((budget,), bool),
        sigma=sigma,
        n_rounds=jnp.int32(budget),
        n_remaining=jnp.int32(0),
    )
