"""`rand` baseline summary: uniform sample + nearest-neighbour weights.

Each site samples `budget` points uniformly, assigns every local point to
its nearest sample, and weights samples by assignment counts.  One round of
communication, same record format as the paper's summary — but no outlier
candidates, which is why it fails at outlier detection (paper Tables 2-4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.summary import Summary
from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.pdist.ops import min_argmin


def rand_summary(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
) -> Summary:
    # resolve the process default eagerly: a jitted policy=None would freeze
    # whatever default the first trace saw into the compile cache
    policy = resolve_policy(policy)
    return _rand_summary(x, key, budget=budget, metric=metric, policy=policy)


@functools.partial(jax.jit, static_argnames=("budget", "metric", "policy"))
def _rand_summary(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    metric: str,
    policy: KernelPolicy,
) -> Summary:
    n, d = x.shape
    idx = jax.random.choice(key, n, (budget,), replace=False).astype(jnp.int32)
    centers = x[idx]
    _, amin = min_argmin(x, centers, metric=metric, policy=policy)
    counts = jnp.zeros((budget,), jnp.float32).at[amin].add(1.0)
    return Summary(
        indices=idx,
        points=centers,
        weights=counts,
        is_candidate=jnp.zeros((budget,), bool),
        valid=jnp.ones((budget,), bool),
        sigma=idx[amin],
        n_rounds=jnp.int32(1),
        n_remaining=jnp.int32(0),
    )
