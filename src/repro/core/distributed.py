"""Algorithm 3 (Distributed-Median/Means) in the coordinator model.

Two execution paths, same algorithm:

* ``distributed_cluster`` — the production path: one ``shard_map`` program
  over a mesh axis ``sites``.  Each site (device/DP shard) builds its local
  summary with Summary-Outliers(A_i, k, 2t/s) (Algorithm 1/2), the summaries
  are exchanged with a single ``all_gather`` (THE one round of communication
  the paper allows), and the second-level weighted k-means-- runs replicated
  on the union.  On hardware the all_gather is an ICI collective; its bytes
  are exactly the paper's communication cost.

* ``simulate_coordinator`` — host-driven loop over sites used by the
  wall-clock benchmarks (single CPU device): same summaries, same second
  level, explicit communication accounting in records.

Partition modes: ``random`` uses the paper's local budget t_i = 2t/s
(Chernoff: all sites respect it w.h.p.); ``adversarial`` uses t_i = t.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import obs
from repro.core.augmented import augmented_summary_outliers
from repro.core.collective import gather_sites, replicated_coordinator
from repro.core.kmeans_mm import kmeans_minus_minus
from repro.core.summary import summary_outliers, summary_outliers_compact
from repro.kernels.dispatch import KernelPolicy
from repro.summarize.base import (SummarizerPolicy, select_summarizer,
                                  summarizer_policy)


class DistClusterResult(NamedTuple):
    centers: jnp.ndarray        # (k, d)
    outlier_ids: jnp.ndarray    # (cap_out,) int32 global ids, -1 padded
    summary_ids: jnp.ndarray    # (s*cap,) int32 global ids of summary records, -1 padded
    summary_weights: jnp.ndarray
    comm_records: jnp.ndarray   # () float — records gathered to coordinator
    cost: jnp.ndarray           # () second-level objective (on summary)


def local_budget(t: int, s: int, partition: str) -> int:
    if partition == "adversarial":
        return t
    return max(1, int(math.ceil(2 * t / s)))


def _site_summarizer(summarizer: SummarizerPolicy | None, summary_alg: str,
                     *, metric: str, k: int, t: int):
    """Resolve the per-site summary algorithm to a fixed-shape callable.

    ``summarizer=None`` maps the legacy ``summary_alg`` string onto the
    registry's ``paper`` entry with the variant pinned, so the default
    reproduces the pre-registry Algorithm 1/2 calls bit for bit.
    """
    if summarizer is None:
        if summary_alg not in ("augmented", "plain"):
            raise ValueError(f"unknown summary_alg {summary_alg!r}")
        summarizer = summarizer_policy("paper", variant=summary_alg)
    spec = select_summarizer(summarizer, metric=metric, k=k, t=t)
    if spec.site_summary is None:
        raise ValueError(
            f"summarizer {spec.name!r} has no fixed-shape site path and "
            f"cannot run inside shard_map; use simulate_coordinator "
            f"(host-driven) for it")
    params = summarizer.params_dict()

    def summarize_site(x, key, *, policy):
        return spec.site_summary(x, key, k=k, t=t, alpha=2.0, beta=0.45,
                                 metric=metric, kernel_policy=policy,
                                 **params)

    return summarize_site


def _second_level(points, weights, valid, gids, key, *, k, t, iters, metric, policy):
    sol = kmeans_minus_minus(points, weights, valid, key, k=k, t=float(t),
                             iters=iters, metric=metric, policy=policy)
    out_ids = jnp.where(sol.outlier, gids, -1)
    order = jnp.argsort(~sol.outlier)  # flagged first
    return sol, out_ids[order], order


def distributed_cluster(
    x_parts: jnp.ndarray,
    key: jax.Array,
    mesh: Mesh,
    *,
    k: int,
    t: int,
    axis: str = "sites",
    partition: str = "random",
    summary_alg: str = "augmented",
    summarizer: SummarizerPolicy | None = None,
    second_iters: int = 25,
    metric: str = "l2sq",
    policy: KernelPolicy | None = None,
) -> DistClusterResult:
    """x_parts: (s, n_per, d), sharded over ``axis`` on the leading dim.

    ``summarizer`` selects each site's summary algorithm from the
    ``repro.summarize`` registry (it must provide a fixed-shape site path);
    None maps the legacy ``summary_alg`` string to the registry's ``paper``
    entry, reproducing the pre-registry results bit for bit.
    """
    s, n_per, d = x_parts.shape
    t_i = local_budget(t, s, partition)
    summarize = _site_summarizer(summarizer, summary_alg,
                                 metric=metric, k=k, t=t_i)

    def per_site(xp, key):
        x_local = xp[0]  # (n_per, d) — this site's block
        site = jax.lax.axis_index(axis)
        skey = jax.random.fold_in(key, site)
        summ = summarize(x_local, skey, policy=policy)
        gids = jnp.where(summ.valid, summ.indices + site * n_per, -1)
        # --- the one round of communication ---
        pts, wts, val, gid = gather_sites(
            (summ.points, summ.weights, summ.valid, gids), axis)
        # --- replicated second level at the "coordinator" ---
        sol, out_ids_sorted, _ = _second_level(
            pts, wts, val, gid, jax.random.fold_in(key, 2**31 - 1),
            k=k, t=t, iters=second_iters, metric=metric, policy=policy)
        comm = val.sum().astype(jnp.float32)
        return (sol.centers, out_ids_sorted, gid, wts, comm, sol.cost)

    fn = replicated_coordinator(per_site, mesh, axis=axis, n_sharded=1)
    centers, out_ids, gids, wts, comm, cost = fn(x_parts, key)
    # comm accounting happens post-hoc on the host (the gather itself runs
    # inside the shard_map program): valid records per site from the id
    # blocks, padded bytes from the per-site slice of the gathered payload
    reg_obs = obs.get_default_registry()
    if reg_obs.enabled:
        gids_h = np.asarray(gids).reshape(s, -1)
        cap = gids_h.shape[1]
        per_rec = [int((gids_h[i] >= 0).sum()) for i in range(s)]
        site_bytes = cap * (4 * d + 4 + 1 + 4)   # pts + w + valid + gid
        obs.record_comm(per_rec, [site_bytes] * s, path="shard_map")
    return DistClusterResult(
        centers=centers,
        outlier_ids=out_ids,
        summary_ids=gids,
        summary_weights=wts,
        comm_records=comm,
        cost=cost,
    )


def simulate_coordinator(
    parts: Sequence[np.ndarray],
    key: jax.Array,
    *,
    k: int,
    t: int,
    partition: str = "random",
    summary_alg: str = "augmented",
    summarizer: SummarizerPolicy | None = None,
    second_iters: int = 25,
    metric: str = "l2sq",
    policy: KernelPolicy | None = None,
    compact: bool = True,
):
    """Host-side Algorithm 3 over a list of per-site arrays.

    Returns (result: DistClusterResult-like dict, per-site summaries).
    Global ids are offsets into the concatenation of ``parts``.

    ``summarizer`` runs any registered ``repro.summarize`` algorithm per
    site through its weighted entry point (unit weights) — including the
    host-driven ones (``ball_cover``, ``coreset``) that cannot run inside
    ``distributed_cluster``'s shard_map program.  None keeps the legacy
    ``summary_alg``/``compact`` selection, bit for bit.
    """
    s = len(parts)
    t_i = local_budget(t, s, partition)
    offs = np.cumsum([0] + [p.shape[0] for p in parts])

    all_pts, all_w, all_gid, all_cand = [], [], [], []
    for i, part in enumerate(parts):
        skey = jax.random.fold_in(key, i)
        with obs.trace("oneshot.site_summary", site=i):
            if summarizer is not None:
                from repro.summarize.base import summarize as _summarize_w

                ws = _summarize_w(part, np.ones((part.shape[0],), np.float32),
                                  skey, k=k, t=t_i, metric=metric,
                                  policy=summarizer, kernel_policy=policy)
                all_pts.append(np.asarray(ws.points))
                all_w.append(np.asarray(ws.weights))
                all_gid.append(np.asarray(ws.indices) + offs[i])
                all_cand.append(np.asarray(ws.is_candidate))
                continue
            if summary_alg == "augmented":
                summ = augmented_summary_outliers(jnp.asarray(part), skey,
                                                  k=k, t=t_i, metric=metric,
                                                  policy=policy)
            elif compact:
                summ = summary_outliers_compact(part, skey, k=k, t=t_i,
                                                metric=metric, policy=policy)
            else:
                summ = summary_outliers(jnp.asarray(part), skey, k=k, t=t_i,
                                        metric=metric, policy=policy)
            valid = np.asarray(summ.valid)
            all_pts.append(np.asarray(summ.points)[valid])
            all_w.append(np.asarray(summ.weights)[valid])
            all_gid.append(np.asarray(summ.indices)[valid] + offs[i])
            all_cand.append(np.asarray(summ.is_candidate)[valid])

    # each site "sends" exactly its live summary records to the coordinator
    obs.record_comm(
        [p.shape[0] for p in all_pts],
        [p.nbytes + w.nbytes + g.nbytes + c.nbytes
         for p, w, g, c in zip(all_pts, all_w, all_gid, all_cand)],
        path="host-sim")
    pts = jnp.asarray(np.concatenate(all_pts), jnp.float32)
    wts = jnp.asarray(np.concatenate(all_w), jnp.float32)
    gid = np.concatenate(all_gid)
    n_rec = pts.shape[0]
    with obs.trace("oneshot.second_level"):
        sol = kmeans_minus_minus(pts, wts, jnp.ones((n_rec,), bool),
                                 jax.random.fold_in(key, 2**31 - 1),
                                 k=k, t=float(t),
                                 iters=second_iters, metric=metric,
                                 policy=policy)
    out_mask = np.asarray(sol.outlier)
    return {
        "centers": np.asarray(sol.centers),
        "outlier_ids": gid[out_mask],
        "summary_ids": gid,
        "summary_weights": np.concatenate(all_w),
        "summary_candidates": np.concatenate(all_cand),
        "comm_records": float(n_rec),
        "cost": float(sol.cost),
    }
