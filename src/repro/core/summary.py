"""Algorithm 1 (Summary-Outliers) from Chen, Sadeqi Azer & Zhang (2018).

Two implementations of the same algorithm:

* ``summary_outliers``        — single ``jax.jit`` with ``lax.while_loop`` over a
  fixed-capacity masked state.  Shapes are static, so this version composes
  with ``shard_map`` (Algorithm 3 runs it per site inside one program) and
  lowers for the TPU dry-run.  Cost: O(R·n·m) distance work because the
  masked array never shrinks (R = #rounds).
* ``summary_outliers_compact`` — host-driven loop that physically compacts
  X_i between rounds, recovering the paper's O(n·m) total work
  (Σ|X_i| ≤ n/β).  Used by the wall-clock benchmarks; not shard_map-able.

Both implement the same sampling process (they draw with different PRNG
mechanics, so summaries agree statistically, not bit-for-bit); both are
tested against the same invariants and loss bounds.

Notation maps 1:1 to the paper: kappa = max{k, log n}; each round samples
``m = alpha*kappa`` points S_i from the remainder X_i, grows balls of the
smallest radius rho_i capturing a beta fraction, assigns captured points to
their nearest sample (sigma), and recurses.  Stops when |X_i| <= 8t; the
survivors X_r are the outlier *candidates* (weight 1), the samples are the
summary centers (weight = |sigma^{-1}|).

The paper's experiments state "alpha=2, beta=4.5"; Algorithm 1 requires
0.25 <= beta < 0.5, so we read beta=0.45 (typo) and default to that.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.pdist.ops import min_argmin


class Summary(NamedTuple):
    """Fixed-capacity weighted summary Q of a dataset X.

    indices      (cap,) int32  — index into the original X; == n for padding
    points       (cap, d) f32  — the summary points (zeros for padding)
    weights      (cap,) f32    — |sigma^{-1}(x)|; 0 for padding
    is_candidate (cap,) bool   — True for X_r members (outlier candidates)
    valid        (cap,) bool   — real entry vs padding
    sigma        (n,) int32    — the paper's mapping sigma: X -> X
    n_rounds     () int32      — r
    n_remaining  () int32      — |X_r|
    """

    indices: jnp.ndarray
    points: jnp.ndarray
    weights: jnp.ndarray
    is_candidate: jnp.ndarray
    valid: jnp.ndarray
    sigma: jnp.ndarray
    n_rounds: jnp.ndarray
    n_remaining: jnp.ndarray

    @property
    def size(self):
        return self.valid.sum()


def _plan(n: int, k: int, t: int, alpha: float, beta: float):
    """Static (python) round/capacity plan. Deterministic upper bounds:
    each round removes >= ceil(beta*|X_i|) points, so
    |X_i| <= n*(1-beta)^i and R = ceil(log(n/max(8t,1)) / -log(1-beta))."""
    kappa = max(k, max(1, math.ceil(math.log(max(n, 2)))))
    m = max(1, int(math.ceil(alpha * kappa)))
    stop = max(8 * t, 1)
    if n <= stop:
        rounds = 0
    else:
        rounds = max(1, int(math.ceil(math.log(n / stop) / -math.log1p(-beta))))
    cap = min(n, rounds * m + 8 * t + 1)
    return kappa, m, rounds, cap


def summary_outliers(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    block_n: Optional[int] = None,      # removed alias: raises TypeError
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
) -> Summary:
    """Fixed-shape Summary-Outliers (Algorithm 1). jit/shard_map friendly."""
    policy = resolve_policy(policy, use_pallas=use_pallas, block_n=block_n,
                            caller="summary_outliers")
    return _summary_outliers(x, key, k=k, t=t, alpha=alpha, beta=beta,
                             metric=metric, policy=policy)


@functools.partial(
    jax.jit,
    static_argnames=("k", "t", "alpha", "beta", "metric", "policy"),
)
def _summary_outliers(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float,
    beta: float,
    metric: str,
    policy: KernelPolicy,
) -> Summary:
    n, d = x.shape
    _, m, rounds, cap = _plan(n, k, t, alpha, beta)
    stop = 8 * t

    def cond(state):
        i, _, active, _, _ = state
        return (active.sum() > stop) & (i < rounds)

    def body(state):
        i, key, active, sigma, center_mask = state
        key, sk = jax.random.split(key)
        # Line 6: sample m points (with replacement) uniformly from X_i.
        logits = jnp.where(active, 0.0, -jnp.inf)
        idx = jax.random.categorical(sk, logits, shape=(m,))
        s = x[idx]
        # Line 7: nearest-sample distance for every remaining point.
        mind, amin = min_argmin(x, s, metric=metric, policy=policy)
        masked = jnp.where(active, mind, jnp.inf)
        # Line 8: smallest rho with |B(S_i, X_i, rho)| >= beta*|X_i|.
        cnt = active.sum()
        kth = jnp.clip(jnp.ceil(beta * cnt).astype(jnp.int32), 1, cnt)
        rho = jnp.sort(masked)[kth - 1]
        captured = active & (mind <= rho)
        # Line 9: sigma(x) <- nearest sample, as a global index.
        sigma = jnp.where(captured, idx[amin], sigma)
        center_mask = center_mask.at[idx].set(True)
        return i + 1, key, active & ~captured, sigma, center_mask

    # Derive carry inits from x so they carry the same varying-manual-axes
    # (vma) tag as x — required for running inside shard_map (Algorithm 3).
    vzero = (x[:, 0] * 0).astype(jnp.int32)
    init = (
        jnp.int32(0),
        key,
        vzero == 0,
        jnp.arange(n, dtype=jnp.int32) + vzero,
        vzero != 0,
    )
    if rounds == 0:
        i, _, active, sigma, center_mask = init
    else:
        i, _, active, sigma, center_mask = jax.lax.while_loop(cond, body, init)

    # Line 13: survivors map to themselves (already arange-initialized, but a
    # captured-then-resampled point cannot exist; make the invariant explicit).
    sigma = jnp.where(active, jnp.arange(n, dtype=jnp.int32), sigma)
    # Line 14: weights w_x = |sigma^{-1}(x)|.
    w = jnp.zeros((n,), jnp.float32).at[sigma].add(1.0)

    sel = center_mask | active
    idx_q = jnp.nonzero(sel, size=cap, fill_value=n)[0].astype(jnp.int32)
    xp = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    wp = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])
    cand = jnp.concatenate([active, jnp.zeros((1,), bool)])
    return Summary(
        indices=idx_q,
        points=xp[idx_q],
        weights=wp[idx_q],
        is_candidate=cand[idx_q],
        valid=idx_q < n,
        sigma=sigma,
        n_rounds=i,
        n_remaining=active.sum(),
    )


def summary_outliers_compact(
    x,
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
) -> Summary:
    """Host-driven Summary-Outliers that compacts X_i between rounds.

    Work matches the paper's O(max{k, log n} * n): the i-th round touches
    |X_i| <= n(1-beta)^i points. The distance inner loop stays jitted
    (min_argmin); set logic runs in numpy on the host.
    """
    x = np.asarray(x, np.float32)
    n, d = x.shape
    _, m, _, _ = _plan(n, k, t, alpha, beta)
    stop = max(8 * t, 1)

    remaining = np.arange(n, dtype=np.int64)          # global ids of X_i
    sigma = np.arange(n, dtype=np.int64)
    center_ids: list[np.ndarray] = []
    rounds = 0
    while remaining.size > stop:
        key, sk = jax.random.split(key)
        pick = np.asarray(jax.random.randint(sk, (m,), 0, remaining.size))
        idx = remaining[pick]                          # global sample ids
        xi = x[remaining]
        mind, amin = (np.asarray(a) for a in
                      min_argmin(xi, x[idx], metric=metric, policy=policy))
        kth = int(np.clip(np.ceil(beta * remaining.size), 1, remaining.size))
        rho = np.partition(mind, kth - 1)[kth - 1]
        captured = mind <= rho
        sigma[remaining[captured]] = idx[amin[captured]]
        center_ids.append(idx)
        remaining = remaining[~captured]
        rounds += 1

    sigma[remaining] = remaining
    w = np.zeros((n,), np.float32)
    np.add.at(w, sigma, 1.0)

    centers = np.unique(np.concatenate(center_ids)) if center_ids else np.empty(0, np.int64)
    is_cand = np.zeros((n,), bool)
    is_cand[remaining] = True
    sel = np.union1d(centers, remaining).astype(np.int64)
    return Summary(
        indices=jnp.asarray(sel, jnp.int32),
        points=jnp.asarray(x[sel]),
        weights=jnp.asarray(w[sel]),
        is_candidate=jnp.asarray(is_cand[sel]),
        valid=jnp.ones((sel.size,), bool),
        sigma=jnp.asarray(sigma, jnp.int32),
        n_rounds=jnp.int32(rounds),
        n_remaining=jnp.int32(remaining.size),
    )


def information_loss(x: jnp.ndarray, sigma: jnp.ndarray, metric: str = "l2sq"):
    """loss(Q) = phi_X(sigma) = sum_x d(x, sigma(x))  (Definition 2)."""
    delta = x - x[sigma]
    if metric == "l1":
        return jnp.abs(delta).sum()
    sq = (delta * delta).sum(-1)
    return sq.sum() if metric == "l2sq" else jnp.sqrt(sq).sum()
