"""k-means|| (Bahmani et al. 2012) baseline, budget-extended for outliers.

The paper compares against k-means|| with the center budget raised from k to
O(k log n + t).  k-means|| is a *multi-round* algorithm: each of R rounds
samples ~ell candidates with probability proportional to the current D^p
cost, and in the distributed setting every round requires the coordinator to
gather the new candidates from all sites and broadcast the union back —
this is exactly why its communication grows with both R and s (paper Fig 1a).

We implement the practical fixed-count variant (sample exactly ell per round
via D^p-categorical draws) and track the communication a coordinator-model
deployment would incur:

    comm_records = sum over rounds [ gathered candidates  +  s * |union| ]

(the s*|union| term is the broadcast each site receives next round).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.summary import Summary
from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.pdist.ops import min_argmin


class KmeansParallelResult(NamedTuple):
    summary: Summary
    comm_records: jnp.ndarray  # () float — coordinator-model communication
    rounds: int


def kmeans_parallel_summary(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    rounds: int = 5,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    sites: int = 1,
) -> KmeansParallelResult:
    # resolve the process default eagerly: a jitted policy=None would freeze
    # whatever default the first trace saw into the compile cache
    policy = resolve_policy(policy)
    return _kmeans_parallel_summary(x, key, budget=budget, rounds=rounds,
                                    metric=metric, policy=policy, sites=sites)


@functools.partial(jax.jit, static_argnames=("budget", "rounds", "metric", "policy", "sites"))
def _kmeans_parallel_summary(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    budget: int,
    rounds: int,
    metric: str,
    policy: KernelPolicy,
    sites: int,
) -> KmeansParallelResult:
    n, d = x.shape
    ell = max(1, budget // rounds)

    def round_body(carry, _):
        key, mind = carry
        key, sk = jax.random.split(key)
        score = jnp.where(jnp.isinf(mind), 1.0, mind)
        score = jnp.where(score.sum() > 0, score, jnp.ones_like(score))
        logits = jnp.log(jnp.maximum(score, 1e-30))
        idx = jax.random.categorical(sk, logits, shape=(ell,)).astype(jnp.int32)
        dists, _ = min_argmin(x, x[idx], metric=metric, policy=policy)
        mind = jnp.minimum(mind, dists)
        return (key, mind), idx

    init = (key, jnp.full((n,), jnp.inf, jnp.float32) + x[:, 0] * 0)
    (_, _), idx_rounds = jax.lax.scan(round_body, init, None, length=rounds)
    idx = idx_rounds.reshape(-1)  # (rounds*ell,)

    centers = x[idx]
    _, amin = min_argmin(x, centers, metric=metric, policy=policy)
    counts = jnp.zeros((idx.shape[0],), jnp.float32).at[amin].add(1.0)
    summary = Summary(
        indices=idx,
        points=centers,
        weights=counts,
        is_candidate=jnp.zeros_like(idx, dtype=bool),
        valid=jnp.ones_like(idx, dtype=bool),
        sigma=idx[amin],
        n_rounds=jnp.int32(rounds),
        n_remaining=jnp.int32(0),
    )
    # Round i gathers ell candidates and broadcasts the running union
    # (i+1)*ell to each of the `sites` sites for the next round's D^p scoring.
    per_round = jnp.arange(1, rounds + 1) * ell
    comm = jnp.float32(rounds * ell) + jnp.float32(sites) * per_round.sum().astype(jnp.float32)
    return KmeansParallelResult(summary=summary, comm_records=comm, rounds=rounds)
