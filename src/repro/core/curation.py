"""Data curation for LM training — the paper's technique as a first-class
framework feature (DESIGN §3.1).

Each data-parallel shard is a "site".  Sequence embeddings (mean-pooled
final hidden states, stop-grad) accumulate into a per-site reservoir; every
`detect_every` observations the site builds a Summary-Outliers summary of
its reservoir (Algorithm 1 with t' = 2t/s), summaries are gathered, and the
replicated second-level k-means-- labels the global outlier sequences.
Flagged sequence ids feed back into the sampler as weights (drop or
down-weight).  One round of communication per detection — Algorithm 3
verbatim, with sites = DP shards.

The host-side API (observe/detect) is deliberately synchronous-free: it
runs off the training step on the host using the embeddings the step
already computed, so it adds zero device-step latency.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.distributed import simulate_coordinator


@dataclass
class CuratorConfig:
    k: int = 16                 # embedding clusters
    outlier_frac: float = 0.01  # t = frac * observed
    reservoir: int = 4096       # per-site reservoir capacity
    min_points: int = 256       # don't cluster before this many
    seed: int = 0


@dataclass
class DataCurator:
    n_sites: int
    cfg: CuratorConfig = field(default_factory=CuratorConfig)
    _buf: list = field(default_factory=list)      # per-site lists
    _ids: list = field(default_factory=list)
    _seen: int = 0

    def __post_init__(self):
        self._buf = [[] for _ in range(self.n_sites)]
        self._ids = [[] for _ in range(self.n_sites)]
        self._rng = np.random.default_rng(self.cfg.seed)

    def observe(self, site: int, embeddings: np.ndarray, seq_ids: np.ndarray):
        """Reservoir-sample sequence embeddings for one site."""
        emb = np.asarray(embeddings, np.float32)
        ids = np.asarray(seq_ids)
        buf, bids = self._buf[site], self._ids[site]
        for e, i in zip(emb, ids):
            self._seen += 1
            if len(buf) < self.cfg.reservoir:
                buf.append(e), bids.append(i)
            else:
                j = self._rng.integers(0, self._seen)
                if j < self.cfg.reservoir:
                    buf[j], bids[j] = e, i

    @property
    def n_points(self) -> int:
        return sum(len(b) for b in self._buf)

    def detect(self):
        """Run Algorithm 3 over the reservoirs.
        Returns (outlier_seq_ids, comm_records) or (None, 0) if too few."""
        n = self.n_points
        if n < self.cfg.min_points:
            return None, 0.0
        t = max(1, int(self.cfg.outlier_frac * n))
        parts = [np.stack(b) for b in self._buf if b]
        id_parts = [np.asarray(i) for i in self._ids if len(i)]
        res = simulate_coordinator(
            parts, jax.random.key(self.cfg.seed), k=self.cfg.k, t=t,
            summary_alg="augmented")
        conc = np.concatenate(id_parts)
        flagged = conc[res["outlier_ids"]]
        return flagged, res["comm_records"]

    def sample_weights(self, seq_ids: np.ndarray, flagged) -> np.ndarray:
        """1.0 for clean sequences, 0.0 for flagged ones."""
        if flagged is None:
            return np.ones(len(seq_ids), np.float32)
        bad = np.isin(np.asarray(seq_ids), flagged)
        return (~bad).astype(np.float32)
