"""Core: the paper's contribution — summary construction + distributed
(k,t)-means/median with outliers."""
from repro.core.summary import (  # noqa: F401
    Summary, summary_outliers, summary_outliers_compact, information_loss,
)
from repro.core.augmented import augmented_summary_outliers  # noqa: F401
from repro.core.kmeans_mm import OutlierClustering, kmeans_minus_minus  # noqa: F401
from repro.core.kmeans_pp import kmeanspp_seed, kmeanspp_summary, pp_budget  # noqa: F401
from repro.core.kmeans_parallel import kmeans_parallel_summary  # noqa: F401
from repro.core.rand_summary import rand_summary  # noqa: F401
from repro.core.distributed import (  # noqa: F401
    DistClusterResult, distributed_cluster, simulate_coordinator, local_budget,
)
from repro.core.collective import (  # noqa: F401
    gather_sites, gathered_bytes, payload_bytes, replicated_coordinator,
    sites_mesh,
)
from repro.core.metrics import clustering_losses, outlier_scores  # noqa: F401
