"""Shared mesh / shard_map plumbing for the coordinator model.

Every "one round of communication" program in this repo has the same shape:
a per-site function runs under ``shard_map`` over a 1-D ``sites`` axis, does
local work, exchanges fixed-shape payloads with a single ``all_gather``, and
finishes with a replicated coordinator step whose result is identical on
every site.  The one-shot path (``repro.core.distributed``) and the sharded
streaming path (``repro.stream.sharded``) both follow it; this module holds
the plumbing they would otherwise duplicate:

* ``shard_map``          — version-compat wrapper (``jax.shard_map`` moved
                           out of ``jax.experimental`` only in newer jax);
* ``sites_mesh``         — the canonical 1-D mesh over ``sites``;
* ``gather_sites``       — all_gather a pytree over the axis and collapse
                           the site dim, i.e. "send every site's summary to
                           the coordinator" as one collective;
* ``replicated_coordinator`` — wraps the per-site fn so callers stop hand
                           rolling the ``[None]`` / take-``[0]`` dance for
                           replicated outputs;
* ``payload_bytes`` / ``gathered_bytes`` — communication accounting: the
  bytes one site contributes to an all_gather, and the total a refresh puts
  on the wire.  The paper measures communication in summary records; these
  give the byte-level view the benchmarks report alongside it.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def shard_map(fn, mesh: Mesh, *, in_specs, out_specs):
    """``jax.shard_map`` where available, ``jax.experimental.shard_map``
    otherwise (the public alias only exists in newer jax releases)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        # the experimental version predates replication rules for while-loops
        # (which every k-means inner loop is) — disable the check there.
        from jax.experimental.shard_map import shard_map as esm
        return esm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def sites_mesh(n_sites: int | None = None, *, axis: str = "sites") -> Mesh:
    """1-D mesh over ``axis``: one site per device (default: all devices)."""
    n = n_sites or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def gather_sites(tree, axis: str = "sites"):
    """Inside shard_map: all_gather every leaf over ``axis`` and collapse the
    gathered site dim, so a per-site ``(cap, ...)`` leaf becomes the
    coordinator's ``(s * cap, ...)`` union.  THE one round of communication:
    on hardware this lowers to one ICI collective per leaf."""

    def g(a):
        ga = jax.lax.all_gather(a, axis)          # (s, cap, ...)
        return ga.reshape((-1,) + ga.shape[2:])   # (s * cap, ...)

    return jax.tree_util.tree_map(g, tree)


def replicated_coordinator(per_site, mesh: Mesh, *, axis: str = "sites",
                           n_sharded: int = 1):
    """shard_map ``per_site`` over ``axis`` and unstack its replicated result.

    The first ``n_sharded`` arguments are sharded on their leading dim (each
    site sees its block with the leading site dim kept, length 1); remaining
    arguments are replicated.  ``per_site`` must return a pytree of arrays
    that is *identical on every site* (the coordinator result after a
    ``gather_sites``); the wrapper stacks them over sites and returns site
    0's copy, so callers get the coordinator view directly.

    The returned callable is jit-wrapped around one stable closure per
    argument count, so repeated invocations (e.g. every streaming refresh)
    reuse the compiled program instead of re-tracing — hold on to it.
    """

    def wrapped(*args):
        out = per_site(*args)
        return jax.tree_util.tree_map(lambda a: a[None], out)

    programs: dict[int, object] = {}   # arg count -> jitted shard_map program

    def call(*args):
        if len(args) < n_sharded:
            raise ValueError(f"{len(args)} args but n_sharded={n_sharded}")
        fn = programs.get(len(args))
        if fn is None:
            in_specs = tuple(P(axis) if i < n_sharded else P()
                             for i in range(len(args)))
            fn = jax.jit(shard_map(wrapped, mesh,
                                   in_specs=in_specs, out_specs=P(axis)))
            programs[len(args)] = fn
        out = fn(*args)
        return jax.tree_util.tree_map(lambda a: a[0], out)

    return call


def payload_bytes(tree) -> int:
    """Bytes one site contributes to an all_gather of ``tree`` (its padded
    per-site payload — what actually crosses the interconnect, as opposed to
    the paper's valid-record count)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        dt = np.dtype(leaf.dtype)
        total += int(math.prod(leaf.shape)) * dt.itemsize
    return total


def gathered_bytes(tree, n_sites: int) -> int:
    """Total bytes one all_gather of per-site ``tree`` moves: every one of
    the ``n_sites`` participants contributes its payload once."""
    return payload_bytes(tree) * n_sites
