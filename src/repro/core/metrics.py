"""Evaluation metrics from the paper's Section 5.1.2.

Clustering quality:  l1-loss (k,t)-median and l2-loss (k,t)-means over the
ORIGINAL dataset X given returned centers C and outliers O.

Outlier detection, against ground truth O*:
  preRec = |S  cap O*| / |O*|   (S = summary fed to the 2nd level)
  recall = |O  cap O*| / |O*|
  prec   = |O  cap O*| / |O|
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.kernels.dispatch import KernelPolicy
from repro.kernels.pdist.ops import min_argmin


class OutlierScores(NamedTuple):
    pre_recall: float
    precision: float
    recall: float


def clustering_losses(x, centers, outlier_mask_x, *,
                      policy: Optional[KernelPolicy] = None):
    """(l1, l2) losses of centers over X \\ O.  outlier_mask_x is (n,) bool."""
    d1, _ = min_argmin(x, centers, metric="l2", policy=policy)
    keep = ~outlier_mask_x
    l1 = jnp.where(keep, d1, 0.0).sum()
    l2 = jnp.where(keep, d1 * d1, 0.0).sum()
    return l1, l2


def outlier_scores(true_idx, summary_idx, reported_idx) -> OutlierScores:
    """All args are integer index arrays into X (device or numpy)."""
    import numpy as np

    true_set = set(np.asarray(true_idx).tolist())
    s_set = set(np.asarray(summary_idx).tolist())
    o_set = set(np.asarray(reported_idx).tolist())
    pre = len(s_set & true_set) / max(len(true_set), 1)
    rec = len(o_set & true_set) / max(len(true_set), 1)
    prc = len(o_set & true_set) / max(len(o_set), 1)
    return OutlierScores(pre_recall=pre, precision=prc, recall=rec)
