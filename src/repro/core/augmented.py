"""Algorithm 2 (Augmented-Summary-Outliers).

When t >> k the plain summary is outlier-heavy: |X_r| ~ 8t candidates but
only O(k log n) centers.  The augmentation samples |X_r| - |S| extra centers
S' from X \\ (X_r u S) and reassigns every non-candidate point to its nearest
center in S u S', which can only lower the information loss
(phi_X(pi) <= phi_X(sigma)).  Cost grows to O(t*n) for the reassignment —
still one pass of fused min-dist+argmin, i.e. one pdist kernel call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.summary import Summary, _plan, _summary_outliers
from repro.kernels.dispatch import KernelPolicy, resolve_policy
from repro.kernels.pdist.ops import min_argmin

_FAR = 1e30  # sentinel coordinate for invalid center slots


def augmented_summary_compact(
    x,
    key,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
) -> "Summary":
    """Host-driven Algorithm 2 with the paper's O(t*n) cost: compact
    Algorithm 1 (O(max{k,log n}*n)), then one fused min-dist+argmin pass for
    the reassignment. Used by the wall-clock benchmarks."""
    import numpy as np
    from repro.core.summary import summary_outliers_compact

    x = np.asarray(x, np.float32)
    n, d = x.shape
    key, k1, k2 = jax.random.split(jax.random.fold_in(key, 17), 3)
    base = summary_outliers_compact(x, k1, k=k, t=t, alpha=alpha, beta=beta,
                                    metric=metric, policy=policy)
    sel = np.asarray(base.indices)
    cand = np.asarray(base.is_candidate)
    cand_ids = sel[cand]
    center_ids = sel[~cand]
    extra = max(int(cand_ids.size) - int(center_ids.size), 0)
    if extra:
        eligible = np.setdiff1d(np.arange(n), sel)
        if eligible.size == 0:
            eligible = np.arange(n)
        pick = np.asarray(jax.random.randint(k2, (extra,), 0, eligible.size))
        center_ids = np.concatenate([center_ids, eligible[pick]])
    # Line 3: reassign everything outside X_r to nearest center in S u S'
    _, amin = min_argmin(jnp.asarray(x), jnp.asarray(x[center_ids]),
                         metric=metric, policy=policy)
    pi = center_ids[np.asarray(amin)]
    pi[cand_ids] = cand_ids
    w = np.zeros(n, np.float32)
    np.add.at(w, pi, 1.0)
    all_ids = np.concatenate([np.unique(center_ids), cand_ids])
    is_cand = np.concatenate([np.zeros(np.unique(center_ids).size, bool),
                              np.ones(cand_ids.size, bool)])
    return Summary(
        indices=jnp.asarray(all_ids, jnp.int32),
        points=jnp.asarray(x[all_ids]),
        weights=jnp.asarray(w[all_ids]),
        is_candidate=jnp.asarray(is_cand),
        valid=jnp.ones(all_ids.size, bool),
        sigma=jnp.asarray(pi, jnp.int32),
        n_rounds=base.n_rounds,
        n_remaining=base.n_remaining,
    )


def augmented_summary_outliers(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float = 2.0,
    beta: float = 0.45,
    metric: str = "l2sq",
    policy: Optional[KernelPolicy] = None,
    block_n: Optional[int] = None,      # removed alias: raises TypeError
    use_pallas: Optional[bool] = None,  # removed alias: raises TypeError
) -> Summary:
    policy = resolve_policy(policy, use_pallas=use_pallas, block_n=block_n,
                            caller="augmented_summary_outliers")
    if metric == "cosine":
        # the fixed-shape reassignment marks invalid center slots with a
        # far-away coordinate sentinel; under a direction-only metric that
        # sentinel is an ordinary direction and would capture points
        raise ValueError(
            "augmented_summary_outliers does not support metric='cosine'; "
            "use summary_outliers or the weighted summarize layer")
    return _augmented_summary_outliers(x, key, k=k, t=t, alpha=alpha,
                                       beta=beta, metric=metric, policy=policy)


@functools.partial(
    jax.jit,
    static_argnames=("k", "t", "alpha", "beta", "metric", "policy"),
)
def _augmented_summary_outliers(
    x: jnp.ndarray,
    key: jax.Array,
    *,
    k: int,
    t: int,
    alpha: float,
    beta: float,
    metric: str,
    policy: KernelPolicy,
) -> Summary:
    n, d = x.shape
    key, k1, k2 = jax.random.split(key, 3)
    base = _summary_outliers(
        x, k1, k=k, t=t, alpha=alpha, beta=beta, metric=metric, policy=policy,
    )
    _, m, rounds, _ = _plan(n, k, t, alpha, beta)

    # Existing center / candidate masks over X (from the base summary).
    cand_mask = jnp.zeros((n,), bool).at[
        jnp.where(base.valid & base.is_candidate, base.indices, n)
    ].set(True, mode="drop")
    center_mask = jnp.zeros((n,), bool).at[
        jnp.where(base.valid & ~base.is_candidate, base.indices, n)
    ].set(True, mode="drop")

    n_cand = (base.valid & base.is_candidate).sum()
    n_centers = (base.valid & ~base.is_candidate).sum()

    # Line 2: sample |X_r| - |S| extra centers from X \ (X_r u S).
    extra_cap = 8 * t + 1  # |X_r| <= 8t, so never need more than this
    eligible = ~(cand_mask | center_mask)
    # guard: if nothing is eligible fall back to sampling anywhere
    logits = jnp.where(eligible, 0.0, -jnp.inf)
    logits = jnp.where(eligible.any(), logits, jnp.zeros((n,)))
    extra_idx = jax.random.categorical(k2, logits, shape=(extra_cap,)).astype(jnp.int32)
    n_extra = jnp.maximum(n_cand - n_centers, 0)
    extra_valid = jnp.arange(extra_cap) < n_extra
    extra_mask = jnp.zeros((n,), bool).at[
        jnp.where(extra_valid, extra_idx, n)
    ].set(True, mode="drop")

    all_center_mask = center_mask | extra_mask
    center_cap = rounds * m + extra_cap
    c_idx = jnp.nonzero(all_center_mask, size=center_cap, fill_value=n)[0].astype(jnp.int32)
    xp = jnp.concatenate([x, jnp.full((1, d), _FAR, x.dtype)], axis=0)
    c_pts = xp[c_idx]  # invalid slots sit at _FAR -> never nearest

    # Line 3: reassign every x in X \ X_r to its nearest center in S u S'.
    _, amin = min_argmin(x, c_pts, metric=metric, policy=policy)
    pi = jnp.where(cand_mask, jnp.arange(n, dtype=jnp.int32), c_idx[amin])

    # Line 4: weights under the new mapping.
    w = jnp.zeros((n,), jnp.float32).at[pi].add(1.0)

    sel = all_center_mask | cand_mask
    cap = center_cap + 8 * t + 1
    idx_q = jnp.nonzero(sel, size=cap, fill_value=n)[0].astype(jnp.int32)
    xz = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    wp = jnp.concatenate([w, jnp.zeros((1,), jnp.float32)])
    candp = jnp.concatenate([cand_mask, jnp.zeros((1,), bool)])
    return Summary(
        indices=idx_q,
        points=xz[idx_q],
        weights=wp[idx_q],
        is_candidate=candp[idx_q],
        valid=idx_q < n,
        sigma=pi,
        n_rounds=base.n_rounds,
        n_remaining=base.n_remaining,
    )
