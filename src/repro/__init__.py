"""Distributed clustering and outlier detection — public API.

The package root re-exports the curated stable surface; ``__all__`` is
the contract (CI asserts every name resolves).  Four groups:

* **config + session** — ``PipelineConfig`` (declarative, serializable
  description of a run) and ``Session`` (one verb set over the oneshot /
  stream / sharded topologies).  Start here: see ``examples/`` and
  ``python -m repro run --config <file>``.
* **policies** — ``KernelPolicy`` (compute backend / tile selection) and
  ``SummarizerPolicy`` (summary algorithm selection), with their
  process-default installers.
* **summaries + algorithms** — the paper's objects for callers composing
  their own pipelines: Summary-Outliers, weighted summaries, the stream
  tree, k-means--, and the coordinator entry points.
* **serving + persistence** — the stream services, their configs, the
  model/result records, the async serving layer (``ServingSpec`` knobs,
  ``ServingScheduler``, typed ``ShedReject`` — ``repro.serve``), the
  checkpoint manager, and the tiered summary store (``StoreSpec`` policy
  / ``TieredStore`` engine — bounded-memory streaming with async spill,
  demand paging and incremental refresh, ``repro.store``).
* **observability** — the process metrics registry (``repro.obs``):
  ``Session.stats()`` snapshots it, ``trace``/``counter``/``gauge``/
  ``histogram`` feed it, ``render_prometheus`` formats it for scraping,
  ``set_metrics_enabled`` (or env ``REPRO_METRICS=0``) switches the whole
  plane off.  Request-level tracing rides the same plane: a bounded
  ``FlightRecorder`` of structured spans (``TraceSpec`` config knobs,
  ``configure_tracing``/``set_tracing_enabled``, ``dump_trace`` exports
  Chrome trace-event JSON) plus typed ``Alert`` records from the online
  drift/staleness/shed monitors in ``snapshot()["alerts"]``.

Deeper internals stay importable from their modules (``repro.kernels``,
``repro.summarize``, ``repro.stream``, ``repro.core``) but only the names
below are the stable cross-PR surface.
"""
from repro.api import (
    PipelineConfig, ProblemSpec, Session, TOPOLOGIES, TopologySpec,
    pipeline_config, register_config_migration,
)
from repro.store import StoreSpec, TieredStore
from repro.kernels.dispatch import (
    KernelPolicy, get_default_policy, set_default_policy, using_policy,
)
from repro.summarize import (
    SummarizerPolicy, get_default_summarizer, registered_summarizers,
    set_default_summarizer, summarizer_policy, using_summarizer,
)
from repro.core import (
    DistClusterResult, augmented_summary_outliers, distributed_cluster,
    kmeans_minus_minus, simulate_coordinator, summary_outliers,
)
from repro.stream import (
    BaseServiceConfig, ModelState, QueryResult, ServiceConfig,
    ShardedServiceConfig, ShardedStreamService, StreamService, StreamTree,
    TreeConfig, WeightedSummary, weighted_summary_outliers,
)
from repro.serve import (
    ScoreTicket, ServingScheduler, ServingSpec, ShedReject,
)
from repro.checkpoint.manager import CheckpointManager
from repro.obs import (
    Alert, FlightRecorder, MetricsRegistry, TraceSpec, apply_trace_spec,
    configure_tracing, dump_trace, render_prometheus, set_metrics_enabled,
    set_tracing_enabled, using_registry,
)

__all__ = [
    # config + session
    "PipelineConfig", "ProblemSpec", "TopologySpec", "TOPOLOGIES",
    "pipeline_config", "Session", "register_config_migration",
    # tiered summary store
    "StoreSpec", "TieredStore",
    # policies
    "KernelPolicy", "get_default_policy", "set_default_policy",
    "using_policy",
    "SummarizerPolicy", "get_default_summarizer", "set_default_summarizer",
    "summarizer_policy", "using_summarizer", "registered_summarizers",
    # summaries + algorithms
    "summary_outliers", "augmented_summary_outliers",
    "weighted_summary_outliers", "WeightedSummary", "StreamTree",
    "TreeConfig", "kmeans_minus_minus", "distributed_cluster",
    "simulate_coordinator", "DistClusterResult",
    # serving + persistence
    "BaseServiceConfig", "ServiceConfig", "ShardedServiceConfig",
    "StreamService", "ShardedStreamService", "ModelState", "QueryResult",
    "ServingSpec", "ServingScheduler", "ScoreTicket", "ShedReject",
    "CheckpointManager",
    # observability
    "MetricsRegistry", "render_prometheus", "set_metrics_enabled",
    "using_registry",
    "Alert", "FlightRecorder", "TraceSpec", "apply_trace_spec",
    "configure_tracing", "dump_trace", "set_tracing_enabled",
]
