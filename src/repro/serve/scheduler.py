"""Continuous-batching scheduler with admission control over one engine.

The scheduler/worker split in front of ``ServingFrontEnd``: many client
threads ``submit()`` score requests concurrently; a single worker thread
pops them in ticks — lingering up to ``batch_window_ms`` so requests from
*different* clients coalesce — and scores each tick through the engine's
existing micro-batched read path (ONE fused score-kernel dispatch per
micro-batch — pdist + argmin + threshold divide in a single pass via
``repro.kernels.score`` — padded to a static shape, so the hot path never
retraces).  Because the
scoring kernel computes every row independently and every micro-batch is
padded to the same static shape, a row's result is bit-identical no
matter which requests it shared a tick with — the concurrent path returns
exactly what sequential ``submit``+``drain`` would (asserted in
``tests/test_serving.py``).

Admission control (:class:`repro.serve.spec.ServingSpec`):

* the queue is bounded by ``queue_bound``; when full, ``shed_policy``
  either resolves the request *immediately* with a typed
  :class:`ShedReject` (``"shed"`` — overload costs goodput, not p99) or
  blocks the submitting client until space frees (``"wait"`` —
  backpressure);
* ``tenant_quota`` caps any one tenant's share of the queue, so a noisy
  tenant saturates its quota, not the service.

Every admitted request yields a :class:`ScoreTicket`; ``ticket.result()``
returns the engine's ``QueryResult`` (or the ``ShedReject``), re-raising
a worker-side failure on the *caller's* thread — a poison request never
kills the worker loop.

Telemetry (``repro.obs``): ``serve.queue_depth`` gauge,
``serve.admitted{tenant=}`` / ``serve.completed{tenant=}`` /
``serve.shed{tenant=,reason=}`` counters, ``serve.batch_occupancy``
histogram (batched rows / max_batch per tick), ``serve.ticks`` counter,
and per-tenant end-to-end latency in
``serve.latency{tenant=,topology=scheduler}``.

Tracing: every submitted row starts a trace in the flight recorder;
its lifecycle spans (``serve.request`` root, ``serve.admission``,
``serve.queue_wait``, ``serve.tick``) are recorded from timestamps the
scheduler stamps on the ticket, so an unsampled request costs two id
allocations and nothing else.  The worker carries the first sampled
ticket's context across the thread boundary (``obs.use_context``)
around the engine submit/drain, so that request's trace stitches
admission -> queue wait -> tick -> ``score.fused`` -> drain into ONE
timeline.  ``ShedReject`` and worker-tick errors are force-recorded
(they bypass sampling) with the rejecting tenant and live queue depth.
"""
from __future__ import annotations

import contextlib
import threading
import time
import weakref
from collections import deque
from typing import NamedTuple, Optional

import numpy as np

from repro import obs
from repro.serve.spec import ServingSpec

# occupancy is a fraction of max_batch — latency buckets would waste edges
_OCCUPANCY_BUCKETS = (0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0)

# the serve.queue_depth gauge is process-global (one registry, one series),
# while schedulers come and go with Sessions — so the gauge reads the *sum*
# over live schedulers rather than whichever instance registered last, and
# close() (or gc) removes an instance from the sum
_live_lock = threading.Lock()
_live_schedulers: "weakref.WeakSet[ServingScheduler]" = weakref.WeakSet()


def _total_queue_depth() -> int:
    with _live_lock:
        return sum(len(s._queue) for s in _live_schedulers)


class ShedReject(NamedTuple):
    """Typed admission rejection — a *result*, not an exception.

    ``reason`` is ``"queue_full"`` (the shared queue hit ``queue_bound``),
    ``"tenant_quota"`` (this tenant hit its quota) or ``"shutdown"`` (the
    scheduler was closed while the request waited for admission).
    ``queue_depth`` is the depth observed at the rejection.
    """
    request_id: int
    tenant: str
    reason: str
    queue_depth: int


class ScoreTicket:
    """One submitted row's pending result.

    ``result()`` blocks until the worker resolves the ticket and returns
    either the engine's ``QueryResult`` or a :class:`ShedReject`; a
    worker-side exception is re-raised here, on the caller's thread.
    """

    __slots__ = ("request_id", "tenant", "t_submit", "t_admit",
                 "t_dequeue", "t_done", "_event", "_value", "_error",
                 "_trace")

    def __init__(self, request_id: int, tenant: str):
        self.request_id = request_id
        self.tenant = tenant
        self.t_submit = time.perf_counter()
        self.t_admit: Optional[float] = None    # stamped at enqueue
        self.t_dequeue: Optional[float] = None  # stamped when a tick pops it
        self.t_done: Optional[float] = None
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None
        self._trace = None                      # SpanContext or None

    def _resolve(self, value) -> None:
        self.t_done = time.perf_counter()
        self._value = value
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self.t_done = time.perf_counter()
        self._error = error
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not scored within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    @property
    def shed(self) -> bool:
        return isinstance(self._value, ShedReject)

    @property
    def latency_s(self) -> Optional[float]:
        """Admission -> resolution wall time (None while pending)."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


class ServingScheduler:
    """Async request queue + worker loop over one ``ServingFrontEnd``.

    The scheduler *owns* its engine's read path: every engine access —
    the worker's per-tick ``submit``/``drain``, but also any synchronous
    caller going around the queue (``Session.score`` / ``ingest`` /
    ``refresh`` while serving is active) — must hold ``engine_lock``.
    The ``Session`` facade routes its verbs through that lock whenever a
    scheduler is attached.

    The worker thread starts lazily on the first ``submit`` (or via
    ``start()``); ``close()`` drains what was already admitted, resolves
    every ticket, and joins the worker.  A scheduler with
    ``autostart=False`` queues without scoring until ``start()`` — tests
    use this to exercise admission control deterministically.
    """

    def __init__(self, engine, spec: Optional[ServingSpec] = None, *,
                 autostart: bool = True):
        self.engine = engine
        self.spec = spec if spec is not None else ServingSpec()
        self.engine_lock = threading.RLock()
        self.max_batch = (self.spec.max_batch
                          if self.spec.max_batch is not None
                          else int(engine.cfg.micro_batch))
        self._cond = threading.Condition()
        self._queue: deque = deque()        # (ticket, row (d,) f32)
        self._pending: dict[str, int] = {}  # queued-per-tenant (quota)
        self._inflight = 0                  # popped, not yet resolved
        self._next_id = 0
        self._stop = False
        self._autostart = autostart
        self._worker: Optional[threading.Thread] = None
        self.peak_depth = 0                 # high-water mark of len(_queue)
        # ---------------------------------------------------------- metrics
        with _live_lock:
            _live_schedulers.add(self)
        self._depth_gauge = obs.gauge("serve.queue_depth")
        self._depth_gauge.set_fn(_total_queue_depth)
        self._ticks = obs.counter("serve.ticks")
        self._occupancy = obs.histogram("serve.batch_occupancy",
                                        buckets=_OCCUPANCY_BUCKETS)
        self._worker_errors = obs.counter("serve.worker_errors")
        self._by_tenant: dict = {}
        self._shed_counters: dict = {}
        reg = obs.get_default_registry()
        self._recorder = reg.recorder
        self._monitors = reg.monitors

    def _tenant_metrics(self, tenant: str):
        m = self._by_tenant.get(tenant)
        if m is None:
            m = (obs.counter("serve.admitted", tenant=tenant),
                 obs.counter("serve.completed", tenant=tenant),
                 obs.histogram("serve.latency", tenant=tenant,
                               topology="scheduler"))
            self._by_tenant[tenant] = m
        return m

    def _count_shed(self, tenant: str, reason: str) -> None:
        c = self._shed_counters.get((tenant, reason))
        if c is None:
            c = obs.counter("serve.shed", tenant=tenant, reason=reason)
            self._shed_counters[(tenant, reason)] = c
        c.inc()

    # ------------------------------------------------------------ tracing
    def _record_shed(self, ticket: ScoreTicket, reason: str,
                     depth: int) -> None:
        """Force-record a shed so overload incidents survive sampling."""
        self._recorder.record_event(
            "serve.shed", ticket._trace, force=True,
            attrs={"request_id": ticket.request_id, "tenant": ticket.tenant,
                   "reason": reason, "queue_depth": depth})
        self._record_ticket_trace(ticket, "shed")

    def _record_ticket_trace(self, ticket: ScoreTicket, status: str,
                             tick_span_id: Optional[int] = None,
                             batch_size: Optional[int] = None) -> None:
        """Record a resolved ticket's lifecycle spans from its stamps.

        Spans are written retroactively (not opened live) so pending
        tickets carry only timestamps; non-ok statuses force-record.
        """
        tctx = ticket._trace
        if tctx is None:
            return
        force = status != "ok"
        if not (tctx.sampled or force):
            return
        rec = self._recorder
        rec.record_span(
            "serve.request", tctx, t0=ticket.t_submit, t1=ticket.t_done,
            span_id=tctx.span_id, parent_id=None, status=status, force=force,
            attrs={"request_id": ticket.request_id, "tenant": ticket.tenant})
        if ticket.t_admit is None:
            return
        rec.record_span("serve.admission", tctx, t0=ticket.t_submit,
                        t1=ticket.t_admit, parent_id=tctx.span_id,
                        force=force)
        if ticket.t_dequeue is None:
            return
        rec.record_span("serve.queue_wait", tctx, t0=ticket.t_admit,
                        t1=ticket.t_dequeue, parent_id=tctx.span_id,
                        force=force)
        attrs = {} if batch_size is None else {"batch": batch_size}
        rec.record_span("serve.tick", tctx, t0=ticket.t_dequeue,
                        t1=ticket.t_done, span_id=tick_span_id,
                        parent_id=tctx.span_id, status=status, force=force,
                        attrs=attrs)

    # ------------------------------------------------------------ admission
    def submit(self, points, *, tenant: str = "default") -> list[ScoreTicket]:
        """Admit query rows; returns one (possibly pre-resolved) ticket per
        row, in row order.  Validation errors raise here, on the caller —
        a malformed row never reaches the worker."""
        x, _ = self.engine._validate_points(points, None)
        # start the worker *before* admission: a "wait"-policy submit
        # larger than the queue bound blocks until ticks free space, which
        # only a running worker can do
        if self._worker is None and self._autostart:
            self.start()
        admitted_c, _, _ = self._tenant_metrics(tenant)
        spec = self.spec
        tickets: list[ScoreTicket] = []
        n_admitted = 0
        n_shed = 0
        with self._cond:
            for row in x:
                ticket = ScoreTicket(self._next_id, tenant)
                self._next_id += 1
                ticket._trace = self._recorder.new_trace()
                tickets.append(ticket)
                if self._stop:
                    depth = len(self._queue)
                    ticket._resolve(ShedReject(ticket.request_id, tenant,
                                               "shutdown", depth))
                    self._count_shed(tenant, "shutdown")
                    self._record_shed(ticket, "shutdown", depth)
                    n_shed += 1
                    continue
                reason = self._admission_block(tenant)
                if reason is not None and spec.shed_policy == "wait":
                    while reason is not None and not self._stop:
                        self._cond.wait(0.05)
                        reason = self._admission_block(tenant)
                    if self._stop:
                        reason = "shutdown"
                if reason is not None:
                    depth = len(self._queue)
                    ticket._resolve(ShedReject(ticket.request_id, tenant,
                                               reason, depth))
                    self._count_shed(tenant, reason)
                    self._record_shed(ticket, reason, depth)
                    n_shed += 1
                    continue
                ticket.t_admit = time.perf_counter()
                self._queue.append((ticket, row))
                self._pending[tenant] = self._pending.get(tenant, 0) + 1
                n_admitted += 1
                if len(self._queue) > self.peak_depth:
                    self.peak_depth = len(self._queue)
            if n_admitted:
                self._cond.notify_all()   # wake the worker (and waiters)
        if n_admitted:
            admitted_c.inc(n_admitted)
        if n_admitted or n_shed:
            self._monitors.observe_admission(n_admitted, n_shed)
        return tickets

    def _admission_block(self, tenant: str) -> Optional[str]:
        """Why this tenant cannot enqueue right now (None = admitted).
        Caller holds ``_cond``."""
        if len(self._queue) >= self.spec.queue_bound:
            return "queue_full"
        q = self.spec.tenant_quota
        if q is not None and self._pending.get(tenant, 0) >= q:
            return "tenant_quota"
        return None

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ worker
    def start(self) -> None:
        """Start the worker thread (idempotent)."""
        with self._cond:
            if self._worker is not None or self._stop:
                return
            self._worker = threading.Thread(
                target=self._loop, name="serve-scheduler", daemon=True)
            self._worker.start()

    def _loop(self) -> None:
        window_s = self.spec.batch_window_ms / 1e3
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait(0.1)
                if not self._queue and self._stop:
                    return
                # continuous batching: linger up to the batch window so
                # requests arriving from other clients join this tick
                if window_s > 0 and len(self._queue) < self.max_batch:
                    deadline = time.perf_counter() + window_s
                    while len(self._queue) < self.max_batch:
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0 or self._stop:
                            break
                        self._cond.wait(remaining)
                take = min(self.max_batch, len(self._queue))
                batch = [self._queue.popleft() for _ in range(take)]
                t_pop = time.perf_counter()
                for ticket, _ in batch:
                    ticket.t_dequeue = t_pop
                    self._pending[ticket.tenant] -= 1
                self._inflight += take
                self._cond.notify_all()   # queue space freed: wake waiters
            try:
                self._score_batch(batch)
            finally:
                with self._cond:
                    self._inflight -= len(batch)
                    self._cond.notify_all()

    def _score_batch(self, batch) -> None:
        """One tick: score the popped requests through the engine's
        micro-batched read path and resolve their tickets.  Engine errors
        resolve the tick's tickets (re-raised at ``result()``) and leave
        the loop alive for the next tick."""
        self._ticks.inc()
        self._occupancy.observe(len(batch) / self.max_batch)
        rows = np.stack([row for _, row in batch])
        # cross-thread stitch: carry the first sampled ticket's trace into
        # the engine work so its score.enqueue/batch/fused/drain spans nest
        # under this tick (one "primary" per tick keeps the worker O(1))
        rec = self._recorder
        primary: Optional[ScoreTicket] = None
        tick_span_id: Optional[int] = None
        for ticket, _ in batch:
            if ticket._trace is not None and ticket._trace.sampled:
                primary = ticket
                tick_span_id = rec.alloc_id()
                break
        if primary is not None:
            engine_ctx = obs.use_context(obs.SpanContext(
                primary._trace.trace_id, tick_span_id, True))
        else:
            engine_ctx = contextlib.nullcontext()
        try:
            with self.engine_lock, engine_ctx:
                try:
                    ids = self.engine.submit(rows)
                    results = self.engine.drain()
                except BaseException:
                    # a failed tick must not leave its rows in the engine's
                    # read queue: drain() can raise before popping anything
                    # (e.g. "no model yet"), and the next tick would then
                    # drain the stale rows first, misaligning every
                    # subsequent result
                    self.engine.discard_pending()
                    raise
        except BaseException as e:
            self._worker_errors.inc()
            rec.record_event(
                "serve.worker_error",
                primary._trace if primary is not None else None, force=True,
                attrs={"error": type(e).__name__, "batch": len(batch),
                       "queue_depth": len(self._queue),
                       "tenants": sorted({t.tenant for t, _ in batch})})
            for ticket, _ in batch:
                ticket._fail(e)
                self._record_ticket_trace(
                    ticket, "error",
                    tick_span_id if ticket is primary else None,
                    batch_size=len(batch))
            return
        by_id = {r.request_id: r for r in results}
        if len(results) != len(batch) or any(rid not in by_id for rid in ids):
            self._worker_errors.inc()
            err = RuntimeError(
                f"engine returned {len(results)} results for a "
                f"{len(batch)}-row tick — its read queue was touched "
                f"outside the scheduler's engine_lock")
            rec.record_event(
                "serve.worker_error",
                primary._trace if primary is not None else None, force=True,
                attrs={"error": "ResultMisalignment", "batch": len(batch),
                       "queue_depth": len(self._queue),
                       "tenants": sorted({t.tenant for t, _ in batch})})
            for ticket, _ in batch:
                ticket._fail(err)
                self._record_ticket_trace(
                    ticket, "error",
                    tick_span_id if ticket is primary else None,
                    batch_size=len(batch))
            return
        for (ticket, _), rid in zip(batch, ids):
            ticket._resolve(by_id[rid])
            _, completed_c, lat_h = self._tenant_metrics(ticket.tenant)
            completed_c.inc()
            lat_h.observe(ticket.latency_s)
            self._record_ticket_trace(
                ticket, "ok", tick_span_id if ticket is primary else None,
                batch_size=len(batch))

    # ------------------------------------------------------------ lifecycle
    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until everything admitted so far is resolved.  Returns
        False on timeout (queue or in-flight work remains)."""
        if self._worker is None and self._autostart:
            self.start()
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._cond:
            while self._queue or self._inflight:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(remaining if remaining is not None else 0.1)
        return True

    def close(self) -> None:
        """Stop admitting, drain what was admitted, join the worker.
        Idempotent; afterwards ``submit`` resolves everything as a
        ``shutdown`` shed."""
        with _live_lock:
            _live_schedulers.discard(self)
        with self._cond:
            self._stop = True
            self._cond.notify_all()
            worker = self._worker
        if worker is not None:
            worker.join()
        else:
            # never started: resolve whatever sits in the queue as shed
            with self._cond:
                while self._queue:
                    ticket, _ = self._queue.popleft()
                    self._pending[ticket.tenant] -= 1
                    ticket._resolve(ShedReject(ticket.request_id,
                                               ticket.tenant, "shutdown", 0))
                    self._count_shed(ticket.tenant, "shutdown")
                    self._record_shed(ticket, "shutdown", 0)

    def __enter__(self) -> "ServingScheduler":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
