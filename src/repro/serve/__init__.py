"""Async serving: continuous batching + admission control over one model.

The paper's summary is tiny and scoring against it is one fused score
kernel (``repro.kernels.score``: pdist + argmin + threshold divide in a
single dispatch) — cheap enough that a single shared model should serve
many concurrent clients.  This package is the scheduler/worker split
that makes that true in-process:

    client threads --submit--> bounded queue --tick--> one fused score
         |                       |  admission control       per micro-batch
    score_stream()               |   queue_bound: shed|wait      |
     (Session)                   |   per-tenant quotas           v
         <------- tickets resolve with QueryResult | ShedReject --

* :class:`ServingSpec` (``spec``) — the declarative knobs (queue bound,
  batch window, shed-or-wait policy, tenant quota), carried by
  ``PipelineConfig.serving``;
* :class:`ServingScheduler` (``scheduler``) — the bounded request queue,
  admission control and the continuous-batching worker tick over any
  ``ServingFrontEnd``; per-request :class:`ScoreTicket`, typed
  :class:`ShedReject`;
* ``loadgen`` — the open-loop N-client load generator behind the
  goodput-vs-offered-load benchmark ladder and ``serve --clients N``.

Scores through the concurrent path are bit-identical to sequential
``submit``+``drain``; queue depth, shed rate, batch occupancy and
per-tenant latency land in ``repro.obs``.
"""
from repro.serve.spec import SHED_POLICIES, ServingSpec  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    ScoreTicket, ServingScheduler, ShedReject,
)
from repro.serve.loadgen import estimate_capacity, run_load  # noqa: F401
