"""Admission-control and continuous-batching knobs: ``ServingSpec``.

One frozen, JSON-scalar dataclass describing how the serving scheduler
(:class:`repro.serve.scheduler.ServingScheduler`) admits and batches
concurrent score requests — the serving-layer twin of ``KernelPolicy`` /
``SummarizerPolicy``.  ``PipelineConfig`` carries an optional ``serving``
section of exactly this shape, so a load-test setup is a reproducible
artifact like everything else.

The knobs, and why each exists:

* ``queue_bound`` — the scheduler's request queue is *bounded*; an
  unbounded queue under overload turns a latency problem into an OOM plus
  unbounded p99.  When the queue is full the ``shed_policy`` decides.
* ``shed_policy`` — ``"shed"`` resolves the request immediately with a
  typed :class:`repro.serve.scheduler.ShedReject` (goodput stays flat and
  p99 stays bounded under overload: load-shedding); ``"wait"`` blocks the
  submitting client until space frees (backpressure propagates to the
  caller: no request is lost, offered load self-limits).
* ``batch_window_ms`` — how long a scheduler tick lingers to let more
  requests join the batch.  Larger windows raise batch occupancy (fewer,
  fuller fused score-kernel calls) at the cost of added latency at low
  load.
* ``tenant_quota`` — per-tenant cap on *queued* requests; one noisy
  tenant can fill at most its quota of the shared queue, so other tenants
  keep getting admitted (fairness under multi-tenant overload).
* ``max_batch`` — per-tick batch cap; ``None`` uses the engine's
  ``micro_batch`` (one jitted call per tick, no retrace).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

SHED_POLICIES = ("shed", "wait")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """How the scheduler admits and batches concurrent score requests."""

    queue_bound: int = 1024          # max queued (admitted, unscored) requests
    batch_window_ms: float = 2.0     # per-tick linger to fill the batch
    shed_policy: str = "shed"        # on a full queue: "shed" | "wait"
    tenant_quota: Optional[int] = None   # max queued requests per tenant
    max_batch: Optional[int] = None      # per-tick cap; None = micro_batch

    def __post_init__(self):
        _require(isinstance(self.queue_bound, int)
                 and not isinstance(self.queue_bound, bool)
                 and self.queue_bound >= 1,
                 f"serving.queue_bound must be an int >= 1, "
                 f"got {self.queue_bound!r}")
        _require(isinstance(self.batch_window_ms, (int, float))
                 and not isinstance(self.batch_window_ms, bool)
                 and self.batch_window_ms >= 0,
                 f"serving.batch_window_ms must be a number >= 0, "
                 f"got {self.batch_window_ms!r}")
        # serialization round-trips through JSON: keep the field a float
        object.__setattr__(self, "batch_window_ms",
                           float(self.batch_window_ms))
        _require(self.shed_policy in SHED_POLICIES,
                 f"serving.shed_policy must be one of {SHED_POLICIES}, "
                 f"got {self.shed_policy!r}")
        for name in ("tenant_quota", "max_batch"):
            v = getattr(self, name)
            _require(v is None or (isinstance(v, int)
                                   and not isinstance(v, bool) and v >= 1),
                     f"serving.{name} must be None or an int >= 1, "
                     f"got {v!r}")
        if self.tenant_quota is not None:
            _require(self.tenant_quota <= self.queue_bound,
                     f"serving.tenant_quota ({self.tenant_quota}) cannot "
                     f"exceed serving.queue_bound ({self.queue_bound})")
