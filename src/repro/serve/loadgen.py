"""Open-loop multi-client load generator for the serving scheduler.

Drives N client threads against one :class:`ServingScheduler` at a target
*offered* load (rows/s) and reports what actually happened: goodput
(completed rows/s), shed rate, and client-observed latency percentiles.
Open-loop pacing is the point — each client submits on a wall-clock
schedule whether or not earlier requests finished, so offered load can
exceed capacity and the report shows how admission control spends the
excess (shed rate up, p99 bounded) instead of the closed-loop illusion
where offered load silently collapses to capacity.

Used by ``benchmarks/serving_bench.py`` (the goodput-vs-offered-load
ladder in ``BENCH_stream.json``), ``python -m repro serve --clients N``,
and ``examples/serve_load.py``.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.serve.scheduler import ServingScheduler


def estimate_capacity(scheduler: ServingScheduler, queries: np.ndarray, *,
                      duration_s: float = 0.5, burst: int = 256,
                      seed: int = 0) -> float:
    """Closed-loop throughput estimate (rows/s): one client submits a
    burst, waits for it, repeats.  An upper-bound anchor for placing the
    open-loop ladder's rungs."""
    rng = np.random.default_rng(seed)
    done = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < duration_s:
        rows = queries[rng.integers(0, len(queries), size=burst)]
        for t in scheduler.submit(rows):
            t.result(timeout=60.0)
        done += burst
    return done / (time.perf_counter() - t0)


def run_load(scheduler: ServingScheduler, queries: np.ndarray, *,
             offered_rps: float, clients: int = 4, duration_s: float = 2.0,
             tenants: Optional[Sequence[str]] = None,
             seed: int = 0) -> dict:
    """Offer ``offered_rps`` rows/s from ``clients`` threads for
    ``duration_s``; returns one plain JSON-able report dict.

    ``tenants`` maps client i to ``tenants[i % len(tenants)]`` (default:
    every client is the ``"default"`` tenant).  The report's
    ``per_tenant`` section breaks submitted/completed/shed down by tenant
    — the fairness check reads it.
    """
    per_client = offered_rps / clients
    # target ~250 submit calls/s/client so pacing stays sleep-limited,
    # with small bursts so the queue sees a steady arrival process
    burst = max(1, int(round(per_client / 250)))
    interval = burst / per_client
    all_tickets: list[list] = [[] for _ in range(clients)]
    start = time.perf_counter()
    end = start + duration_s

    def client(ci: int) -> None:
        rng = np.random.default_rng(seed + 1000 + ci)
        tenant = tenants[ci % len(tenants)] if tenants else "default"
        next_t = time.perf_counter()
        mine = all_tickets[ci]
        while True:
            now = time.perf_counter()
            if now >= end:
                break
            mine.extend(scheduler.submit(
                queries[rng.integers(0, len(queries), size=burst)],
                tenant=tenant))
            next_t += interval
            delay = next_t - time.perf_counter()
            if delay > 0:
                time.sleep(delay)

    threads = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    scheduler.flush(timeout=120.0)
    wall_s = time.perf_counter() - start

    lat: list[float] = []
    per_tenant: dict[str, dict] = {}
    completed = shed = 0
    for mine in all_tickets:
        for t in mine:
            entry = per_tenant.setdefault(
                t.tenant, {"submitted": 0, "completed": 0, "shed": 0})
            entry["submitted"] += 1
            if t.shed:
                shed += 1
                entry["shed"] += 1
            else:
                t.result(timeout=60.0)   # re-raises worker errors
                completed += 1
                entry["completed"] += 1
                lat.append(t.latency_s)
    submitted = completed + shed
    arr = np.asarray(lat, np.float64)
    return {
        "offered_rps": round(float(offered_rps), 1),
        "clients": clients,
        "duration_s": round(duration_s, 3),
        "wall_s": round(wall_s, 3),
        "submitted": submitted,
        "completed": completed,
        "shed": shed,
        "goodput_rps": round(completed / wall_s, 1),
        "shed_rate": round(shed / submitted, 4) if submitted else 0.0,
        "p50_ms": round(float(np.percentile(arr, 50)) * 1e3, 3)
        if arr.size else None,
        "p99_ms": round(float(np.percentile(arr, 99)) * 1e3, 3)
        if arr.size else None,
        "per_tenant": per_tenant,
    }
