"""Tiered summary store: spec validation, spill/page-in bit-identity on
drifting windowed streams, eviction-vs-spill interplay, checkpoint
round-trips with spilled levels, incremental refresh (skip + warm start),
counter accounting, and the config-version migration hook.

Most tests isolate metrics with ``obs.using_registry`` so counters from
one test never leak into another's accounting assertions.
"""
import warnings

import numpy as np
import pytest

from repro import obs
from repro.api.config import (PipelineConfig, _MIGRATIONS, pipeline_config,
                              register_config_migration)
from repro.api.session import Session
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import drifting_gauss
from repro.store import StoreSpec
from repro.stream import ServiceConfig, StreamService, StreamTree, TreeConfig


def _drift(n, d=4, seed=0):
    """First `n` points of a 3-phase drifting mixture (seeded, float32)."""
    per = -(-n // (3 * 6))  # ceil so we always have >= n points
    x, _, _ = drifting_gauss(n_phases=3, n_centers=6, per_center=per,
                             d=d, sigma=0.05, drift=4.0, seed=seed)
    return np.asarray(x[:n], np.float32)


def _cold(tree):
    return [nd for nd in tree.nodes if nd.summary is None]


# ------------------------------------------------------------ spec
def test_storespec_validation():
    assert not StoreSpec().tiered
    assert StoreSpec(hot_levels=0).tiered
    assert StoreSpec(hot_bytes=1 << 20).tiered
    with pytest.raises(ValueError, match="hot_levels"):
        StoreSpec(hot_levels=-1)
    with pytest.raises(ValueError, match="hot_levels"):
        StoreSpec(hot_levels=True)
    with pytest.raises(ValueError, match="hot_bytes"):
        StoreSpec(hot_bytes=0)
    with pytest.raises(ValueError, match="warm_start_frac"):
        StoreSpec(warm_start_frac=1.5)
    with pytest.raises(ValueError, match="incremental_refresh"):
        StoreSpec(incremental_refresh="yes")
    with pytest.raises(ValueError, match="directory"):
        StoreSpec(directory=7)


# ------------------------------------------------------------ tiering
def _tree_pair(spec, *, n=40_000, window=8192, leaf_size=512, seed=0):
    """Ingest the same drifting stream into an untiered and a tiered tree."""
    base = dict(dim=4, k=6, t=24, leaf_size=leaf_size, window=window,
                seed=3)
    plain = StreamTree(TreeConfig(**base))
    tiered = StreamTree(TreeConfig(**base, store=spec))
    x = _drift(n, seed=seed)
    for i in range(0, len(x), 4096):
        plain.ingest(x[i:i + 4096])
        tiered.ingest(x[i:i + 4096])
    return plain, tiered


def test_tiered_root_bit_identical_under_level_budget():
    with obs.using_registry(obs.MetricsRegistry()):
        plain, tiered = _tree_pair(StoreSpec(hot_levels=0))
        # the tier must actually engage: deep levels spilled, merges of
        # cold nodes demand-paged them back
        st = tiered.store.stats()
        assert st["spills"] >= 1 and st["page_ins"] >= 1
        assert st["spill_bytes"] > 0 and st["page_in_bytes"] > 0
        assert len(_cold(tiered)) >= 1
        # ...and move bytes only: the root is bit-identical
        for a, b in zip(plain.packed_root(), tiered.packed_root()):
            np.testing.assert_array_equal(a, b)
        assert plain.total_weight == tiered.total_weight
        assert plain.num_records == tiered.num_records


def test_tiered_byte_budget_bounds_resident_payload():
    budget = 8 * 1024
    with obs.using_registry(obs.MetricsRegistry()):
        plain, tiered = _tree_pair(StoreSpec(hot_bytes=budget))
        resident = sum(nd.nbytes for nd in tiered.nodes
                       if nd.summary is not None)
        assert resident <= budget
        assert tiered.store.stats()["spills"] >= 1
        for a, b in zip(plain.packed_root(), tiered.packed_root()):
            np.testing.assert_array_equal(a, b)


def test_spilled_nodes_metadata_survives():
    with obs.using_registry(obs.MetricsRegistry()):
        _, tiered = _tree_pair(StoreSpec(hot_levels=0))
        for nd in _cold(tiered):
            # everything refresh decisions / gauges need stays on the node
            assert nd.spill_step is not None
            assert nd.n_records > 0 and nd.nbytes > 0 and nd.weight > 0
        # page_in is transient: reading a cold node does not re-residentize
        nd = _cold(tiered)[0]
        summ = tiered.store.page_in(nd)
        assert summ.points.shape[0] == nd.n_records
        assert nd.summary is None


def test_eviction_discards_spilled_files():
    """Window eviction of a cold node must delete its on-disk blob — the
    spill directory tracks live cold nodes, not stream history."""
    with obs.using_registry(obs.MetricsRegistry()):
        cfg = TreeConfig(dim=4, k=6, t=24, leaf_size=256, window=2048,
                         seed=3, store=StoreSpec(hot_levels=0))
        tree = StreamTree(cfg)
        x = _drift(30_000, seed=1)
        for i in range(0, len(x), 1024):
            tree.ingest(x[i:i + 1024])
        store = tree.store
        store.flush()
        on_disk = store.manager.all_steps()
        cold_steps = sorted(nd.spill_step for nd in _cold(tree))
        assert on_disk == cold_steps
        # far fewer blobs than total spills: evicted cold nodes were
        # discarded from disk, not leaked
        assert len(on_disk) < store.stats()["spills"]


def test_store_counter_accounting():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        _, tiered = _tree_pair(StoreSpec(hot_levels=0), n=20_000)
        store = tiered.store
        st = store.stats()
        # local tallies mirror the obs counters exactly
        snap = reg.snapshot()["counters"]
        labels = ",".join(f"{k}={v}" for k, v in sorted(store.labels.items()))
        for key in ("spills", "page_ins", "spill_bytes", "page_in_bytes"):
            assert snap[f"store.{key}{{{labels}}}"] == st[key]
        # every currently-cold node was spilled exactly once and never
        # re-spilled after a transient page-in
        assert st["spills"] >= len(_cold(tiered))
        store.sync(tiered.nodes)
        g = reg.snapshot()["gauges"]
        assert g[f"store.hot_nodes{{{labels}}}"] + \
            g[f"store.cold_nodes{{{labels}}}"] == len(tiered.nodes)
        assert g[f"store.cold_bytes{{{labels}}}"] == \
            sum(nd.nbytes for nd in _cold(tiered))


# ------------------------------------------------------------ service
def _svc_cfg(**over):
    base = dict(dim=4, k=5, t=20, leaf_size=512, refresh_every=4096,
                window=8192, seed=7)
    base.update(over)
    return ServiceConfig(**base)


def test_service_scores_bit_identical_tiered_vs_untiered():
    """Tiering moves bytes only: an untiered service with the same spec
    (hence the same epoch-derived fit keys) scores bit-identically."""
    x = _drift(24_000, seed=2)
    q = _drift(256, seed=9)
    plain = StreamService(_svc_cfg(store=StoreSpec()))
    tiered = StreamService(_svc_cfg(store=StoreSpec(hot_levels=0)))
    for i in range(0, len(x), 2048):
        plain.ingest(x[i:i + 2048])
        tiered.ingest(x[i:i + 2048])
    for a, b in zip(plain.tree.packed_root(), tiered.tree.packed_root()):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(plain.score(q), tiered.score(q)):
        assert a.center == b.center
        assert a.distance == b.distance          # bit-identical
        assert a.outlier_score == b.outlier_score


def test_service_checkpoint_roundtrip_with_spilled_levels(tmp_path):
    cfg = _svc_cfg(store=StoreSpec(hot_levels=0))
    svc = StreamService(cfg)
    x = _drift(24_000, seed=4)
    for i in range(0, len(x), 2048):
        svc.ingest(x[i:i + 2048])
    assert len(_cold(svc.tree)) >= 1   # checkpoint must pack cold levels
    q = _drift(256, seed=11)
    before = svc.score(q)
    svc.save(CheckpointManager(tmp_path), step=1)
    restored = StreamService.restore(cfg, CheckpointManager(tmp_path))
    # the restored tree re-tiers under its own fresh spill directory
    assert len(_cold(restored.tree)) >= 1
    for a, b in zip(svc.tree.packed_root(), restored.tree.packed_root()):
        np.testing.assert_array_equal(a, b)
    after = restored.score(q)
    for a, b in zip(before, after):
        assert a.center == b.center
        assert a.distance == b.distance
        assert a.outlier_score == b.outlier_score
    restored.ingest(x[:2048])
    assert restored.tree.total_ingested == svc.tree.total_ingested + 2048


# ------------------------------------------------------------ refresh reuse
def test_incremental_refresh_skips_unchanged_root():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        svc = StreamService(_svc_cfg(store=StoreSpec(hot_levels=0)))
        x = _drift(12_000, seed=5)
        svc.ingest(x)
        svc.refresh(blocking=True)   # fold in the post-cadence leftovers
        v = int(svc.model.version)
        assert v >= 1
        # no new points -> root unchanged -> both refreshes are skipped
        svc.refresh(blocking=True)
        svc.refresh(blocking=True)
        assert int(svc.model.version) == v
        snap = reg.snapshot()["counters"]
        assert snap["refresh.skipped{topology=stream}"] >= 2


def test_incremental_refresh_scores_bit_identical_to_always_refit():
    x = _drift(20_000, seed=6)
    q = _drift(256, seed=13)
    skip = StreamService(_svc_cfg(
        store=StoreSpec(hot_levels=0, incremental_refresh=True)))
    refit = StreamService(_svc_cfg(
        store=StoreSpec(hot_levels=0, incremental_refresh=False)))
    for i in range(0, len(x), 2048):
        skip.ingest(x[i:i + 2048])
        refit.ingest(x[i:i + 2048])
    # force extra refreshes with nothing new: `skip` skips, `refit` refits
    for _ in range(2):
        skip.refresh(blocking=True)
        refit.refresh(blocking=True)
    assert int(refit.model.version) > int(skip.model.version)
    # the skipped fits were provably redundant: scores are bit-identical
    for a, b in zip(skip.score(q), refit.score(q)):
        assert a.center == b.center
        assert a.distance == b.distance
        assert a.outlier_score == b.outlier_score


def test_warm_start_counter_and_validity():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        svc = StreamService(_svc_cfg(
            refresh_every=100_000,
            store=StoreSpec(warm_start_frac=1.0)))
        x = _drift(16_000, seed=8)
        svc.ingest(x[:12_000])
        svc.refresh(blocking=True)
        v = int(svc.model.version)
        svc.ingest(x[12_000:])   # small new mass -> warm-startable
        svc.refresh(blocking=True)
        assert int(svc.model.version) == v + 1
        snap = reg.snapshot()["counters"]
        assert snap["refresh.warm_starts{topology=stream}"] >= 1
        assert np.isfinite(np.asarray(svc.model.centers)).all()


# ------------------------------------------------------------ api surface
def test_session_store_stats_and_obs_series():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        cfg = pipeline_config(dim=4, k=5, t=20, topology="stream",
                              window=8192, leaf_size=512,
                              refresh_every=4096, seed=7,
                              store={"hot_levels": 0})
        sess = Session(cfg)
        sess.ingest(_drift(16_000, seed=2))
        st = sess.store_stats()
        assert st is not None and st["spills"] >= 1
        snap = reg.snapshot()
        series = set(snap["counters"]) | set(snap["gauges"])
        for prefix in ("store.spills{", "store.page_ins{",
                       "store.hot_bytes{", "store.cold_nodes{",
                       "refresh.skipped{", "refresh.warm_starts{"):
            assert any(s.startswith(prefix) for s in series), prefix
    # untiered sessions report no store
    plain = Session(pipeline_config(dim=4, k=5, t=20, topology="stream",
                                    leaf_size=512, seed=7))
    plain.ingest(_drift(4_000, seed=2))
    assert plain.store_stats() is None


# ------------------------------------------------------------ config version
def test_config_v1_migrates_with_warning():
    d = pipeline_config(dim=4, k=5, t=20).to_dict()
    d["version"] = 1
    with pytest.warns(UserWarning, match="version-1"):
        cfg = PipelineConfig.from_dict(d)
    assert cfg.problem.dim == 4 and cfg.to_dict()["version"] == 2


def test_config_unknown_version_rejected():
    d = pipeline_config(dim=4, k=5, t=20).to_dict()
    d["version"] = 99
    with pytest.raises(ValueError, match="not supported"):
        PipelineConfig.from_dict(d)


def test_config_migration_registry_chains():
    @register_config_migration(0)
    def _v0_to_v1(d):
        d.pop("legacy_knob", None)
        return d
    try:
        d = pipeline_config(dim=4, k=5, t=20).to_dict()
        d.update(version=0, legacy_knob=True)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # v1->v2 hop still warns
            cfg = PipelineConfig.from_dict(d)
        assert cfg.problem.k == 5
    finally:
        del _MIGRATIONS[0]


def test_config_store_roundtrip_and_validation():
    cfg = pipeline_config(dim=4, k=5, t=20, topology="stream", window=8192,
                          store={"hot_levels": 1, "warm_start_frac": 0.5})
    again = PipelineConfig.from_dict(cfg.to_dict())
    assert again.store == cfg.store == StoreSpec(hot_levels=1,
                                                 warm_start_frac=0.5)
    # bare forms: bool toggles refresh-reuse only, int means hot_levels
    assert pipeline_config(dim=4, k=5, t=20, topology="stream",
                           store=True).store == StoreSpec()
    assert pipeline_config(dim=4, k=5, t=20, topology="stream",
                           store=2).store == StoreSpec(hot_levels=2)
    assert pipeline_config(dim=4, k=5, t=20, topology="stream",
                           store=False).store is None
    with pytest.raises(ValueError, match="stream/sharded"):
        pipeline_config(dim=4, k=5, t=20, store={"hot_levels": 0})


# ------------------------------------------------------------ property (slow)
@pytest.mark.slow
def test_property_long_drifting_stream_under_tiny_budget(tmp_path):
    """ISSUE acceptance: a windowed 1M-point drifting stream under a tiny
    hot budget stays bit-identical to the in-memory tree, interleaves
    eviction with spilling without leaking blobs, survives a checkpoint
    round-trip with spilled levels, and keeps counters consistent."""
    with obs.using_registry(obs.MetricsRegistry()):
        n, batch = 1_000_000, 8192
        base = dict(dim=5, k=8, t=40, leaf_size=2048, window=65_536, seed=3)
        plain = StreamTree(TreeConfig(**base))
        tiered = StreamTree(TreeConfig(**base,
                                       store=StoreSpec(hot_levels=1)))
        x = _drift(n, d=5, seed=0)
        for i in range(0, n, batch):
            plain.ingest(x[i:i + batch])
            tiered.ingest(x[i:i + batch])
        st = tiered.store.stats()
        assert st["spills"] > 10 and st["page_ins"] > 10
        for a, b in zip(plain.packed_root(), tiered.packed_root()):
            np.testing.assert_array_equal(a, b)
        # eviction-vs-spill interplay: the windowed stream evicted most of
        # its history, so live blobs are a small fraction of total spills
        tiered.store.flush()
        on_disk = tiered.store.manager.all_steps()
        assert sorted(nd.spill_step for nd in _cold(tiered)) == on_disk
        assert len(on_disk) < st["spills"] // 2
        # checkpoint round-trip with spilled levels
        cfg = TreeConfig(**base, store=StoreSpec(hot_levels=1))
        cm = CheckpointManager(tmp_path)
        cm.save(1, tiered.pack_state(), blocking=True)
        state, _ = cm.restore(tiered.pack_state())
        restored = StreamTree.from_state(cfg, state)
        for a, b in zip(tiered.packed_root(), restored.packed_root()):
            np.testing.assert_array_equal(a, b)
