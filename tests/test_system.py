"""End-to-end behaviour of the paper's system: the full distributed
pipeline recovers planted structure, beats the rand baseline, and respects
the paper's communication bound."""
import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (kmeans_minus_minus, rand_summary, simulate_coordinator)
from repro.core.metrics import clustering_losses, outlier_scores
from repro.kernels.dispatch import KernelPolicy
from repro.data.synthetic import gauss, partition


def test_end_to_end_distributed_clustering_with_outliers():
    k, t, s = 15, 200, 10
    x, out_ids = gauss(n_centers=k, per_center=1000, sigma=0.1, t=t, seed=9)
    n = x.shape[0]
    parts, gids = partition(x, s, "random", seed=1, outlier_ids=out_ids)
    res = simulate_coordinator(parts, jax.random.key(0), k=k, t=t)

    conc = np.concatenate(gids)
    reported = conc[res["outlier_ids"]]
    sc = outlier_scores(out_ids, conc[res["summary_ids"]], reported)

    # Theorem 2 quality: near-perfect outlier recovery on separated data
    assert sc.pre_recall >= 0.95
    assert sc.recall >= 0.85 and sc.precision >= 0.85

    # communication bound: O(s*k*log n + t) records, one round
    bound = 40 * (s * k * math.log(n) + t)   # generous constant
    assert res["comm_records"] <= bound

    # the distributed solution's loss is close to a centralized k-means--
    mask = np.zeros(n, bool)
    mask[reported] = True
    l1, _ = clustering_losses(jnp.asarray(x), jnp.asarray(res["centers"]),
                              jnp.asarray(mask))
    sol = kmeans_minus_minus(jnp.asarray(x), jnp.ones((n,)),
                             jnp.ones((n,), bool), jax.random.key(1),
                             k=k, t=float(t),
                             policy=KernelPolicy(block_n=65536))
    central_mask = np.asarray(sol.outlier)
    l1c, _ = clustering_losses(jnp.asarray(x), sol.centers,
                               jnp.asarray(central_mask))
    assert float(l1) <= 2.0 * float(l1c) + 1e-6   # O(gamma) approximation

    # and it beats the rand baseline at equal summary size on detection
    budget = max(1, int(np.ceil(res["comm_records"] / s)))
    rand_ids = []
    for i, part in enumerate(parts):
        summ = rand_summary(jnp.asarray(part), jax.random.fold_in(jax.random.key(2), i),
                            budget=budget)
        rand_ids.append(gids[i][np.asarray(summ.indices)])
    rand_pre = outlier_scores(out_ids, np.concatenate(rand_ids),
                              np.array([], int)).pre_recall
    assert sc.pre_recall > rand_pre + 0.05
