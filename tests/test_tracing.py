"""Request-level tracing and online monitors (the flight recorder).

Coverage demanded by the observability PR's acceptance criteria:
  * a concurrent ``score_stream`` storm yields exactly ONE trace per
    ticket, with admission / queue-wait / tick spans parented under the
    ``serve.request`` root — the cross-thread stitch works;
  * head sampling is deterministic under a seeded sampler and is decided
    once at the trace root;
  * ``sample_rate=0`` records nothing except forced events — shed
    rejections (with the rejecting tenant and live queue depth) and
    worker-tick errors survive any sampling rate;
  * scores are bit-identical with tracing on or off;
  * a sharded refresh stitches its per-site root summaries under one
    refresh trace;
  * the Chrome trace-event export is valid per ``benchmarks/
    check_trace.py`` (well-formed, monotone ts, every parent exists);
  * the paper-grounded outlier-rate monitor raises an ``Alert`` on a
    drifting stream, and the staleness / shed-burn monitors fire on
    their thresholds;
  * ``snapshot()`` schema v2 round-trips the validator, and v1
    snapshots are still accepted via the downgrade path.

Tests isolate with ``obs.using_registry`` — which isolates the flight
recorder and monitor hub exactly like metric state — and construct
services *inside* the scope because layers capture handles at
construction.
"""
from __future__ import annotations

import importlib.util
import json
import random
import threading
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.api.config import PipelineConfig, pipeline_config
from repro.api.session import Session
from repro.obs.monitors import MonitorHub, ShedRateMonitor, StalenessMonitor
from repro.obs.tracing import FlightRecorder, TraceSpec
from repro.serve import ServingScheduler, ServingSpec
from repro.stream import QueryResult, ServiceConfig, StreamService
from repro.stream.sharded import ShardedServiceConfig, ShardedStreamService

_BENCH = Path(__file__).resolve().parent.parent / "benchmarks"


def _load_bench(name: str):
    spec = importlib.util.spec_from_file_location(name, _BENCH / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _cluster_data(n=1200, d=4, seed=0):
    rng = np.random.default_rng(seed)
    centers = np.eye(3, d) * 6.0
    x = centers[rng.integers(0, 3, n)] + rng.normal(0, 0.05, (n, d))
    return x.astype(np.float32)


def _fitted_service(d=4, micro_batch=64, seed=0):
    svc = StreamService(ServiceConfig(
        dim=d, k=3, t=20, leaf_size=512, refresh_every=10**6,
        micro_batch=micro_batch, seed=seed))
    svc.ingest(_cluster_data(d=d, seed=seed))
    svc.refresh()
    return svc


# ------------------------------------------------------------ recorder core
def test_root_trace_and_nested_spans_parent_correctly():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        rec = reg.recorder
        with obs.root_trace("req", kind="unit") as ctx:
            assert obs.current_context() == ctx
            with obs.trace("step.inner", site=0):
                pass
        root = rec.spans("req")
        inner = rec.spans("step.inner")
        assert len(root) == 1 and len(inner) == 1
        assert root[0]["span_id"] == ctx.span_id
        assert root[0]["parent_id"] is None
        assert root[0]["attrs"] == {"kind": "unit"}
        assert inner[0]["trace_id"] == ctx.trace_id
        assert inner[0]["parent_id"] == ctx.span_id
        # the dual span still fed the phase histogram
        assert reg.snapshot()["histograms"][
            "phase.step.inner{site=0}"]["count"] == 1
        # outside any trace, obs.trace degrades to histogram-only
        assert obs.current_context() is None


def test_disabled_recorder_is_inert_and_ring_bounds_memory():
    rec = FlightRecorder(False)
    assert rec.new_trace() is None
    assert rec.record_event("x", force=True) is False
    rec = FlightRecorder(True, ring=4)
    ctx = rec.new_trace()
    for i in range(10):
        rec.record_span(f"s{i}", ctx, t0=float(i), t1=float(i) + 0.5,
                        parent_id=None)
    section = rec.snapshot_section()
    assert section["buffered"] == 4
    assert section["recorded"] == 10
    assert section["dropped"] == 6


def test_export_filters_spans_whose_parent_left_the_ring():
    rec = FlightRecorder(True, ring=3)
    ctx = rec.new_trace()
    root_id = rec.record_span("root", ctx, t0=0.0, t1=10.0,
                              span_id=ctx.span_id, parent_id=None)
    for i in range(4):   # evicts the root from the 3-slot ring
        rec.record_span(f"child{i}", ctx, t0=1.0 + i, t1=2.0 + i,
                        parent_id=root_id)
    doc = rec.export_chrome()
    assert doc["traceEvents"] == []   # children are orphans: all filtered
    assert doc["otherData"]["orphaned_spans"] == 3
    check_trace = _load_bench("check_trace")
    # an export with surviving parentage is validator-clean
    rec2 = FlightRecorder(True)
    ctx2 = rec2.new_trace()
    rid = rec2.record_span("root", ctx2, t0=0.0, t1=10.0,
                           span_id=ctx2.span_id, parent_id=None)
    rec2.record_span("child", ctx2, t0=1.0, t1=2.0, parent_id=rid)
    assert check_trace.validate_trace(rec2.export_chrome()) == []


def test_seeded_sampler_is_deterministic():
    rec_a = FlightRecorder(True, sample_rate=0.5, seed=123)
    rec_b = FlightRecorder(True, sample_rate=0.5, seed=123)
    a = [rec_a.new_trace().sampled for _ in range(200)]
    b = [rec_b.new_trace().sampled for _ in range(200)]
    assert a == b
    # the sampled set is a pure replay of random.Random(seed)
    replay = random.Random(123)
    assert a == [replay.random() < 0.5 for _ in range(200)]
    assert 0 < sum(a) < 200   # actually mixed at 0.5
    # rates 0 and 1 never consult the rng (decision order independent)
    rec1 = FlightRecorder(True, sample_rate=1.0, seed=123)
    rec0 = FlightRecorder(True, sample_rate=0.0, seed=123)
    assert all(rec1.new_trace().sampled for _ in range(10))
    assert not any(rec0.new_trace().sampled for _ in range(10))


def test_trace_spec_validates_and_roundtrips_through_config():
    with pytest.raises(ValueError, match="sample_rate"):
        TraceSpec(sample_rate=1.5)
    with pytest.raises(ValueError, match="ring"):
        TraceSpec(ring=0)
    cfg = pipeline_config(dim=4, k=3, t=30, topology="stream",
                          refresh_every=10**6,
                          tracing=TraceSpec(sample_rate=0.25, seed=7))
    d = cfg.to_dict()
    assert d["tracing"]["sample_rate"] == 0.25
    assert PipelineConfig.from_dict(d) == cfg
    # sugar: bool toggles, float sets the rate
    assert pipeline_config(dim=4, k=3, t=30, tracing=False) \
        .tracing.enabled is False
    assert pipeline_config(dim=4, k=3, t=30, tracing=0.5) \
        .tracing.sample_rate == 0.5
    # no tracing section -> key absent (old artifacts keep loading)
    assert "tracing" not in pipeline_config(dim=4, k=3, t=30).to_dict()


# ------------------------------------------------------------ serve stitch
def test_score_stream_storm_yields_one_stitched_trace_per_ticket(tmp_path):
    n_threads, per_thread = 8, 16
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        sess = Session(pipeline_config(
            dim=4, k=3, t=20, topology="stream", refresh_every=10**6,
            serving={"queue_bound": 256, "shed_policy": "wait"}))
        sess.fit(_cluster_data())
        x = _cluster_data(n=n_threads * per_thread, seed=2)
        results = [None] * n_threads

        def client(i):
            rows = x[i * per_thread:(i + 1) * per_thread]
            results[i] = list(sess.score_stream(rows, tenant=f"t{i}",
                                                timeout=60.0))

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        sess.close()
        assert all(isinstance(r, QueryResult)
                   for got in results for r in got)

        rec = reg.recorder
        reqs = rec.spans("serve.request")
        # exactly one trace per submitted row, each rooted at its request
        assert len(reqs) == n_threads * per_thread
        assert len({s["trace_id"] for s in reqs}) == len(reqs)
        assert len({s["attrs"]["request_id"] for s in reqs}) == len(reqs)
        by_trace = {s["trace_id"]: s for s in reqs}
        for name in ("serve.admission", "serve.queue_wait", "serve.tick"):
            spans = rec.spans(name)
            assert len(spans) == len(reqs), name
            for s in spans:
                root = by_trace[s["trace_id"]]
                assert s["parent_id"] == root["span_id"]
                assert root["t0"] <= s["t0"] <= s["t1"] <= root["t1"]
        # each tick's primary trace absorbed the engine-side spans
        fused = rec.spans("score.fused")
        assert fused and all(f["trace_id"] in by_trace for f in fused)

        # the export is a valid Chrome trace per the CI validator
        check_trace = _load_bench("check_trace")
        doc = rec.export_chrome()
        assert check_trace.validate_trace(doc) == []
        assert check_trace.check_required(
            doc, ["serve.request", "serve.queue_wait", "serve.tick",
                  "score.fused"]) == []
        # and Session.dump_trace writes the same thing, loadable from disk
        out = tmp_path / "trace.json"
        sess.dump_trace(out)
        assert check_trace.validate_trace(
            json.loads(out.read_text())) == []
        jl = tmp_path / "trace.jsonl"
        sess.dump_trace(jl, fmt="jsonl")
        lines = [json.loads(line) for line in
                 jl.read_text().splitlines()]
        assert lines and all("ts" in r and "dur_s" in r for r in lines
                             if r["kind"] == "span")


def test_sample_rate_zero_records_only_forced_shed_events():
    rec = FlightRecorder(True, sample_rate=0.0)
    with obs.using_registry(obs.MetricsRegistry(recorder=rec)):
        svc = _fitted_service()
        spec = ServingSpec(queue_bound=8, batch_window_ms=0.0)
        sched = ServingScheduler(svc, spec, autostart=False)
        tickets = sched.submit(_cluster_data(n=20, seed=3), tenant="noisy")
        shed = [t for t in tickets if t.shed]
        assert len(shed) == 12
        events = rec.events("serve.shed")
        assert len(events) == 12
        for ev in events:
            assert ev["attrs"]["tenant"] == "noisy"
            assert ev["attrs"]["queue_depth"] >= spec.queue_bound
            assert "request_id" in ev["attrs"]
        # shed lifecycles force-record their request root too...
        shed_reqs = rec.spans("serve.request")
        assert len(shed_reqs) == 12
        assert all(s["status"] == "shed" for s in shed_reqs)
        sched.start()
        assert sched.flush(timeout=60.0)
        sched.close()
        # ...but successfully served, unsampled requests record nothing
        assert len(rec.spans("serve.request")) == 12
        assert rec.spans("serve.tick") == []
        assert rec.spans("score.fused") == []


def test_worker_error_is_force_recorded_with_context():
    rec = FlightRecorder(True, sample_rate=0.0)   # force paths only
    with obs.using_registry(obs.MetricsRegistry(recorder=rec)):
        svc = _fitted_service()
        sched = ServingScheduler(
            svc, ServingSpec(queue_bound=64, batch_window_ms=0.0),
            autostart=False)
        tickets = sched.submit(_cluster_data(n=4, seed=4), tenant="t0")

        def boom(rows):
            raise RuntimeError("poisoned tick")
        svc.submit = boom
        sched.start()
        for t in tickets:
            with pytest.raises(RuntimeError, match="poisoned tick"):
                t.result(timeout=30.0)
        sched.close()
        events = rec.events("serve.worker_error")
        assert len(events) >= 1
        assert events[0]["attrs"]["error"] == "RuntimeError"
        assert events[0]["attrs"]["tenants"] == ["t0"]
        errs = [s for s in rec.spans("serve.request")
                if s["status"] == "error"]
        assert len(errs) == len(tickets)


def test_scores_bit_identical_with_tracing_on_and_off():
    q = _cluster_data(n=256, seed=5)
    with obs.using_registry(obs.MetricsRegistry()):
        svc = _fitted_service()
        assert obs.tracing_enabled()
        a = svc.score(q)
        obs.set_tracing_enabled(False)
        b = svc.score(q)
        obs.set_tracing_enabled(True)
        c = svc.score(q)
    for other in (b, c):
        assert [r.outlier_score for r in a] == \
            [r.outlier_score for r in other]
        assert [(r.center, r.distance, r.is_outlier) for r in a] == \
            [(r.center, r.distance, r.is_outlier) for r in other]


# ------------------------------------------------------------ refresh stitch
def test_sharded_refresh_stitches_site_roots_under_one_trace():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        cfg = ShardedServiceConfig(
            dim=4, k=3, t=8, n_sites=3, leaf_size=64, refresh_every=10**6,
            micro_batch=32, second_iters=5, seed=0)
        svc = ShardedStreamService(cfg)
        svc.ingest(_cluster_data(n=600, seed=6))
        svc.refresh()
        rec = reg.recorder
        roots = rec.spans("refresh")
        assert len(roots) == 1
        tid = roots[0]["trace_id"]
        sites = rec.spans("refresh.site_root")
        assert len(sites) == cfg.n_sites
        assert {s["attrs"]["site"] for s in sites} == set(range(cfg.n_sites))
        assert all(s["trace_id"] == tid for s in sites)
        for name in ("refresh.gather", "refresh.fit", "refresh.install"):
            got = rec.spans(name)
            assert got and all(s["trace_id"] == tid for s in got), name
        check_trace = _load_bench("check_trace")
        assert check_trace.validate_trace(rec.export_chrome()) == []


def test_async_refresh_carries_trace_across_fit_worker():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        svc = _fitted_service()
        svc.ingest(_cluster_data(n=400, seed=7))
        before = len(reg.recorder.spans("refresh"))
        svc.refresh(blocking=False)
        svc.join_refresh()
        roots = reg.recorder.spans("refresh")
        assert len(roots) == before + 1
        tid = roots[-1]["trace_id"]
        fits = [s for s in reg.recorder.spans("refresh.fit")
                if s["trace_id"] == tid]
        installs = [s for s in reg.recorder.spans("refresh.install")
                    if s["trace_id"] == tid]
        assert fits and installs   # worker thread + poller both stitched


# ------------------------------------------------------------ monitors
def test_outlier_rate_monitor_alerts_on_drifting_stream():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        svc = _fitted_service()
        # healthy traffic: no drift alert
        svc.score(_cluster_data(n=128, seed=8))
        names = [a["name"] for a in reg.snapshot()["alerts"]]
        assert "outlier_rate_high" not in names
        # drifted traffic: every query lands far from every center
        far = np.full((128, 4), 100.0, np.float32) \
            + np.random.default_rng(9).normal(0, 0.1, (128, 4)).astype(
                np.float32)
        svc.score(far)
        alerts = reg.snapshot()["alerts"]
        drift = [a for a in alerts if a["name"] == "outlier_rate_high"]
        assert len(drift) == 1
        assert drift[0]["severity"] == "warn"
        assert drift[0]["labels"] == {"topology": "stream"}
        assert drift[0]["value"] > drift[0]["threshold"]


def test_staleness_monitor_fires_past_slo():
    mon = StalenessMonitor(slo_s=0.5)
    assert mon.evaluate(()) == []          # no source wired yet
    mon.set_source(lambda: 0.2)
    assert mon.evaluate(()) == []          # fresh
    mon.set_source(lambda: 3.0)
    (alert,) = mon.evaluate((("topology", "stream"),))
    assert alert.name == "model_staleness"
    assert alert.value == 3.0 and alert.threshold == 0.5
    mon.set_source(lambda: (_ for _ in ()).throw(RuntimeError()))
    assert mon.evaluate(()) == []          # a broken source never pages


def test_shed_rate_monitor_closed_form_matches_per_event():
    batched = ShedRateMonitor(alpha=0.05, burn_max=0.1, min_events=1)
    stepwise = ShedRateMonitor(alpha=0.05, burn_max=0.1, min_events=1)
    batched.observe(3, 2)
    for _ in range(3):
        stepwise.observe(1, 0)
    for _ in range(2):
        stepwise.observe(0, 1)
    assert batched._ewma == pytest.approx(stepwise._ewma, rel=1e-12)
    burning = ShedRateMonitor(alpha=0.05, burn_max=0.1, min_events=4)
    burning.observe(0, 50)
    (alert,) = burning.evaluate(())
    assert alert.name == "shed_burn" and alert.value > 0.9


def test_scheduler_feeds_shed_burn_monitor():
    hub = MonitorHub(shed_min_events=4, shed_burn_max=0.1, shed_alpha=0.3)
    with obs.using_registry(obs.MetricsRegistry(monitors=hub)) as reg:
        svc = _fitted_service()
        sched = ServingScheduler(
            svc, ServingSpec(queue_bound=4, batch_window_ms=0.0),
            autostart=False)
        sched.submit(_cluster_data(n=40, seed=10))   # 4 admitted, 36 shed
        sched.start()
        sched.flush(timeout=60.0)
        sched.close()
        burn = [a for a in reg.snapshot()["alerts"]
                if a["name"] == "shed_burn"]
        assert len(burn) == 1


# ------------------------------------------------------------ snapshot schema
def test_snapshot_v2_passes_validator_and_v1_still_accepted():
    checker = _load_bench("check_obs_snapshot")
    schema = json.loads((_BENCH / "obs_schema.json").read_text())
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        reg.counter("c").inc()
        with obs.root_trace("r"):
            pass
        snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["version"] == 2
    assert checker.validate(snap, schema) == []
    assert checker.semantic_checks(snap) == []
    # a malformed alert entry is caught by the items walker
    bad = dict(snap)
    bad["alerts"] = [{"name": "x"}]
    assert any("alerts[0]" in e for e in checker.validate(bad, schema))
    # legacy v1 snapshot: rejected by v2 schema, accepted after downgrade
    v1 = {k: v for k, v in snap.items() if k not in ("alerts", "trace")}
    v1["version"] = 1
    assert checker.validate(v1, schema) != []
    assert checker.validate(v1, checker.downgrade_schema_to_v1(schema)) == []
