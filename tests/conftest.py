"""Deterministic test environment.

JAX randomness in this repo is explicit (every algorithm takes a key), but
helpers and tests also use the *implicit* numpy / python RNGs.  Seed those
per-test so ordering and -k selections cannot change outcomes, and pin the
JAX PRNG implementation so key streams stay stable across jax upgrades
that might flip the default.
"""
import os
import random

# must be set before jax initializes — conftest imports precede test modules
os.environ.setdefault("JAX_DEFAULT_PRNG_IMPL", "threefry2x32")

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_host_rngs():
    random.seed(0)
    np.random.seed(0)
    yield
