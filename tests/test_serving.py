"""Async serving scheduler: admission control, batching, bit-identity.

Coverage demanded by the subsystem's correctness argument (see
repro/serve/scheduler.py):
  * the bounded queue never exceeds ``queue_bound``, even under a
    many-thread submission storm;
  * a shed is a *typed result* (:class:`ShedReject` with a reason), never
    a worker exception, and the ``wait`` policy sheds nothing — it blocks
    submitters until space frees;
  * ``tenant_quota`` caps one tenant's share of the queue without
    touching other tenants' admission;
  * scores through the concurrent path are bit-identical to sequential
    ``submit``+``drain`` on the same engine (the padded static-shape
    micro-batch makes every row independent of its tick's composition);
  * a poison request fails only its own tick's tickets (re-raised at
    ``result()`` on the caller) and the worker loop survives;
  * the ``Session`` facade front door: ``score_stream`` matches
    ``score`` bitwise, the scheduler's series land in ``repro.obs``, and
    the synchronous verbs keep working while serving is attached;
  * ``ServingSpec`` validates its knobs and round-trips through
    ``PipelineConfig`` serialization.
"""
import threading

import numpy as np
import pytest

from repro.api.config import PipelineConfig, pipeline_config
from repro.api.session import Session
from repro.serve import (ScoreTicket, ServingScheduler, ServingSpec,
                         ShedReject)
from repro.stream import QueryResult, ServiceConfig, StreamService


def _cluster_data(n=1200, d=4, seed=0):
    rng = np.random.default_rng(seed)
    centers = np.eye(3, d) * 6.0
    x = centers[rng.integers(0, 3, n)] + rng.normal(0, 0.05, (n, d))
    return x.astype(np.float32)


def _fitted_service(d=4, micro_batch=64, seed=0):
    svc = StreamService(ServiceConfig(
        dim=d, k=3, t=20, leaf_size=512, refresh_every=10**6,
        micro_batch=micro_batch, seed=seed))
    svc.ingest(_cluster_data(d=d, seed=seed))
    svc.refresh()
    return svc


# ------------------------------------------------------------ spec + config
def test_spec_validates_knobs():
    assert ServingSpec().shed_policy == "shed"
    with pytest.raises(ValueError, match="queue_bound"):
        ServingSpec(queue_bound=0)
    with pytest.raises(ValueError, match="shed_policy"):
        ServingSpec(shed_policy="drop")
    with pytest.raises(ValueError, match="batch_window_ms"):
        ServingSpec(batch_window_ms=-1)
    with pytest.raises(ValueError, match="tenant_quota"):
        ServingSpec(tenant_quota=0)
    with pytest.raises(ValueError, match="cannot exceed"):
        ServingSpec(queue_bound=8, tenant_quota=9)
    # ints are accepted for the window but normalized to float (JSON round-trip)
    assert ServingSpec(batch_window_ms=3).batch_window_ms == 3.0


def test_serving_spec_roundtrips_through_pipeline_config():
    cfg = pipeline_config(
        dim=4, k=3, t=30, topology="stream", refresh_every=10**6,
        serving=ServingSpec(queue_bound=64, shed_policy="wait",
                            tenant_quota=16))
    d = cfg.to_dict()
    assert d["serving"]["queue_bound"] == 64
    assert PipelineConfig.from_dict(d) == cfg
    # dict and bare-policy-name sugar both resolve to a full spec
    assert pipeline_config(dim=4, k=3, t=30,
                           serving={"queue_bound": 8}).serving.queue_bound == 8
    assert pipeline_config(dim=4, k=3, t=30,
                           serving="wait").serving.shed_policy == "wait"
    with pytest.raises(ValueError, match="shed policy"):
        pipeline_config(dim=4, k=3, t=30, serving="nope")
    # a config without a serving section serializes without the key —
    # pre-serving artifacts keep loading and old byte-level dumps hold
    assert "serving" not in pipeline_config(dim=4, k=3, t=30).to_dict()


# ------------------------------------------------------------ admission
def test_bounded_queue_never_exceeds_cap_under_thread_storm():
    """12 threads hammer a stopped scheduler: the queue's high-water mark
    must respect ``queue_bound`` and the excess must come back as typed
    sheds — then, once the worker starts, everything admitted completes."""
    svc = _fitted_service()
    spec = ServingSpec(queue_bound=50, batch_window_ms=0.0)
    sched = ServingScheduler(svc, spec, autostart=False)
    x = _cluster_data(n=400, seed=1)
    all_tickets = []
    lock = threading.Lock()

    def storm(i):
        rows = x[i * 30:(i + 1) * 30]
        got = sched.submit(rows, tenant=f"t{i % 3}")
        with lock:
            all_tickets.extend(got)

    threads = [threading.Thread(target=storm, args=(i,)) for i in range(12)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert sched.peak_depth <= spec.queue_bound
    assert sched.queue_depth == spec.queue_bound  # storm >> bound: full
    shed = [t for t in all_tickets if t.shed]
    assert len(shed) == len(all_tickets) - spec.queue_bound
    assert all(t.result().reason == "queue_full" for t in shed)
    sched.start()
    assert sched.flush(timeout=60.0)
    for t in all_tickets:
        res = t.result(timeout=10.0)
        assert isinstance(res, (QueryResult, ShedReject))
    sched.close()


def test_shed_is_a_typed_result_not_an_exception():
    svc = _fitted_service()
    sched = ServingScheduler(svc, ServingSpec(queue_bound=4),
                             autostart=False)
    x = _cluster_data(n=10, seed=2)
    tickets = sched.submit(x)
    admitted = [t for t in tickets if not t.shed]
    rejected = [t for t in tickets if t.shed]
    assert len(admitted) == 4 and len(rejected) == 6
    for t in rejected:
        r = t.result()              # returns, never raises
        assert isinstance(r, ShedReject)
        assert r.reason == "queue_full" and r.tenant == "default"
        assert t.done() and t.latency_s is not None
    sched.close()
    # after close every admitted-but-unscored request resolves as shutdown
    for t in admitted:
        r = t.result(timeout=1.0)
        assert isinstance(r, ShedReject) and r.reason == "shutdown"
    # and new submissions shed immediately as shutdown
    post = sched.submit(x[:1])
    assert post[0].result().reason == "shutdown"


def test_wait_policy_blocks_submitters_and_sheds_nothing():
    svc = _fitted_service(micro_batch=32)
    sched = ServingScheduler(
        svc, ServingSpec(queue_bound=16, shed_policy="wait",
                         batch_window_ms=0.5))
    x = _cluster_data(n=600, seed=3)
    results_per_thread = {}

    def client(ci):
        tickets = sched.submit(x[ci * 150:(ci + 1) * 150])
        results_per_thread[ci] = [t.result(timeout=60.0) for t in tickets]

    threads = [threading.Thread(target=client, args=(ci,)) for ci in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert sched.peak_depth <= 16
    all_res = [r for rs in results_per_thread.values() for r in rs]
    assert len(all_res) == 600
    assert all(isinstance(r, QueryResult) for r in all_res)  # zero sheds
    sched.close()


def test_tenant_quota_caps_one_tenant_not_the_others():
    svc = _fitted_service()
    sched = ServingScheduler(
        svc, ServingSpec(queue_bound=64, tenant_quota=8), autostart=False)
    x = _cluster_data(n=40, seed=4)
    noisy = sched.submit(x[:20], tenant="noisy")
    assert sum(not t.shed for t in noisy) == 8
    assert all(t.result().reason == "tenant_quota"
               for t in noisy if t.shed)
    # the quota bound the noisy tenant, not the queue: quiet still enters
    quiet = sched.submit(x[20:28], tenant="quiet")
    assert all(not t.shed for t in quiet)
    sched.close()


# ------------------------------------------------------------ bit identity
def test_concurrent_scores_bit_identical_to_sequential():
    """The acceptance criterion: rows scored through the concurrent
    scheduler (interleaved across threads, arbitrary tick composition)
    equal sequential submit+drain on the same engine, bitwise."""
    svc = _fitted_service(micro_batch=32)
    x = _cluster_data(n=320, seed=5)
    sequential = []
    for i in range(0, len(x), 32):
        svc.submit(x[i:i + 32])
        sequential.extend(svc.drain())

    sched = ServingScheduler(svc, ServingSpec(queue_bound=4096,
                                              batch_window_ms=1.0))
    slots = [None] * 8

    def client(ci):
        rows = x[ci * 40:(ci + 1) * 40]
        slots[ci] = [t.result(timeout=60.0) for t in sched.submit(rows)]

    threads = [threading.Thread(target=client, args=(ci,)) for ci in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    sched.close()
    concurrent = [r for rs in slots for r in rs]
    assert len(concurrent) == len(sequential) == 320
    for a, b in zip(sequential, concurrent):
        assert a.center == b.center
        assert a.distance == b.distance            # bitwise, not approx
        assert a.outlier_score == b.outlier_score
        assert a.is_outlier == b.is_outlier


def test_fused_score_bit_identical_to_composed():
    """The read path now scores each micro-batch through ONE fused kernel
    dispatch (``repro.kernels.score``); for the non-quantized backends it
    must return bitwise what the composed min_argmin + divide jit it
    replaced would have — fusing the serving hot path is a pure perf
    change, never a numerics change."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels.pdist.ops import min_argmin

    @functools.partial(jax.jit, static_argnames=("metric", "policy"))
    def composed_batch(xb, centers, threshold, *, metric, policy):
        # verbatim: the pre-fusion serving _score_batch
        dist, amin = min_argmin(xb, centers, metric=metric, policy=policy)
        return dist, amin, dist / jnp.maximum(threshold, 1e-30)

    cfg = pipeline_config(
        dim=4, k=3, t=30, topology="stream", leaf_size=512,
        refresh_every=10**6, micro_batch=64,
        serving={"queue_bound": 256, "batch_window_ms": 1.0}, seed=0)
    x = _cluster_data(n=100, seed=12)       # ragged last micro-batch
    with Session(cfg) as session:
        session.fit(_cluster_data(n=900, seed=12))
        model = session.model
        svc_cfg = session.engine.cfg
        fused = list(session.score_stream(x, timeout=60.0))
    assert len(fused) == len(x)
    mb, j = svc_cfg.micro_batch, 0
    for i in range(0, len(x), mb):
        chunk = x[i:i + mb]
        xb = np.zeros((mb, svc_cfg.dim), np.float32)
        xb[:len(chunk)] = chunk
        dist, amin, score = composed_batch(
            jnp.asarray(xb), model.centers, model.threshold,
            metric=svc_cfg.metric, policy=svc_cfg.policy)
        dist, amin, score = (np.asarray(a) for a in (dist, amin, score))
        for r in range(len(chunk)):
            got = fused[j]
            assert got.center == int(amin[r])
            assert got.distance == float(dist[r])         # bitwise
            assert got.outlier_score == float(score[r])   # bitwise
            assert got.is_outlier == bool(score[r] > 1.0)
            j += 1


# ------------------------------------------------------------ worker errors
def test_worker_error_reraised_on_caller_and_loop_survives():
    """Scoring before any model exists fails inside the worker tick; the
    error must surface at ``result()`` on the caller's thread, the failed
    tick must leave no stale rows in the engine's read queue, and the
    worker must stay alive to serve the next (valid) tick correctly."""
    svc = StreamService(ServiceConfig(
        dim=4, k=3, t=20, leaf_size=512, refresh_every=10**6,
        micro_batch=64, seed=0))
    sched = ServingScheduler(svc, ServingSpec(batch_window_ms=0.0))
    x = _cluster_data(n=8, seed=6)
    bad = sched.submit(x)
    with pytest.raises(RuntimeError):
        bad[0].result(timeout=30.0)
    assert all(t.done() for t in bad)      # the whole tick failed together
    assert len(svc._queue) == 0            # ...and left no stale rows behind
    # heal the engine; the same scheduler/worker must now serve fine —
    # with *different* rows than the failed tick, so leftover stale rows
    # would surface as wrong scores rather than coincidentally-equal ones
    svc.ingest(_cluster_data(seed=0))
    svc.refresh()
    y = _cluster_data(n=8, seed=9)
    good = sched.submit(y)
    results = [t.result(timeout=30.0) for t in good]
    assert all(isinstance(r, QueryResult) for r in results)
    sched.close()
    # post-close direct scoring of the same rows is the reference
    for a, b in zip(svc.score(y), results):
        assert (a.center, a.distance, a.outlier_score) \
            == (b.center, b.distance, b.outlier_score)

    # validation errors raise at submit() on the caller, pre-admission
    svc2 = _fitted_service()
    with ServingScheduler(svc2) as s2:
        with pytest.raises(ValueError):
            s2.submit(np.zeros((4, 9), np.float32))   # wrong dim


def test_queue_depth_gauge_sums_live_schedulers_only():
    """serve.queue_depth is one process-global series, but schedulers come
    and go with Sessions: the gauge must read the sum over *live*
    schedulers, not whichever instance registered its callback last, and a
    closed scheduler must leave the sum."""
    from repro import obs

    svc = _fitted_service()
    s1 = ServingScheduler(svc, ServingSpec(queue_bound=50), autostart=False)
    s2 = ServingScheduler(svc, ServingSpec(queue_bound=50), autostart=False)
    x = _cluster_data(n=10, seed=11)
    s1.submit(x[:4])
    s2.submit(x[4:])
    g = obs.gauge("serve.queue_depth")
    assert g.get() == 10                 # both live schedulers counted
    s2.close()
    assert g.get() == 4                  # s2 gone; s1's depth still reported
    s1.close()
    assert g.get() == 0


# ------------------------------------------------------------ session facade
def test_session_score_stream_matches_score_and_emits_metrics():
    from repro import obs

    cfg = pipeline_config(
        dim=4, k=3, t=30, topology="stream", leaf_size=512,
        refresh_every=10**6, micro_batch=64,
        serving={"queue_bound": 256, "batch_window_ms": 1.0}, seed=0)
    x = _cluster_data(n=900, seed=7)
    with Session(cfg) as session:
        session.fit(x)
        sync = session.score(x[:100])
        conc = list(session.score_stream(x[:100], timeout=60.0))
        assert len(conc) == 100
        for a, b in zip(sync, conc):
            assert (a.center, a.distance, a.outlier_score) \
                == (b.center, b.distance, b.outlier_score)
        # the scheduler came from the config's serving section
        assert session.serving.spec.queue_bound == 256
        # synchronous verbs still work while serving is attached (they
        # route through the scheduler's engine lock)
        session.ingest(x[:64])
        assert len(session.score(x[:8])) == 8
        tickets = session.submit_stream(x[:16], tenant="acme")
        assert all(isinstance(t, ScoreTicket) for t in tickets)
        assert all(isinstance(t.result(timeout=60.0), QueryResult)
                   for t in tickets)
        snap = obs.snapshot()
    keys = [k for sec in ("counters", "gauges", "histograms")
            for k in snap.get(sec, {})]
    for want in ("serve.queue_depth", "serve.ticks",
                 "serve.batch_occupancy",
                 "serve.admitted{tenant=acme}",
                 "serve.completed{tenant=default}",
                 "serve.latency{tenant=default,topology=scheduler}"):
        assert any(k == want or k.startswith(want) for k in keys), want
    # the context manager closed serving; the session still scores
    assert session.serving is None
    assert len(session.score(x[:4])) == 4
    session.close()                                  # idempotent


def test_session_serve_attach_is_thread_safe():
    """Concurrent first ``serve()`` calls must attach exactly one
    scheduler — two would race their worker ticks on the shared engine."""
    cfg = pipeline_config(
        dim=4, k=3, t=30, topology="stream", leaf_size=512,
        refresh_every=10**6, micro_batch=64, seed=0)
    with Session(cfg) as session:
        session.fit(_cluster_data(seed=0))
        n = 8
        barrier = threading.Barrier(n)
        got = [None] * n

        def attach(i):
            barrier.wait()
            got[i] = session.serve()

        threads = [threading.Thread(target=attach, args=(i,))
                   for i in range(n)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert all(s is got[0] for s in got)
        assert session.serving is got[0]


# ------------------------------------------------------------ fairness (slow)
@pytest.mark.slow
def test_tenant_fairness_under_storm_with_quota():
    """One tenant bursting past its quota, one staying small, quota = half
    the queue: the noisy tenant alone absorbs every shed (all typed
    ``tenant_quota``) and the quiet tenant completes everything — it can
    never be crowded out, because noisy's queue share is capped at 32 and
    quiet's worst-case demand (2 threads x 8 rows) always fits in the
    remaining 32."""
    svc = _fitted_service(micro_batch=32)
    sched = ServingScheduler(
        svc, ServingSpec(queue_bound=64, tenant_quota=32,
                         batch_window_ms=0.5))
    x = _cluster_data(n=4000, seed=8)
    done = {"noisy": 0, "quiet": 0}
    shed_reasons = []
    lock = threading.Lock()

    def client(tenant, rows, burst):
        finished, reasons = 0, []
        for i in range(0, len(rows), burst):
            for t in sched.submit(rows[i:i + burst], tenant=tenant):
                r = t.result(timeout=60.0)
                if isinstance(r, ShedReject):
                    reasons.append((r.tenant, r.reason))
                else:
                    finished += 1
        with lock:
            done[tenant] += finished
            shed_reasons.extend(reasons)

    threads = ([threading.Thread(target=client,
                                 args=("noisy", x[:1600], 40))
                for _ in range(2)]
               + [threading.Thread(target=client,
                                   args=("quiet", x[:400], 8))
                  for _ in range(2)])
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    sched.close()
    # the quiet tenant is never starved: every one of its rows completed
    assert done["quiet"] == 800
    # every shed hit the noisy tenant, and via its quota — never the
    # shared queue bound (noisy<=32 + quiet<=16 can't fill 64)
    assert all(t == "noisy" and r == "tenant_quota"
               for t, r in shed_reasons), shed_reasons[:5]
    assert done["noisy"] + len(shed_reasons) == 3200
