"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus tie-breaking and padding edge cases."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.dispatch import KernelPolicy
from repro.kernels.pdist.kernel import min_argmin_pallas
from repro.kernels.pdist.ref import min_argmin_ref
from repro.kernels.pdist.ops import min_argmin
from repro.kernels.lloyd.kernel import lloyd_step_pallas
from repro.kernels.lloyd.ref import lloyd_step_ref

SHAPES = [(64, 3, 5), (513, 128, 34), (1000, 37, 18), (1025, 200, 130)]
METRICS = ["l2sq", "l2", "l1"]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pdist_matches_ref(shape, metric, dtype):
    n, m, d = shape
    rng = np.random.default_rng(n + m + d)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    c = jnp.asarray(rng.normal(size=(m, d)), dtype)
    dk, ak = min_argmin_pallas(x, c, metric=metric, interpret=True)
    dr, ar = min_argmin_ref(x.astype(jnp.float32), c.astype(jnp.float32), metric)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=tol, atol=tol)
    if dtype == jnp.float32:
        assert (np.asarray(ak) == np.asarray(ar)).all()
    else:
        # bf16: near-ties may flip the argmin; the chosen center must still be
        # (near-)optimal
        chosen = np.asarray(c.astype(jnp.float32))[np.asarray(ak)]
        xf = np.asarray(x.astype(jnp.float32))
        d_chosen = ((xf - chosen) ** 2).sum(-1)
        if metric == "l2":
            d_chosen = np.sqrt(d_chosen)
        if metric == "l1":
            d_chosen = np.abs(xf - chosen).sum(-1)
        np.testing.assert_allclose(d_chosen, np.asarray(dr), rtol=5e-2, atol=5e-2)


def test_pdist_tie_breaks_to_first_index():
    # duplicate centers: argmin must pick the smallest index, like the oracle
    x = jnp.zeros((8, 4), jnp.float32)
    c = jnp.concatenate([jnp.ones((3, 4)), jnp.ones((130, 4))])  # all identical
    _, ak = min_argmin_pallas(x, c, metric="l2sq", interpret=True)
    assert (np.asarray(ak) == 0).all()


def test_pdist_ops_chunked_equals_full():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1000, 9)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(33, 9)), jnp.float32)
    for metric in METRICS:
        d1, a1 = min_argmin(x, c, metric=metric,
                            policy=KernelPolicy(backend="blocked", block_n=128))
        d2, a2 = min_argmin_ref(x, c, metric)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5, atol=1e-6)
        assert (np.asarray(a1) == np.asarray(a2)).all()


@pytest.mark.parametrize("shape", [(64, 3, 5), (513, 100, 34), (1025, 130, 200)])
@pytest.mark.parametrize("metric", ["l2sq", "l2"])
def test_lloyd_matches_ref(shape, metric):
    n, k, d = shape
    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 3, size=(n,)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    sk, ck, ak, dk = lloyd_step_pallas(x, w, c, metric=metric, interpret=True)
    sr, cr, ar, dr = lloyd_step_ref(x, w, c, metric)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=1e-5, atol=1e-5)
    assert (np.asarray(ak) == np.asarray(ar)).all()
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-5, atol=1e-5)


def test_lloyd_weight_conservation():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(777, 12)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, size=(777,)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(13, 12)), jnp.float32)
    _, counts, _, _ = lloyd_step_pallas(x, w, c, interpret=True)
    np.testing.assert_allclose(float(counts.sum()), float(w.sum()), rtol=1e-5)


# ------------------------------------------------------------ wkv6 kernel
@pytest.mark.parametrize("shape", [(8, 64, 64, 16), (16, 32, 64, 16),
                                   (8, 128, 64, 64)])
def test_wkv_kernel_matches_oracle(shape):
    from repro.kernels.wkv.kernel import wkv_forward_pallas
    from repro.kernels.wkv.ref import wkv_ref
    BH, T, K, c = shape
    rng = np.random.default_rng(BH + T)
    r, k, v = (jnp.asarray(rng.normal(size=(BH, T, K)), jnp.float32)
               for _ in range(3))
    lw = jnp.asarray(-np.exp(rng.uniform(-6, 3, size=(BH, T, K))), jnp.float32)
    u = jnp.asarray(rng.normal(size=(K,)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(BH, K, K)), jnp.float32)
    ok, sk = wkv_forward_pallas(r, k, v, lw, u, s0, chunk=c, interpret=True)
    orf, srf = wkv_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(ok), np.asarray(orf), atol=1e-3)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(srf), atol=1e-3)


def test_wkv_custom_vjp_grads_match_jnp():
    from repro.kernels.wkv.ops import wkv_forward
    from repro.kernels.wkv.ref import wkv_ref
    BH, T, K = 4, 32, 16
    rng = np.random.default_rng(5)
    r, k, v = (jnp.asarray(rng.normal(size=(BH, T, K)), jnp.float32)
               for _ in range(3))
    lw = jnp.asarray(-np.exp(rng.uniform(-4, 1, size=(BH, T, K))), jnp.float32)
    u = jnp.asarray(rng.normal(size=(K,)), jnp.float32)
    s0 = jnp.zeros((BH, K, K), jnp.float32)

    def loss_kernel(r):
        o, _ = wkv_forward(r, k, v, lw, u, s0, 16)
        return (o ** 2).sum()

    def loss_ref(r):
        o, _ = wkv_ref(r, k, v, lw, u, s0)
        return (o ** 2).sum()

    g1 = jax.grad(loss_kernel)(r)
    g2 = jax.grad(loss_ref)(r)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-3)
