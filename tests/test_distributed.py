"""Algorithm 3 (coordinator model): quality, communication accounting,
partition modes; multi-device via an 8-device subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (distributed_cluster, local_budget, simulate_coordinator)
from repro.core.metrics import outlier_scores
from repro.data.synthetic import gauss, partition


def test_local_budget():
    assert local_budget(100, 10, "random") == 20
    assert local_budget(100, 10, "adversarial") == 100
    assert local_budget(5, 100, "random") == 1


def test_simulate_quality_and_comm():
    x, out_ids = gauss(n_centers=20, per_center=500, t=200, sigma=0.1, seed=2)
    parts, gids = partition(x, 5, "random", seed=0, outlier_ids=out_ids)
    res = simulate_coordinator(parts, jax.random.key(0), k=20, t=200)
    conc = np.concatenate(gids)
    sc = outlier_scores(out_ids, conc[res["summary_ids"]], conc[res["outlier_ids"]])
    assert sc.pre_recall >= 0.95
    assert sc.recall >= 0.8 and sc.precision >= 0.8
    # one-round comm == number of summary records
    assert res["comm_records"] == len(res["summary_ids"])


def test_adversarial_partition_larger_budget_still_works():
    x, out_ids = gauss(n_centers=10, per_center=300, t=60, sigma=0.1, seed=4)
    parts, gids = partition(x, 4, "adversarial", seed=0, outlier_ids=out_ids)
    res = simulate_coordinator(parts, jax.random.key(0), k=10, t=60,
                               partition="adversarial")
    conc = np.concatenate(gids)
    sc = outlier_scores(out_ids, conc[res["summary_ids"]], conc[res["outlier_ids"]])
    assert sc.pre_recall >= 0.9  # all outliers on one site must still surface
    assert sc.recall >= 0.7


def test_shardmap_single_device_matches_simulate_quality():
    x, out_ids = gauss(n_centers=10, per_center=400, t=80, sigma=0.1, seed=6)
    mesh = jax.make_mesh((1,), ("sites",))
    res = distributed_cluster(jnp.asarray(x)[None], jax.random.key(0), mesh,
                              k=10, t=80)
    oi = np.asarray(res.outlier_ids)
    oi = oi[oi >= 0]
    si = np.asarray(res.summary_ids)
    sc = outlier_scores(out_ids, si[si >= 0], oi)
    assert sc.pre_recall >= 0.9
    assert sc.recall >= 0.75


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import distributed_cluster
    from repro.core.metrics import outlier_scores
    from repro.data.synthetic import gauss, partition

    x, out_ids = gauss(n_centers=10, per_center=400, t=160, sigma=0.1, seed=1)
    parts, gids = partition(x, 8, "random", seed=3, outlier_ids=out_ids)
    xs = jnp.asarray(np.stack(parts))
    mesh = jax.make_mesh((8,), ("sites",))
    res = distributed_cluster(xs, jax.random.key(0), mesh, k=10, t=160)
    conc = np.concatenate(gids)
    oi = np.asarray(res.outlier_ids); oi = conc[oi[oi >= 0]]
    si = np.asarray(res.summary_ids); si = conc[si[si >= 0]]
    sc = outlier_scores(out_ids, si, oi)
    print(json.dumps({"pre": sc.pre_recall, "rec": sc.recall,
                      "prec": sc.precision, "comm": float(res.comm_records)}))
""")


@pytest.mark.slow
def test_shardmap_eight_sites_subprocess():
    """Real multi-device shard_map run: 8 sites, one all_gather round."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["pre"] >= 0.9
    assert res["rec"] >= 0.75
    assert res["comm"] > 0
