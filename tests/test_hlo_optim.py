"""HLO cost-model unit tests (the roofline's foundation) + optimizer and
gradient-compression numerics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo import analyze
from repro.optim import adamw
from repro.optim.compression import (decode_bf16, decode_int8, encode_bf16,
                                     encode_int8, init_ef)


# ------------------------------------------------------------ hlo analyzer
def test_scan_trip_count_multiplied():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(s, s).compile().as_text()
    a = analyze(txt)
    expect = 2 * 128**3 * 8
    assert abs(a["flops"] - expect) / expect < 0.01


def test_nested_scan_trip_counts():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(s, s).compile().as_text()
    a = analyze(txt)
    expect = 2 * 64**3 * 12
    assert abs(a["flops"] - expect) / expect < 0.02


def test_dot_contracting_dims_flops():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    sa = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    sb = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    txt = jax.jit(f).lower(sa, sb).compile().as_text()
    a = analyze(txt)
    expect = 2 * 4 * 32 * 8 * 16
    assert abs(a["flops"] - expect) / max(expect, 1) < 0.05


def test_collective_parse_and_wire_model():
    # craft an HLO module by hand: 4-way all-reduce of 1MB + all-gather
    txt = """HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p0: f32[262144], p1: f32[1024]) -> f32[262144] {
  %p0 = f32[262144]{0} parameter(0)
  %p1 = f32[1024]{0} parameter(1)
  %ar = f32[262144]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(%p1), replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %out = f32[262144]{0} add(%ar, %ar)
}
"""
    a = analyze(txt)
    c = a["collectives"]
    mb = 262144 * 4
    assert c["all-reduce"]["count"] == 1
    np.testing.assert_allclose(c["all-reduce"]["wire_bytes"],
                               2 * mb * 3 / 4, rtol=1e-6)
    assert c["all-gather"]["count"] == 1
    np.testing.assert_allclose(c["all-gather"]["wire_bytes"],
                               1024 * 4 * 3, rtol=1e-6)  # s*(n-1), n=4


def test_fused_bytes_below_raw():
    def f(x, w):
        y = jnp.tanh(x) * 2 + 1
        z = y @ w
        return jax.nn.relu(z) - 0.5
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(f).lower(s, s).compile().as_text()
    a = analyze(txt)
    assert a["hbm_bytes"] <= a["hbm_bytes_raw"]
    assert a["hbm_bytes"] > 0


# ------------------------------------------------------------ optimizer
def test_adamw_converges_quadratic():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(32,)),
                         jnp.float32)
    params = {"w": jnp.zeros((32,))}
    c = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=10, total_steps=300,
                          weight_decay=0.0)
    st = adamw.init(params, c)
    for _ in range(300):
        g = {"w": params["w"] - target}
        params, st, m = adamw.apply(params, g, st, c)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_adamw_bf16_state_close_to_f32():
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(64,)) * 0.1, jnp.float32)}
    out = {}
    for dt in ("float32", "bfloat16"):
        c = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=0, state_dtype=dt)
        p, st = dict(params), adamw.init(params, c)
        for _ in range(20):
            p, st, _ = adamw.apply(p, g, st, c)
        out[dt] = np.asarray(p["w"])
    np.testing.assert_allclose(out["bfloat16"], out["float32"],
                               rtol=0.02, atol=1e-4)


def test_grad_clip():
    g = {"w": jnp.full((100,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)
    assert float(norm) == pytest.approx(100.0)


# ------------------------------------------------------------ compression
@pytest.mark.parametrize("enc,dec", [(encode_bf16, decode_bf16),
                                     (encode_int8, decode_int8)])
def test_compression_error_feedback_converges(enc, dec):
    """With error feedback, the time-average of decoded grads approaches the
    true gradient (unbiasedness over steps)."""
    rng = np.random.default_rng(2)
    g_true = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    ef = init_ef(g_true)
    acc = jnp.zeros((256,))
    n = 50
    for _ in range(n):
        q, ef = enc(g_true, ef)
        acc = acc + dec(q)["w"]
    mean_err = float(jnp.abs(acc / n - g_true["w"]).max())
    assert mean_err < 0.02
