"""Kernel-dispatch layer: backend registry, policy threading, autotuner.

Parity contract (the acceptance bar for any new backend):
  * ``blocked`` == ``ref`` EXACTLY (backend vs backend, both through the
    registry) wherever the arithmetic order is preserved: the single-block
    shortcut (any metric) and the l1 center-chunking path in
    ``kernels/pdist/ops.py`` (pure adds, same order).  The tiled l2/l2sq
    path reassociates the matmul (XLA tiles a (64, d) block differently
    from the full array), so there the contract is distances within one
    float ulp-scale tolerance and bit-equal argmins;
  * ``pallas`` (interpret mode on CPU) matches ``ref`` within float
    tolerance, with identical argmins away from ties.

Plus: auto selection picks blocked off-TPU, explicit-but-unsupported
backends fall back the way the old inline dispatch did, the process-wide
default policy threads into jitted callers, the autotuner caches its
measured ``block_n`` under ``$REPRO_KERNELS_CACHE``, and the removed
``use_pallas=``/``block_n=`` aliases raise a ``TypeError`` pointing at
``KernelPolicy`` from every public edge.
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.lloyd.ops import lloyd_step
from repro.kernels.lloyd.ref import lloyd_step_ref
from repro.kernels.pdist.ops import min_argmin
from repro.kernels.pdist.ref import min_argmin_ref
from repro.kernels.score.ops import score, score_blocked, score_int8

METRICS = ["l2sq", "l2", "l1"]
# ragged on purpose: nothing divides the tile sizes; the 200-center cases
# exercise the l1 center-chunking scan (mc=64) in the blocked path
RAGGED_SHAPES = [(37, 3, 5), (257, 65, 11), (1001, 200, 18), (130, 129, 3)]


def _data(n, m, d):
    rng = np.random.default_rng(n * 7 + m * 3 + d)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=(n,)), jnp.float32)
    return x, c, w


# ------------------------------------------------------------ parity sweeps
@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("metric", METRICS)
def test_min_argmin_blocked_single_block_equals_ref_exactly(shape, metric):
    n, m, d = shape
    x, c, _ = _data(n, m, d)
    db, ab = min_argmin(x, c, metric=metric,
                        policy=KernelPolicy(backend="blocked",
                                            block_n=max(n, 16384)))
    dr, ar = min_argmin(x, c, metric=metric,
                        policy=KernelPolicy(backend="ref"))
    assert (np.asarray(db) == np.asarray(dr)).all()
    assert (np.asarray(ab) == np.asarray(ar)).all()
    # and the registered ref backend IS the oracle
    do, ao = min_argmin_ref(x, c, metric)
    np.testing.assert_allclose(np.asarray(dr), np.asarray(do),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(ar) == np.asarray(ao)).all()


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("metric", METRICS)
def test_min_argmin_blocked_chunked_equals_ref(shape, metric):
    n, m, d = shape
    x, c, _ = _data(n, m, d)
    # block_n smaller than n: the chunked lax.map path, not the single-block
    # shortcut; m > 64 cases also exercise the l1 center-chunking scan
    db, ab = min_argmin(x, c, metric=metric,
                        policy=KernelPolicy(backend="blocked", block_n=64))
    dr, ar = min_argmin_ref(x, c, metric)
    assert (np.asarray(ab) == np.asarray(ar)).all()
    if metric == "l1":
        # pure adds in the same order: tiling cannot change the bits
        assert (np.asarray(db) == np.asarray(dr)).all()
    else:
        np.testing.assert_allclose(np.asarray(db), np.asarray(dr),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("metric", METRICS)
def test_min_argmin_pallas_interpret_close_to_ref(shape, metric):
    n, m, d = shape
    x, c, _ = _data(n, m, d)
    dp, ap_ = min_argmin(x, c, metric=metric,
                         policy=KernelPolicy(backend="pallas"))
    dr, ar = min_argmin_ref(x, c, metric)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ap_) == np.asarray(ar)).all()


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("metric", METRICS)
def test_lloyd_blocked_equals_ref(shape, metric):
    n, m, d = shape
    x, c, w = _data(n, m, d)
    sb, cb, ab, db = lloyd_step(x, w, c, metric=metric,
                                policy=KernelPolicy(backend="blocked",
                                                    block_n=64))
    sr, cr, ar, dr = lloyd_step_ref(x, w, c, metric)
    assert (np.asarray(ab) == np.asarray(ar)).all()
    if metric == "l1":
        assert (np.asarray(db) == np.asarray(dr)).all()
    else:
        np.testing.assert_allclose(np.asarray(db), np.asarray(dr),
                                   rtol=1e-5, atol=1e-5)
    # accumulators: one-hot matmul vs scatter-add differ only in summation
    # order
    np.testing.assert_allclose(np.asarray(sb), np.asarray(sr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cb), np.asarray(cr),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("metric", ["l2sq", "l2"])
def test_lloyd_pallas_interpret_close_to_ref(metric):
    x, c, w = _data(513, 37, 9)
    sp, cp, ap_, dp = lloyd_step(x, w, c, metric=metric,
                                 policy=KernelPolicy(backend="pallas"))
    sr, cr, ar, dr = lloyd_step_ref(x, w, c, metric)
    assert (np.asarray(ap_) == np.asarray(ar)).all()
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(cr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ registry rules
def test_auto_selects_blocked_off_tpu():
    assert jax.default_backend() != "tpu", "test assumes a CPU/GPU host"
    for op in ("min_argmin", "lloyd_step", "score"):
        reg = dispatch.select_backend(op, KernelPolicy(), metric="l2sq",
                                      n=100, m=10, d=4)
        assert reg.name == "blocked"


def test_auto_on_tpu_prefers_pallas():
    reg = dispatch.select_backend("min_argmin", KernelPolicy(),
                                  metric="l2sq", n=100, m=10, d=4,
                                  platform="tpu")
    assert reg.name == "pallas"
    # ... but the lloyd kernel has no l1 path even on TPU
    reg = dispatch.select_backend("lloyd_step", KernelPolicy(),
                                  metric="l1", n=100, m=10, d=4,
                                  platform="tpu")
    assert reg.name == "blocked"


def test_explicit_unsupported_backend_falls_back():
    # the old `if use_pallas and metric in ("l2sq", "l2")` semantics: an l1
    # lloyd call under an explicit pallas policy silently uses the best
    # supported backend instead of erroring
    reg = dispatch.select_backend("lloyd_step", KernelPolicy(backend="pallas"),
                                  metric="l1", n=100, m=10, d=4)
    assert reg.name == "blocked"
    x, c, w = _data(64, 70, 5)   # m > 64: center-chunking path
    s1, c1, a1, d1 = lloyd_step(x, w, c, metric="l1",
                                policy=KernelPolicy(backend="pallas"))
    sr, cr, ar, dr = lloyd_step_ref(x, w, c, "l1")
    assert (np.asarray(a1) == np.asarray(ar)).all()


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        KernelPolicy(backend="cuda")


def test_default_policy_threads_into_jitted_callers():
    from repro.core.summary import summary_outliers
    x = jnp.asarray(np.random.default_rng(0).normal(size=(400, 4)), jnp.float32)
    key = jax.random.key(3)
    base = summary_outliers(x, key, k=4, t=6)
    with dispatch.using_policy(KernelPolicy(backend="ref")):
        via_default = summary_outliers(x, key, k=4, t=6)
    # same sampling path, backend swap only: identical summaries
    assert (np.asarray(base.indices) == np.asarray(via_default.indices)).all()
    np.testing.assert_allclose(np.asarray(base.weights),
                               np.asarray(via_default.weights))
    # and the context manager restored the previous default
    assert dispatch.get_default_policy() == KernelPolicy()


def test_default_policy_not_frozen_by_jit_cache(monkeypatch):
    """Jitted entry points must re-resolve the process default per call: a
    policy=None static argument would freeze the first trace's backend into
    the compile cache (regression test for exactly that bug)."""
    from repro.core.rand_summary import rand_summary
    x = jnp.asarray(np.random.default_rng(2).normal(size=(128, 3)), jnp.float32)
    key = jax.random.key(0)
    rand_summary(x, key, budget=4)   # populate the jit cache under "blocked"

    calls = []
    regs = dispatch.registered_backends("min_argmin")
    orig = regs["ref"]

    def spy_impl(*args, **kw):
        calls.append("ref")
        return orig.impl(*args, **kw)

    monkeypatch.setitem(regs, "ref", orig._replace(impl=spy_impl))
    with dispatch.using_policy(KernelPolicy(backend="ref")):
        rand_summary(x, key, budget=4)   # same shapes: would cache-hit if stale
    assert calls, "default-policy switch ignored: jit cache served 'blocked'"


def test_configs_capture_process_default_at_construction():
    from repro.stream import ServiceConfig, ShardedServiceConfig, TreeConfig
    tuned = KernelPolicy(backend="ref", block_n=123)
    with dispatch.using_policy(tuned):
        svc_cfg = ServiceConfig(dim=3, k=4, t=10)
        sh_cfg = ShardedServiceConfig(dim=3, k=4, t=10, n_sites=2)
        tr_cfg = TreeConfig(dim=3, k=4, t=10)
    assert svc_cfg.policy == tuned
    assert svc_cfg.tree_config().policy == tuned
    assert sh_cfg.policy == tuned and sh_cfg.site_tree_config().policy == tuned
    assert tr_cfg.policy == tuned
    # an explicit policy always wins over the ambient default
    with dispatch.using_policy(tuned):
        explicit = ServiceConfig(dim=3, k=4, t=10,
                                 policy=KernelPolicy(backend="blocked"))
    assert explicit.policy == KernelPolicy(backend="blocked")


# ------------------------------------------------------------ autotuner
def test_autotune_writes_and_reuses_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS_CACHE", str(tmp_path))
    dispatch.clear_autotune_cache()
    try:
        bn = dispatch.autotune_block_n("min_argmin", "blocked",
                                       metric="l2sq", n=4096, m=16, d=4)
        assert bn in (4096, 8192, 16384, 32768, 65536)
        cache_file = tmp_path / "autotune.json"
        assert cache_file.exists()
        payload = json.loads(cache_file.read_text())
        (key,) = payload.keys()
        assert "min_argmin/blocked" in key
        assert payload[key]["block_n"] == bn
        assert payload[key]["timings_us"]
        # second call (same shape bucket): served from cache, so poisoning
        # the cached value must be reflected verbatim
        payload[key]["block_n"] = 12345
        cache_file.write_text(json.dumps(payload))
        dispatch.clear_autotune_cache()
        assert dispatch.autotune_block_n("min_argmin", "blocked",
                                         metric="l2sq", n=4000, m=16,
                                         d=4) == 12345
    finally:
        dispatch.clear_autotune_cache()


def test_autotune_policy_resolves_block_n(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS_CACHE", str(tmp_path))
    dispatch.clear_autotune_cache()
    try:
        # candidates above the shape bucket are clamped to it (no point
        # tiling wider than the data), so only {4096, 8192} compete here
        reg, bn = dispatch.resolve("min_argmin",
                                   KernelPolicy(autotune=True),
                                   metric="l2sq", n=5000, m=8, d=4)
        assert reg.name == "blocked" and bn in (4096, 8192)
        # an explicit block_n always wins over the tuner
        _, bn2 = dispatch.resolve("min_argmin",
                                  KernelPolicy(autotune=True, block_n=777),
                                  metric="l2sq", n=5000, m=8, d=4)
        assert bn2 == 777
    finally:
        dispatch.clear_autotune_cache()


# ------------------------------------------------------------ fused score op
_THR = 0.7  # scores land on both sides of the outlier boundary


def _score_ref(x, c, metric):
    """Oracle through the registry's ref backend (the composed path)."""
    return score(x, c, jnp.float32(_THR), metric=metric,
                 policy=KernelPolicy(backend="ref"))


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("metric", METRICS + ["cosine"])
def test_score_blocked_parity_vs_ref(shape, metric):
    n, m, d = shape
    x, c, _ = _data(n, m, d)
    dr, ar, sr = _score_ref(x, c, metric)
    # chunked rows (block_n=64) through the registry, default center tile
    db, ab, sb = score(x, c, jnp.float32(_THR), metric=metric,
                       policy=KernelPolicy(backend="blocked", block_n=64))
    assert (np.asarray(ab) == np.asarray(ar)).all()
    np.testing.assert_allclose(np.asarray(db), np.asarray(dr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(sr),
                               rtol=1e-5, atol=1e-5)
    # tiny center tile (block_m=32): the running-min scan over center
    # tiles, incl. the masked ragged last tile — bit-equal argmins still
    dt, at, st = score_blocked(x, c, jnp.float32(_THR), metric=metric,
                               block_n=64, block_m=32)
    assert (np.asarray(at) == np.asarray(ar)).all()
    np.testing.assert_allclose(np.asarray(dt), np.asarray(dr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("metric", METRICS)
def test_score_pallas_interpret_parity_vs_ref(shape, metric):
    n, m, d = shape
    x, c, _ = _data(n, m, d)
    dr, ar, sr = _score_ref(x, c, metric)
    dp, ap_, sp = score(x, c, jnp.float32(_THR), metric=metric,
                        policy=KernelPolicy(backend="pallas"))
    assert (np.asarray(ap_) == np.asarray(ar)).all()
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-5, atol=1e-5)


def test_score_small_m_blocked_is_bit_identical_to_ref():
    # the serving shape (m = k ~ tens <= block_m): the center-tile loop
    # collapses to the ref computation, so fusing must not change a bit
    x, c, _ = _data(257, 20, 11)
    dr, ar, sr = _score_ref(x, c, "l2sq")
    db, ab, sb = score(x, c, jnp.float32(_THR), metric="l2sq",
                       policy=KernelPolicy(backend="blocked"))
    assert (np.asarray(db) == np.asarray(dr)).all()
    assert (np.asarray(ab) == np.asarray(ar)).all()
    assert (np.asarray(sb) == np.asarray(sr)).all()


def test_score_predicates_cosine_excluded_from_pallas_only():
    regs = dispatch.registered_backends("score")
    assert set(regs) == {"ref", "blocked", "pallas", "int8"}
    for name in ("ref", "blocked", "int8"):
        assert regs[name].supports("cosine", "cpu", np.float32, 100, 10, 4)
    assert not regs["pallas"].supports("cosine", "tpu", np.float32, 100, 10, 4)
    # explicit-but-unsupported falls back to auto, like pdist does
    reg = dispatch.select_backend("score", KernelPolicy(backend="pallas"),
                                  metric="cosine", n=100, m=10, d=4,
                                  platform="tpu")
    assert reg.name == "blocked"


def test_score_int8_never_auto_picked():
    # int8 changes results, so auto must not select it on any platform
    for platform in ("cpu", "tpu"):
        reg = dispatch.select_backend("score", KernelPolicy(), metric="l2sq",
                                      n=100, m=10, d=4, platform=platform)
        assert reg.name != "int8"
    reg = dispatch.select_backend("score", KernelPolicy(backend="int8"),
                                  metric="l2sq", n=100, m=10, d=4)
    assert reg.name == "int8"


@pytest.mark.parametrize("metric", METRICS + ["cosine"])
def test_score_int8_error_within_gated_ceiling(metric):
    """The int8 path's error must stay under the SAME ceiling the bench
    gate enforces (benchmarks/stream_thresholds.json) — the bound is
    measured there, asserted here."""
    from pathlib import Path
    thr_file = (Path(__file__).resolve().parent.parent / "benchmarks"
                / "stream_thresholds.json")
    ceiling = json.loads(thr_file.read_text())["quant_max_score_err"]
    x, c, _ = _data(1001, 64, 8)
    dr, ar, _ = _score_ref(x, c, metric)
    # decision-boundary threshold, like the bench: scores sit around 1
    thr = jnp.maximum(jnp.median(dr), 1e-12).astype(jnp.float32)
    _, _, sr = score(x, c, thr, metric=metric,
                     policy=KernelPolicy(backend="ref"))
    _, _, sq = score(x, c, thr, metric=metric,
                     policy=KernelPolicy(backend="int8"))
    err = float(np.max(np.abs(np.asarray(sq) - np.asarray(sr))))
    assert err <= ceiling, (metric, err)


def test_score_joint_autotune_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS_CACHE", str(tmp_path))
    dispatch.clear_autotune_cache()
    try:
        bn, bm = dispatch.autotune_tiles("score", "blocked", metric="l2sq",
                                         n=2048, m=256, d=8)
        cache_file = tmp_path / "autotune.json"
        payload = json.loads(cache_file.read_text())
        (key,) = payload.keys()
        assert key.startswith("v2/score/blocked/")
        assert payload[key]["block_n"] == bn
        assert payload[key]["block_m"] == bm
        assert payload[key]["timings_us"]
        # second call (same bucket): served from cache — poisoning the
        # cached pair must be reflected verbatim
        payload[key]["block_n"], payload[key]["block_m"] = 12345, 678
        cache_file.write_text(json.dumps(payload))
        dispatch.clear_autotune_cache()
        assert dispatch.autotune_tiles("score", "blocked", metric="l2sq",
                                       n=2000, m=250, d=8) == (12345, 678)
        # and resolve_tiles threads the tuned pair through the policy path
        reg, rbn, rbm = dispatch.resolve_tiles(
            "score", KernelPolicy(autotune=True), metric="l2sq",
            n=2000, m=250, d=8)
        assert reg.name == "blocked" and (rbn, rbm) == (12345, 678)
        # an explicit block_n pins the row tile and disables the tuner
        _, ebn, ebm = dispatch.resolve_tiles(
            "score", KernelPolicy(autotune=True, block_n=777),
            metric="l2sq", n=2000, m=250, d=8)
        assert ebn == 777 and ebm != 678
    finally:
        dispatch.clear_autotune_cache()


def test_autotune_cache_ignores_stale_and_older_schema_entries(
        tmp_path, monkeypatch):
    """Schema-bump migration: a mixed-version cache file must be read
    without a KeyError — pre-v2 keys never match, and a v2 key written
    without ``block_m`` (the 1-D tuner's record under a 2-D op's bucket)
    is re-measured, not trusted."""
    monkeypatch.setenv("REPRO_KERNELS_CACHE", str(tmp_path))
    dispatch.clear_autotune_cache()
    try:
        stale_key = "v2/score/blocked/cpu/l2sq/n2048/m256/d8"
        mixed = {
            # pre-bump schema: unversioned key, single dimension
            "score/blocked/cpu/l2sq/n2048/m256/d8": {"block_n": 99999},
            # v2 key lacking the field the 2-D reader needs
            stale_key: {"block_n": 4096},
        }
        cache_file = tmp_path / "autotune.json"
        cache_file.write_text(json.dumps(mixed))
        bn, bm = dispatch.autotune_tiles("score", "blocked", metric="l2sq",
                                         n=2048, m=256, d=8)
        # stale entry was re-measured and overwritten with the full pair
        payload = json.loads(cache_file.read_text())
        assert payload[stale_key]["block_n"] == bn
        assert payload[stale_key]["block_m"] == bm
        # the old-schema key survives untouched (ignored, not migrated)
        assert payload["score/blocked/cpu/l2sq/n2048/m256/d8"] == {
            "block_n": 99999}
        # clear_autotune_cache over the mixed file: in-memory drop + reload
        dispatch.clear_autotune_cache()
        assert dispatch.autotune_tiles("score", "blocked", metric="l2sq",
                                       n=2048, m=256, d=8) == (bn, bm)
        # the 1-D tuner never sees 2-D entries as stale: block_n suffices
        bn1 = dispatch.autotune_block_n("score", "blocked", metric="l2sq",
                                        n=2048, m=256, d=8)
        assert bn1 == bn
    finally:
        dispatch.clear_autotune_cache()


# ------------------------------------------------- removed legacy aliases
def test_removed_aliases_raise_type_error_at_every_public_edge():
    """The PR-3 deprecation window is over: every public edge that carried
    ``use_pallas=``/``block_n=`` now raises a TypeError that names the
    ``KernelPolicy`` replacement instead of warning."""
    from repro.core.augmented import augmented_summary_outliers
    from repro.core.kmeans_mm import kmeans_minus_minus
    from repro.core.summary import summary_outliers
    from repro.stream.weighted import weighted_summary_outliers

    x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 3)), jnp.float32)
    w = jnp.ones((64,), jnp.float32)
    key = jax.random.key(11)
    edges = [
        lambda: summary_outliers(x, key, k=3, t=5, use_pallas=True),
        lambda: augmented_summary_outliers(x, key, k=3, t=8, block_n=64),
        lambda: kmeans_minus_minus(x, w, w > 0, key, k=3, t=5.0,
                                   use_pallas=False),
        lambda: weighted_summary_outliers(x, w, key, k=3, t=5, block_n=128),
        lambda: min_argmin(x, x[:4], block_n=128),
        lambda: lloyd_step(x, w, x[:4], use_pallas=True),
        lambda: score(x, x[:4], 1.0, block_n=128),
    ]
    for edge in edges:
        with pytest.raises(TypeError, match="KernelPolicy"):
            edge()


def test_policy_plus_alias_is_still_an_error():
    x, c, _ = _data(10, 2, 2)
    with pytest.raises(TypeError, match="removed"):
        min_argmin(x, c, policy=KernelPolicy(), block_n=64)


def test_kernel_policy_validates_block_n():
    for bad in (0, -1, True, 2.5):
        with pytest.raises(ValueError, match="block_n"):
            KernelPolicy(block_n=bad)
    assert KernelPolicy(block_n=None).block_n is None
    assert KernelPolicy(block_n=64).block_n == 64
