"""The telemetry plane: registry semantics, layer instrumentation,
front-door snapshot coverage, and the bit-identity guarantee.

Most tests isolate themselves with ``obs.using_registry`` so process-wide
series from other tests don't leak in; the layer tests construct their
services *inside* the scope because instrumented layers capture metric
handles at construction.
"""
from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.api.config import pipeline_config
from repro.api.session import Session
from repro.data.synthetic import gauss
from repro.obs.registry import metric_key, split_key
from repro.stream.service import ServiceConfig, StreamService
from repro.stream.sharded import ShardedServiceConfig, ShardedStreamService


# --------------------------------------------------------------- registry
def test_histogram_percentiles_match_numpy():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat")
    rng = np.random.default_rng(0)
    xs = rng.exponential(scale=0.01, size=1500)
    for v in xs:
        h.observe(v)
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(xs, q)), rel=1e-12)
    e = h.snapshot_entry()
    assert e["p50"] == pytest.approx(float(np.percentile(xs, 50)))
    assert e["p95"] == pytest.approx(float(np.percentile(xs, 95)))
    assert e["p99"] == pytest.approx(float(np.percentile(xs, 99)))
    assert e["count"] == 1500
    assert e["min"] == pytest.approx(xs.min())
    assert e["max"] == pytest.approx(xs.max())


def test_histogram_ring_bounds_memory_but_buckets_stay_cumulative():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat", ring=100)
    for v in np.linspace(0.001, 0.002, 1000):
        h.observe(v)
    e = h.snapshot_entry()
    assert e["count"] == 1000                    # buckets: full history
    assert e["buckets"]["+Inf"] == 1000
    assert len(h._ring) == 100                   # ring: bounded
    # percentiles computed over the *recent* 100 samples
    recent = np.linspace(0.001, 0.002, 1000)[-100:]
    assert h.percentile(50) == pytest.approx(float(np.percentile(recent, 50)))


def test_histogram_bucket_le_semantics():
    reg = obs.MetricsRegistry()
    h = reg.histogram("x", buckets=(1.0, 2.0))
    for v in (0.5, 1.0, 1.5, 2.0, 99.0):
        h.observe(v)
    b = h.snapshot_entry()["buckets"]
    assert b["1"] == 2        # 0.5, 1.0  (le-inclusive)
    assert b["2"] == 4        # + 1.5, 2.0
    assert b["+Inf"] == 5


def test_snapshot_golden_schema():
    """The snapshot dict is a cross-PR surface — shape pinned here."""
    reg = obs.MetricsRegistry()
    reg.counter("c", a="1").inc(3)
    reg.gauge("g").set(2.5)
    reg.histogram("h", buckets=(0.1,)).observe(0.05)
    snap = reg.snapshot()
    assert snap == {
        "version": 2,
        "enabled": True,
        "counters": {"c{a=1}": 3},
        "gauges": {"g": 2.5},
        "histograms": {"h": {
            "count": 1, "sum": 0.05, "min": 0.05, "max": 0.05,
            "p50": 0.05, "p95": 0.05, "p99": pytest.approx(0.05),
            "buckets": {"0.1": 1, "+Inf": 1},
        }},
        "alerts": [],
        "trace": {
            "enabled": True, "sample_rate": 1.0, "ring": 65536,
            "recorded": 0, "buffered": 0, "dropped": 0, "traces": 0,
        },
    }
    json.dumps(snap)   # JSON-serializable as-is


def test_counter_thread_safety():
    reg = obs.MetricsRegistry()
    c = reg.counter("hits")
    n_threads, per = 8, 5000

    def work():
        for _ in range(per):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per


def test_histogram_thread_safety():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat")
    n_threads, per = 4, 2000

    def work():
        for _ in range(per):
            h.observe(0.001)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    e = h.snapshot_entry()
    assert e["count"] == n_threads * per
    assert e["buckets"]["+Inf"] == n_threads * per


def test_disabled_registry_is_noop():
    reg = obs.MetricsRegistry(enabled=False)
    reg.counter("c").inc()
    reg.gauge("g").set(1.0)
    reg.histogram("h").observe(1.0)
    with reg.trace("p"):
        pass
    snap = reg.snapshot()
    assert snap["enabled"] is False
    assert snap["counters"]["c"] == 0
    assert snap["histograms"]["h"]["count"] == 0
    assert "phase.p" not in snap["histograms"]


def test_gauge_callable_and_failure():
    reg = obs.MetricsRegistry()
    reg.gauge("ok").set_fn(lambda: 42)
    reg.gauge("bad").set_fn(lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["gauges"]["ok"] == 42.0
    assert snap["gauges"]["bad"] is None   # failing gauge never raises


def test_metric_key_roundtrip_and_sanitization():
    key = metric_key("comm.records", {"site": 3, "topology": "sharded"})
    assert key == "comm.records{site=3,topology=sharded}"
    assert split_key(key) == ("comm.records",
                              {"site": "3", "topology": "sharded"})
    assert split_key("plain") == ("plain", {})
    # label values that would break the key format are sanitized
    assert "{" not in metric_key("m", {"v": "a{b}=c,d"}).split("{", 1)[1][:-1]\
        .split("=", 1)[1]


def test_trace_span_records_wall_time():
    reg = obs.MetricsRegistry()
    with reg.trace("fit", topology="t"):
        pass
    e = reg.snapshot()["histograms"]["phase.fit{topology=t}"]
    assert e["count"] == 1 and e["sum"] >= 0


def test_using_registry_scopes_default():
    base = obs.get_default_registry()
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        assert obs.get_default_registry() is reg
        obs.counter("scoped").inc()
        assert reg.snapshot()["counters"]["scoped"] == 1
    assert obs.get_default_registry() is base
    assert "scoped" not in base.snapshot()["counters"]


def test_prometheus_rendering():
    reg = obs.MetricsRegistry()
    reg.counter("comm.records", site=0).inc(7)
    reg.gauge("tree.records").set(12)
    reg.histogram("serve.latency", buckets=(0.01,),
                  topology="stream").observe(0.005)
    txt = obs.render_prometheus(reg.snapshot())
    assert "# TYPE comm_records_total counter" in txt
    assert 'comm_records_total{site="0"} 7' in txt
    assert "tree_records 12" in txt
    assert "# TYPE serve_latency histogram" in txt
    assert 'serve_latency_bucket{le="0.01",topology="stream"} 1' in txt
    assert 'serve_latency_count{topology="stream"} 1' in txt
    assert ('serve_latency_quantile{quantile="0.5",topology="stream"}'
            in txt)


# ------------------------------------------------------------ layer wiring
def _stream_cfg(**kw):
    base = dict(dim=4, k=3, t=8, leaf_size=64, refresh_every=256,
                micro_batch=32, second_iters=5, seed=0)
    base.update(kw)
    return ServiceConfig(**base)


def _ingest_data(n=600, d=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def test_latency_stats_compat_shim():
    with obs.using_registry(obs.MetricsRegistry()):
        svc = StreamService(_stream_cfg())
        empty = svc.latency_stats()
        assert empty["count"] == 0
        assert np.isnan(empty["p50_ms"]) and np.isnan(empty["p99_ms"])
        svc.ingest(_ingest_data())
        svc.refresh()
        svc.score(_ingest_data(70))
        stats = svc.latency_stats()
        assert set(stats) == {"count", "p50_ms", "p99_ms"}
        assert stats["count"] == 70
        assert np.isfinite(stats["p50_ms"])
        assert stats["p50_ms"] <= stats["p99_ms"]
        svc.reset_latency_stats()
        assert svc.latency_stats()["count"] == 0


def test_bounded_latency_state():
    """The unbounded-list leak is gone: latency state is O(ring), not O(n)."""
    with obs.using_registry(obs.MetricsRegistry()):
        svc = StreamService(_stream_cfg())
        assert not hasattr(svc, "_latencies")
        assert svc._lat._ring.maxlen == obs.DEFAULT_RING


def test_single_host_refresh_stats_and_staleness():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        svc = StreamService(_stream_cfg())
        assert svc.last_fit is None
        assert svc.seconds_since_install() is None
        svc.ingest(_ingest_data())
        svc.refresh()
        assert svc.last_fit is not None
        assert svc.last_fit.version == int(svc.model.version)
        assert svc.last_fit.records_folded > 0
        assert svc.last_fit.fit_s >= 0
        age = svc.seconds_since_install()
        assert age is not None and age >= 0
        snap = reg.snapshot()
        g = snap["gauges"]["model.seconds_since_install{topology=stream}"]
        assert g is not None and g >= age   # gauge evaluates later => older
        assert snap["histograms"][
            "phase.refresh.fit{topology=stream}"]["count"] >= 1


def test_async_refresh_stats_install_at_poll():
    with obs.using_registry(obs.MetricsRegistry()):
        svc = StreamService(_stream_cfg(async_refresh=True))
        svc.ingest(_ingest_data(200))
        svc.refresh(blocking=False)
        svc.join_refresh()
        assert svc.last_fit is not None
        assert svc.last_fit.version == int(svc.model.version)
        assert svc.last_fit.records_folded > 0


def test_stream_snapshot_covers_tree_and_phases():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        svc = StreamService(_stream_cfg())
        svc.ingest(_ingest_data())
        svc.refresh()
        svc.score(_ingest_data(40))
        snap = reg.snapshot()
        c, h, g = snap["counters"], snap["histograms"], snap["gauges"]
        summ = svc.cfg.summarizer.name
        assert c[f"tree.leaf_flushes{{summarizer={summ}}}"] >= 2
        assert c["ingest.points{topology=stream}"] == 600
        assert c["score.requests{topology=stream}"] == 40
        assert h[f"phase.ingest.leaf_flush{{summarizer={summ}}}"][
            "count"] >= 2
        assert h["phase.score.fused{topology=stream}"]["count"] >= 1
        assert g[f"tree.records{{summarizer={summ}}}"] > 0
        assert any(k.startswith("kernels.dispatch{") for k in c)


def test_sharded_comm_accounting_matches_refresh_stats():
    with obs.using_registry(obs.MetricsRegistry()) as reg:
        cfg = ShardedServiceConfig(
            dim=4, k=3, t=8, n_sites=3, leaf_size=64, refresh_every=256,
            micro_batch=32, second_iters=5, seed=0)
        svc = ShardedStreamService(cfg)
        svc.ingest(_ingest_data(600))
        svc.refresh()
        st = svc.last_refresh
        snap = reg.snapshot()
        c = snap["counters"]
        assert c["comm.rounds{topology=sharded}"] == int(st.version)
        # the LAST refresh's per-site records are the final increments;
        # totals accumulate over all refreshes, so each site's counter is
        # at least its last contribution
        for i, rec in enumerate(st.per_site_records):
            key = f"comm.records{{site={i},topology=sharded}}"
            assert c[key] >= rec
        key0 = "comm.bytes{site=0,topology=sharded}"
        assert c[key0] >= st.payload_bytes
        # per-site tree series carry the site label
        summ = svc.trees[0].cfg.summarizer.name
        assert f"tree.records{{site=0,summarizer={summ}}}" in snap["gauges"]


def test_scores_bit_identical_with_metrics_on_and_off():
    x = _ingest_data(600)
    q = _ingest_data(64, seed=7)

    def run() -> list:
        svc = StreamService(_stream_cfg())
        svc.ingest(x)
        svc.refresh()
        return svc.score(q)

    with obs.using_registry(obs.MetricsRegistry(enabled=True)):
        res_on = run()
    with obs.using_registry(obs.MetricsRegistry(enabled=False)):
        res_off = run()
    for a, b in zip(res_on, res_off):
        assert a.request_id == b.request_id
        assert a.center == b.center
        assert a.distance == b.distance            # bit-identical
        assert a.outlier_score == b.outlier_score
        assert a.is_outlier == b.is_outlier


def test_checkpoint_metrics(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    with obs.using_registry(obs.MetricsRegistry()) as reg:
        mgr = CheckpointManager(tmp_path)
        state = {"a": np.arange(100, dtype=np.float32)}
        mgr.save(1, state, blocking=True)
        restored, _ = mgr.restore({"a": np.zeros(100, np.float32)})
        snap = reg.snapshot()
        c = snap["counters"]
        assert c["checkpoint.saves"] == 1
        assert c["checkpoint.restores"] == 1
        assert c["checkpoint.bytes_written"] == 400
        assert c["checkpoint.bytes_read"] == 400
        assert snap["histograms"]["phase.checkpoint.save"]["count"] == 1
        assert snap["histograms"]["phase.checkpoint.restore"]["count"] == 1
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      state["a"])


# --------------------------------------------------------------- front door
def _session_snapshot(kind: str) -> dict:
    topo_kw = {}
    if kind in ("stream", "sharded"):
        topo_kw = dict(leaf_size=64, refresh_every=256, micro_batch=32)
    if kind == "sharded":
        topo_kw["sites"] = 2
    cfg = pipeline_config(dim=4, k=3, t=10, topology=kind,
                          second_iters=5, seed=0, **topo_kw)
    x, _ = gauss(n_centers=3, per_center=150, d=4, t=10, seed=0)
    session = Session(cfg)
    session.fit(np.asarray(x, np.float32))
    session.score(np.asarray(x[:40], np.float32))
    return session.stats()


@pytest.mark.parametrize("kind", ["oneshot", "stream", "sharded"])
def test_session_stats_covers_every_topology(kind):
    with obs.using_registry(obs.MetricsRegistry()):
        snap = _session_snapshot(kind)
        h, c = snap["histograms"], snap["counters"]
        # serve latency histogram for this topology
        assert h[f"serve.latency{{topology={kind}}}"]["count"] == 40
        # refresh phase timings
        assert h[f"phase.refresh.fit{{topology={kind}}}"]["count"] >= 1
        # score phases
        assert h[f"phase.score.fused{{topology={kind}}}"]["count"] >= 1
        # kernel-backend dispatch counts
        assert any(k.startswith("kernels.dispatch{") for k in c)
        if kind == "oneshot":
            assert any(k.startswith("comm.records{") for k in c)
            assert any(k.startswith("phase.oneshot.site_summary{")
                       for k in h)
        if kind == "sharded":
            assert c["comm.rounds{topology=sharded}"] >= 1
            assert any(k.startswith("comm.bytes{") for k in c)


def test_session_stats_is_json_and_prom_renderable():
    with obs.using_registry(obs.MetricsRegistry()):
        snap = _session_snapshot("stream")
        json.dumps(snap)
        txt = obs.render_prometheus(snap)
        assert "serve_latency_bucket" in txt
