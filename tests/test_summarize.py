"""Summarizer registry: protocol invariants parametrized over every
registered implementation, merge-then-reduce composability, default
bit-identity with the pre-registry call sites, and the cosine satellite."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional: only the property tests need hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import distributed_cluster, simulate_coordinator
from repro.data.synthetic import gauss, partition, susy_like
from repro.kernels.pdist.ops import min_argmin
from repro.stream import (ServiceConfig, ShardedServiceConfig,
                          ShardedStreamService, StreamService, StreamTree,
                          TreeConfig)
from repro.stream.weighted import resummarize, weighted_summary_outliers
from repro.summarize import (SummarizerPolicy, get_summarizer, record_bound,
                             reduce_summaries, registered_summarizers,
                             select_summarizer, site_summary, summarize,
                             summarizer_policy, using_summarizer)
from repro.summarize.paper import pick_augmented

ALL_SUMMARIZERS = sorted(registered_summarizers())
K, T = 8, 25


def _data(n=1200, d=4, seed=0, outliers=30):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    if outliers:
        ids = rng.choice(n, outliers, replace=False)
        x[ids] += rng.uniform(-25, 25, size=(outliers, d)).astype(np.float32)
    return x


def _check_protocol(x, w, summ, t):
    # mass conservation: the contract that makes summaries compose
    np.testing.assert_allclose(float(summ.weights.sum()), float(w.sum()),
                               rtol=1e-4)
    assert float(summ.total_weight) == pytest.approx(float(w.sum()), rel=1e-5)
    # every record carries positive mass
    assert (summ.weights > 0).all()
    # provenance: summary points are input rows, ids into the caller's array
    assert summ.indices is not None
    np.testing.assert_array_equal(summ.points, x[summ.indices])
    # candidate (outlier-survivor) mass bounded by the paper's 8t
    assert float(summ.weights[summ.is_candidate].sum()) <= 8 * t + 1e-3


# ------------------------------------------------------------------ registry
def test_registry_contents():
    assert {"paper", "uniform", "ball_cover", "coreset"} <= set(ALL_SUMMARIZERS)
    with pytest.raises(ValueError, match="unknown summarizer"):
        get_summarizer("nope")
    with pytest.raises(ValueError, match="unknown summarizer"):
        summarize(np.zeros((4, 2)), np.ones(4), jax.random.key(0),
                  k=2, t=1, policy=SummarizerPolicy("nope"))


def test_auto_selects_paper_and_never_a_baseline():
    for metric in ("l2sq", "l2", "l1", "cosine"):
        spec = select_summarizer(SummarizerPolicy("auto"), metric=metric,
                                 k=K, t=T)
        assert spec.name == "paper"
    assert get_summarizer("uniform").priority < 0  # by-name only


def test_policy_params_are_canonical_and_hashable():
    a = summarizer_policy("coreset", budget=64, seed_rounds=2)
    b = SummarizerPolicy("coreset", {"seed_rounds": 2, "budget": 64})
    assert a == b and hash(a) == hash(b)
    assert a.with_params(budget=128).params_dict()["budget"] == 128
    assert a.params_dict() == {"budget": 64, "seed_rounds": 2}


# ------------------------------------------- protocol, every implementation
@pytest.mark.parametrize("name", ALL_SUMMARIZERS)
def test_protocol_unit_weights(name):
    x = _data()
    w = np.ones((x.shape[0],), np.float32)
    summ = summarize(x, w, jax.random.key(1), k=K, t=T,
                     policy=SummarizerPolicy(name))
    _check_protocol(x, w, summ, T)
    # unit weights: candidates each carry >= 1 mass, so count <= 8t too
    assert int(summ.is_candidate.sum()) <= 8 * T


@pytest.mark.parametrize("name", ALL_SUMMARIZERS)
def test_protocol_weighted_records(name):
    x = _data(seed=2)
    rng = np.random.default_rng(3)
    w = rng.uniform(0.25, 4.0, size=(x.shape[0],)).astype(np.float32)
    w[rng.choice(x.shape[0], 50, replace=False)] = 0.0  # dropped rows
    summ = summarize(x, w, jax.random.key(2), k=K, t=T,
                     policy=SummarizerPolicy(name))
    _check_protocol(x, w, summ, T)


@pytest.mark.parametrize("name", ALL_SUMMARIZERS)
def test_merge_then_reduce_composes(name):
    pol = SummarizerPolicy(name)
    x1, x2 = _data(seed=4), _data(seed=5)
    w = np.ones((x1.shape[0],), np.float32)
    s1 = summarize(x1, w, jax.random.key(3), k=K, t=T, policy=pol)
    s2 = summarize(x2, w, jax.random.key(4), k=K, t=T, policy=pol)
    red = reduce_summaries([s1, s2], jax.random.key(5), k=K, t=T, policy=pol)
    # reducing a union of summaries conserves the union's mass ...
    np.testing.assert_allclose(float(red.weights.sum()),
                               x1.shape[0] + x2.shape[0], rtol=1e-4)
    # ... stays within the registered static record bound ...
    cap = record_bound(pol, k=K, t=T, max_points=x1.shape[0] + x2.shape[0],
                       leaf_size=x1.shape[0])
    assert red.points.shape[0] <= cap
    # ... and keeps the candidate-mass bound (outliers can still surface)
    assert float(red.weights[red.is_candidate].sum()) <= 8 * T + 1e-3


@pytest.mark.parametrize("name", ALL_SUMMARIZERS)
def test_empty_and_degenerate_inputs(name):
    pol = SummarizerPolicy(name)
    s = summarize(np.zeros((0, 3), np.float32), np.zeros((0,), np.float32),
                  jax.random.key(0), k=K, t=T, policy=pol)
    assert s.points.shape[0] == 0 and s.total_weight == 0.0
    one = summarize(np.ones((1, 3), np.float32), np.ones((1,), np.float32),
                    jax.random.key(0), k=K, t=T, policy=pol)
    assert float(one.weights.sum()) == pytest.approx(1.0)


# ---------------------------------------------------- hypothesis properties
if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        name=st.sampled_from(ALL_SUMMARIZERS),
        n=st.integers(min_value=1, max_value=180),
        d=st.integers(min_value=1, max_value=6),
        t=st.integers(min_value=1, max_value=12),
        wseed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_mass_conservation_property(name, n, d, t, wseed):
        rng = np.random.default_rng(wseed)
        x = rng.normal(size=(n, d)).astype(np.float32)
        w = rng.uniform(0.0, 3.0, size=(n,)).astype(np.float32)
        summ = summarize(x, w, jax.random.key(wseed % 997), k=3, t=t,
                         policy=SummarizerPolicy(name))
        np.testing.assert_allclose(float(summ.weights.sum()), float(w.sum()),
                                   rtol=1e-3, atol=1e-4)
        assert float(summ.weights[summ.is_candidate].sum()) <= 8 * t + 1e-3
        if summ.points.shape[0]:
            np.testing.assert_array_equal(summ.points, x[summ.indices])

    @settings(max_examples=8, deadline=None)
    @given(
        name=st.sampled_from(ALL_SUMMARIZERS),
        split=st.integers(min_value=1, max_value=159),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_reduce_conserves_mass_property(name, split, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(160, 3)).astype(np.float32)
        w = rng.uniform(0.1, 2.0, size=(160,)).astype(np.float32)
        pol = SummarizerPolicy(name)
        s1 = summarize(x[:split], w[:split], jax.random.key(seed % 991),
                       k=3, t=4, policy=pol)
        s2 = summarize(x[split:], w[split:], jax.random.key(seed % 983),
                       k=3, t=4, policy=pol)
        red = reduce_summaries([s1, s2], jax.random.key(seed % 977),
                               k=3, t=4, policy=pol)
        np.testing.assert_allclose(float(red.weights.sum()), float(w.sum()),
                                   rtol=1e-3, atol=1e-4)


# ------------------------------------------------------- default bit-identity
def test_default_summarize_is_weighted_summary_outliers_bitwise():
    x = _data(seed=6)
    w = np.ones((x.shape[0],), np.float32)
    via_registry = summarize(x, w, jax.random.key(7), k=K, t=T)
    direct = weighted_summary_outliers(x, w, jax.random.key(7), k=K, t=T)
    np.testing.assert_array_equal(via_registry.points, direct.points)
    np.testing.assert_array_equal(via_registry.weights, direct.weights)
    np.testing.assert_array_equal(via_registry.is_candidate,
                                  direct.is_candidate)


def test_default_reduce_is_resummarize_bitwise():
    x = _data(seed=7)
    w = np.ones((x.shape[0],), np.float32)
    s1 = weighted_summary_outliers(x[:600], w[:600], jax.random.key(8),
                                   k=K, t=T)
    s2 = weighted_summary_outliers(x[600:], w[600:], jax.random.key(9),
                                   k=K, t=T)
    a = reduce_summaries([s1, s2], jax.random.key(10), k=K, t=T)
    b = resummarize([s1, s2], jax.random.key(10), k=K, t=T)
    np.testing.assert_array_equal(a.points, b.points)
    np.testing.assert_array_equal(a.weights, b.weights)


def test_tree_default_matches_explicit_paper_policy_bitwise():
    x = _data(n=3000, seed=8)
    roots = []
    for pol in (None, SummarizerPolicy("paper")):
        cfg = TreeConfig(dim=x.shape[1], k=K, t=T, leaf_size=512,
                         summarizer=pol, seed=1)
        tree = StreamTree(cfg)
        tree.ingest(x)
        roots.append(tree.root())
    np.testing.assert_array_equal(roots[0][0], roots[1][0])
    np.testing.assert_array_equal(roots[0][1], roots[1][1])


def test_distributed_cluster_default_matches_paper_policy_bitwise():
    x, _ = gauss(n_centers=6, per_center=200, t=40, sigma=0.1, seed=9)
    mesh = jax.make_mesh((1,), ("sites",))
    res_default = distributed_cluster(jnp.asarray(x)[None],
                                      jax.random.key(0), mesh, k=6, t=40)
    res_policy = distributed_cluster(
        jnp.asarray(x)[None], jax.random.key(0), mesh, k=6, t=40,
        summarizer=summarizer_policy("paper", variant="augmented"))
    np.testing.assert_array_equal(np.asarray(res_default.centers),
                                  np.asarray(res_policy.centers))
    np.testing.assert_array_equal(np.asarray(res_default.outlier_ids),
                                  np.asarray(res_policy.outlier_ids))


# ------------------------------------------------------- per-site threading
def test_distributed_cluster_uniform_site_path():
    x, _ = gauss(n_centers=6, per_center=200, t=40, sigma=0.1, seed=10)
    mesh = jax.make_mesh((1,), ("sites",))
    res = distributed_cluster(
        jnp.asarray(x)[None], jax.random.key(0), mesh, k=6, t=40,
        summarizer=summarizer_policy("uniform", budget=300))
    assert np.asarray(res.centers).shape == (6, x.shape[1])
    assert float(res.comm_records) <= 300


def test_distributed_cluster_host_only_summarizer_raises():
    x = _data(n=400, seed=11)
    mesh = jax.make_mesh((1,), ("sites",))
    with pytest.raises(ValueError, match="no fixed-shape site path"):
        distributed_cluster(jnp.asarray(x)[None], jax.random.key(0), mesh,
                            k=K, t=T, summarizer=SummarizerPolicy("ball_cover"))


@pytest.mark.parametrize("name", ["ball_cover", "coreset"])
def test_simulate_coordinator_with_registry_summarizer(name):
    x, out_ids = gauss(n_centers=10, per_center=300, t=60, sigma=0.1, seed=12)
    parts, gids = partition(x, 4, "random", seed=0, outlier_ids=out_ids)
    res = simulate_coordinator(parts, jax.random.key(0), k=10, t=60,
                               summarizer=SummarizerPolicy(name))
    assert res["comm_records"] == len(res["summary_ids"])
    conc_w = float(np.sum(res["summary_weights"]))
    assert conc_w == pytest.approx(sum(p.shape[0] for p in parts), rel=1e-4)
    assert res["centers"].shape == (10, x.shape[1])


def test_ball_cover_beats_paper_center_count_under_heavy_noise():
    """t >> k heavy noise: aggregation folds noise balls into heavy ones,
    so ball_cover spends fewer records on scattered noise centers."""
    rng = np.random.default_rng(13)
    n, t = 4000, 400                       # 10% noise, k=4: t >> k
    x = np.concatenate([
        rng.normal(size=(n - t, 3)) * 0.05 +
        rng.choice([-4.0, 4.0], size=(n - t, 1)),
        rng.uniform(-40, 40, size=(t, 3)),
    ]).astype(np.float32)
    w = np.ones((n,), np.float32)
    s_paper = summarize(x, w, jax.random.key(1), k=4, t=t,
                        policy=SummarizerPolicy("paper"))
    s_bc = summarize(x, w, jax.random.key(1), k=4, t=t,
                     policy=SummarizerPolicy("ball_cover"))
    centers_paper = int((~s_paper.is_candidate).sum())
    centers_bc = int((~s_bc.is_candidate).sum())
    assert centers_bc < centers_paper
    np.testing.assert_allclose(float(s_bc.weights.sum()), n, rtol=1e-4)


def test_stream_service_accepts_summarizer_policy():
    x = _data(n=4000, seed=14)
    cfg = ServiceConfig(dim=x.shape[1], k=K, t=T, leaf_size=512,
                        refresh_every=2048,
                        summarizer=summarizer_policy("coreset", budget=256))
    svc = StreamService(cfg)
    svc.ingest(x)
    svc.refresh()
    assert svc.model is not None and int(svc.model.version) >= 1
    # coreset leaves are budget-bounded
    assert all(nd.summary.points.shape[0] <= 256 for nd in svc.tree.nodes)
    res = svc.score(x[:8])
    assert len(res) == 8


def test_sharded_service_threads_summarizer_to_site_trees():
    pol = summarizer_policy("uniform", budget=128)
    cfg = ShardedServiceConfig(dim=3, k=4, t=8, n_sites=3, leaf_size=256,
                               refresh_every=1024, summarizer=pol)
    svc = ShardedStreamService(cfg)
    assert all(tr.cfg.summarizer == pol for tr in svc.trees)
    svc.ingest(_data(n=2000, d=3, seed=15))
    svc.refresh()
    assert svc.model is not None


def test_process_default_summarizer_threading():
    x = _data(n=1500, seed=16)
    with using_summarizer(summarizer_policy("uniform", budget=96)):
        cfg = TreeConfig(dim=x.shape[1], k=K, t=T, leaf_size=512)
        tree = StreamTree(cfg)
        tree.ingest(x)
    assert cfg.summarizer.name == "uniform"
    assert all(nd.summary.points.shape[0] <= 96 for nd in tree.nodes)
    np.testing.assert_allclose(tree.total_weight, x.shape[0], rtol=1e-5)


# ----------------------------------------------------------- paper variants
def test_paper_variant_auto_rule():
    assert pick_augmented("auto", k=10, t=100, metric="l2sq")
    assert not pick_augmented("auto", k=10, t=5, metric="l2sq")
    assert not pick_augmented("auto", k=10, t=100, metric="cosine")
    assert pick_augmented("augmented", k=10, t=1, metric="l2sq")
    assert not pick_augmented("plain", k=10, t=100, metric="l2sq")
    with pytest.raises(ValueError, match="variant"):
        pick_augmented("bogus", k=10, t=1, metric="l2sq")


def test_site_summary_plain_is_summary_outliers_bitwise():
    from repro.core import summary_outliers

    x = jnp.asarray(_data(n=900, seed=17))
    via = site_summary(x, jax.random.key(3), k=K, t=T,
                       policy=summarizer_policy("paper", variant="plain"))
    direct = summary_outliers(x, jax.random.key(3), k=K, t=T)
    np.testing.assert_array_equal(np.asarray(via.points),
                                  np.asarray(direct.points))
    np.testing.assert_array_equal(np.asarray(via.weights),
                                  np.asarray(direct.weights))


def test_site_summary_host_only_raises():
    with pytest.raises(ValueError, match="no fixed-shape site path"):
        site_summary(jnp.zeros((64, 3)), jax.random.key(0), k=2, t=2,
                     policy=SummarizerPolicy("coreset"))


# ------------------------------------------------------------- cosine metric
def test_cosine_min_argmin_matches_manual():
    rng = np.random.default_rng(18)
    x = rng.normal(size=(500, 6)).astype(np.float32)
    c = rng.normal(size=(17, 6)).astype(np.float32)
    xn = x / np.linalg.norm(x, axis=1, keepdims=True)
    cn = c / np.linalg.norm(c, axis=1, keepdims=True)
    ref = 1.0 - xn @ cn.T
    d, a = (np.asarray(v) for v in min_argmin(jnp.asarray(x), jnp.asarray(c),
                                              metric="cosine"))
    np.testing.assert_allclose(d, ref.min(axis=1), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(a, ref.argmin(axis=1))


def test_cosine_never_auto_selects_pallas():
    from repro.kernels import dispatch
    from repro.kernels.dispatch import KernelPolicy

    for policy in (KernelPolicy(), KernelPolicy(backend="pallas")):
        reg = dispatch.select_backend("min_argmin", policy, metric="cosine",
                                      n=1000, m=32, d=8, platform="tpu")
        assert reg.name != "pallas"


def test_coreset_cosine_on_unit_normalized_susy():
    x, out_ids = susy_like(n=4000, t=60, seed=19)
    x = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    w = np.ones((x.shape[0],), np.float32)
    summ = summarize(x, w, jax.random.key(4), k=10, t=60, metric="cosine",
                     policy=summarizer_policy("coreset", budget=512))
    _check_protocol(x, w, summ, 60)
    assert summ.points.shape[0] <= 512


def test_cosine_end_to_end_through_second_level():
    """metric='cosine' must survive the whole pipeline, not just the
    summarizer: the lloyd_step blocked/ref backends serve it (weighted-mean
    centers = the spherical k-means update), so simulate_coordinator and a
    cosine-configured stream refresh run to completion."""
    x, out_ids = susy_like(n=3000, t=50, seed=20)
    x = (x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
         ).astype(np.float32)
    parts, gids = partition(x, 3, "random", seed=0, outlier_ids=out_ids)
    res = simulate_coordinator(parts, jax.random.key(0), k=6, t=50,
                               metric="cosine",
                               summarizer=SummarizerPolicy("coreset"))
    assert res["centers"].shape == (6, x.shape[1])
    assert np.isfinite(res["cost"])

    cfg = ServiceConfig(dim=x.shape[1], k=6, t=50, leaf_size=512,
                        refresh_every=2048, metric="cosine")
    svc = StreamService(cfg)
    svc.ingest(x)
    svc.refresh()
    out = svc.score(x[:4])
    assert len(out) == 4 and all(np.isfinite(r.distance) for r in out)


def test_augmented_rejects_cosine():
    from repro.core import augmented_summary_outliers

    with pytest.raises(ValueError, match="cosine"):
        augmented_summary_outliers(jnp.zeros((64, 3)), jax.random.key(0),
                                   k=2, t=2, metric="cosine")
