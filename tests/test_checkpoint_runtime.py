"""Checkpoint manager, elastic runner, straggler monitor, robust
aggregation, data pipeline determinism, curation."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.curation import CuratorConfig, DataCurator
from repro.data.tokens import PipelineConfig, TokenPipeline
from repro.runtime.straggler import StragglerMonitor


# ------------------------------------------------------------ checkpoint
def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(7, dtype=jnp.int32)},
            "step_scale": jnp.float32(3.5)}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    t = _tree(1)
    cm.save(5, t, blocking=True)
    like = jax.tree.map(jnp.zeros_like, t)
    restored, step = cm.restore(like)
    assert step == 5
    jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                         np.asarray(b)),
                 t, restored)


def test_checkpoint_async_latest_and_prune(tmp_path):
    cm = CheckpointManager(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    cm.wait()
    assert cm.latest_step() == 4
    assert cm.all_steps() == [3, 4]


def test_checkpoint_crc_detects_corruption(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, _tree(2), blocking=True)
    d = cm.root / "step_000000001"
    f = sorted(d.glob("arr_*.npy"))[0]
    arr = np.load(f)
    arr = arr.reshape(-1)
    arr[0] += 1
    np.save(f, arr.reshape(np.load(f).shape))
    with pytest.raises(IOError):
        cm.restore(jax.tree.map(jnp.zeros_like, _tree(2)))


def test_checkpoint_interrupted_write_invisible(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, _tree(0), blocking=True)
    # simulate a crashed writer: stale tmp dir must be ignored
    (cm.root / "step_000000009.tmp").mkdir()
    assert cm.latest_step() == 1


def test_checkpoint_async_write_error_reraised(tmp_path, monkeypatch):
    """A failed async write must not die silently with the daemon thread:
    wait() re-raises it on the caller, and so does the next save() (which
    waits first), so dependent work cannot proceed past a lost step."""
    cm = CheckpointManager(tmp_path)
    orig = np.save

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(np, "save", boom)
    cm.save(1, _tree(0))             # async: enqueues, returns immediately
    with pytest.raises(OSError, match="disk full"):
        cm.wait()
    # the failed step was never published
    assert cm.all_steps() == []
    # error also surfaces on the next save(), not just an explicit wait():
    # save(3) joins the failed step-2 writer before touching disk itself
    cm.save(2, _tree(0))
    with pytest.raises(OSError, match="disk full"):
        cm.save(3, _tree(0), blocking=True)
    assert cm.all_steps() == []
    # ...and once drained the manager keeps working
    monkeypatch.setattr(np, "save", orig)
    cm.save(4, _tree(0), blocking=True)
    assert cm.latest_step() == 4


# ------------------------------------------------------------ data pipeline
def test_pipeline_deterministic_and_shard_disjoint():
    cfg = PipelineConfig(vocab=64, seq_len=32, global_batch=8, n_shards=4, seed=7)
    p = TokenPipeline(cfg)
    b1 = p.batch(10, 2)["tokens"]
    b2 = p.batch(10, 2)["tokens"]
    np.testing.assert_array_equal(b1, b2)           # resumable
    b3 = p.batch(10, 3)["tokens"]
    assert not np.array_equal(b1, b3)               # shards differ
    b4 = p.batch(11, 2)["tokens"]
    assert not np.array_equal(b1, b4)               # steps differ
    g = p.global_batch(10)["tokens"]
    assert g.shape == (8, 32)


# ------------------------------------------------------------ straggler
def test_straggler_monitor_flags_slow_site():
    mon = StragglerMonitor(n_sites=8, budget_frac=0.2)
    rng = np.random.default_rng(0)
    mask = None
    for _ in range(10):
        d = rng.normal(1.0, 0.02, size=8).astype(np.float32)
        d[3] = 4.0  # persistent straggler
        mask = mon.observe(d)
    assert mask[3]
    assert mask.sum() <= 2
    assert 3 in mon.policy(mask)


def test_straggler_monitor_quiet_when_healthy():
    mon = StragglerMonitor(n_sites=8)
    rng = np.random.default_rng(1)
    for _ in range(10):
        mask = mon.observe(rng.normal(1.0, 0.02, size=8).astype(np.float32))
    assert mask.sum() == 0


# ------------------------------------------------------------ curation
def test_curator_flags_planted_outlier_sequences():
    cur = DataCurator(n_sites=4, cfg=CuratorConfig(k=8, outlier_frac=0.02,
                                                   min_points=200))
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(8, 16)) * 3
    planted = []
    sid = 0
    for site in range(4):
        embs, ids = [], []
        for _ in range(400):
            c = rng.integers(0, 8)
            e = centers[c] + rng.normal(scale=0.05, size=16)
            if rng.random() < 0.02:
                e = e + rng.uniform(-30, 30, size=16)
                planted.append(sid)
            embs.append(e), ids.append(sid)
            sid += 1
        cur.observe(site, np.stack(embs), np.array(ids))
    flagged, comm = cur.detect()
    assert flagged is not None and comm > 0
    rec = len(set(flagged.tolist()) & set(planted)) / max(len(planted), 1)
    assert rec >= 0.7
    w = cur.sample_weights(np.array(planted), flagged)
    assert w.mean() <= 0.3


# ------------------------------------------------------------ elastic (subprocess)
_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.elastic import ElasticConfig, ElasticRunner

    D = 16

    def make_step(mesh):
        spec = NamedSharding(mesh, P())
        bspec = NamedSharding(mesh, P("data"))
        @jax.jit
        def step(state, batch):
            w, opt_step = state
            x, y = batch
            def loss_fn(w):
                pred = x @ w
                return jnp.mean((pred - y) ** 2)
            loss, g = jax.value_and_grad(loss_fn)(w)
            return (w - 0.1 * g, opt_step + 1), {"loss": loss}
        def run(state, batch):
            x = jax.device_put(batch["x"], bspec)
            y = jax.device_put(batch["y"], bspec)
            st = jax.device_put(state, (spec, spec))
            return step(st, (x, y))
        return run

    def init_state(mesh):
        return (jnp.zeros((D,)), jnp.int32(0))

    def shardings(mesh, state):
        s = NamedSharding(mesh, P())
        return (s, s)

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=D)
    def data_fn(step):
        r = np.random.default_rng(step)
        x = r.normal(size=(8, D)).astype(np.float32)
        return {"x": x, "y": (x @ w_true).astype(np.float32)}

    import tempfile
    ckpt = CheckpointManager(tempfile.mkdtemp())
    runner = ElasticRunner(make_step=make_step, init_state=init_state,
                           state_shardings=shardings, data_fn=data_fn,
                           ckpt=ckpt, cfg=ElasticConfig(ckpt_every=5))
    state, log = runner.run(60, fail_at={23: 4, 41: 2})
    print(json.dumps({
        "final_loss": log["losses"][-1],
        "remeshes": log["remesh_steps"],
        "devices_seen": sorted(set(log["device_counts"]), reverse=True),
    }))
""")


@pytest.mark.slow
def test_elastic_runner_survives_failures_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _ELASTIC], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(res["remeshes"]) == 2          # two injected failures
    assert res["devices_seen"] == [8, 4, 2]   # elastic shrink path
    assert res["final_loss"] < 1e-2           # training still converges


# ------------------------------------------------------------ robust agg (subprocess)
_ROBUST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.collective import shard_map
    from repro.runtime.robust_agg import robust_mean_grads

    mesh = jax.make_mesh((8,), ("data",))
    D = 32

    def per_replica(g):
        mean, (n_honest, flagged) = robust_mean_grads(
            {"w": g[0]}, "data", byzantine_budget=2)
        return mean["w"][None], jnp.stack([n_honest.astype(jnp.float32),
                                           flagged.astype(jnp.float32)])[None]

    fn = shard_map(per_replica, mesh, in_specs=P("data"),
                   out_specs=(P("data"), P("data")))
    rng = np.random.default_rng(0)
    base = rng.normal(size=D).astype(np.float32)
    grads = np.stack([base + rng.normal(scale=0.01, size=D).astype(np.float32)
                      for _ in range(8)])
    grads[5] = 1000.0  # corrupted replica
    mean, info = fn(jnp.asarray(grads))
    mean = np.asarray(mean)[0]
    info = np.asarray(info)
    err_robust = float(np.abs(mean - base).max())
    err_naive = float(np.abs(grads.mean(0) - base).max())
    print(json.dumps({"robust": err_robust, "naive": err_naive,
                      "honest": float(info[0, 0]),
                      "flagged5": float(info[5, 1])}))
""")


@pytest.mark.slow
def test_robust_aggregation_masks_byzantine_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _ROBUST], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flagged5"] == 1.0             # the corrupted replica is caught
    # k-means-- flags at most byzantine_budget replicas; the significance
    # gate may keep or drop the second (borderline honest) one
    assert res["honest"] >= 6.0
    assert res["robust"] < 0.05               # paper primitive fixes the mean
    assert res["naive"] > 10.0                # naive averaging is destroyed
