"""Streaming subsystem: weighted summaries, merge-and-reduce tree, service.

Merge-semantics coverage demanded by the subsystem's correctness argument
(see repro/stream/__init__.py):
  * mass conservation through summarize / merge / re-summarize,
  * ingest order cannot change the total-weight invariant,
  * the tree's quantization loss stays within a constant factor of the
    one-shot summary_outliers loss on the same data.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import information_loss, summary_outliers
from repro.data.synthetic import gauss
from repro.kernels.pdist.ops import min_argmin
from repro.stream import (ServiceConfig, StreamService, StreamTree,
                          TreeConfig, merge_summaries, record_cap,
                          resummarize, weighted_summary_outliers)


def _mk(n, d, seed, spread=1.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)) * spread).astype(np.float32)


# --------------------------------------------------------- weighted summary
def test_weighted_unit_invariants():
    x = _mk(1500, 4, 0)
    s = weighted_summary_outliers(x, np.ones(1500), jax.random.key(0),
                                  k=8, t=30)
    np.testing.assert_allclose(float(s.weights.sum()), 1500, rtol=1e-6)
    assert float(s.weights[s.is_candidate].sum()) <= 8 * 30
    assert (s.weights > 0).all()
    assert s.points.shape[0] < 1500  # actually compressed


def test_weighted_mass_conservation_arbitrary_weights():
    rng = np.random.default_rng(1)
    x = _mk(800, 3, 1)
    w = rng.uniform(0.5, 5.0, size=800).astype(np.float32)
    s = weighted_summary_outliers(x, w, jax.random.key(1), k=6, t=20)
    np.testing.assert_allclose(float(s.weights.sum()), float(w.sum()),
                               rtol=1e-5)


def test_weighted_record_acts_like_duplicates():
    """Summarizing (x, w) and the explicitly duplicated dataset must agree
    on the conserved mass and produce summaries of similar size."""
    rng = np.random.default_rng(2)
    pts = _mk(400, 3, 2)
    w = rng.integers(1, 5, size=400).astype(np.float32)
    dup = np.repeat(pts, w.astype(int), axis=0)
    s_w = weighted_summary_outliers(pts, w, jax.random.key(3), k=5, t=10)
    s_d = weighted_summary_outliers(dup, np.ones(dup.shape[0]),
                                    jax.random.key(3), k=5, t=10)
    np.testing.assert_allclose(float(s_w.weights.sum()),
                               float(s_d.weights.sum()), rtol=1e-5)


def test_weighted_duplicates_keep_weights_positive():
    """Coincident rows tie on argmin; the losing twin must not surface as a
    zero-weight record (regression)."""
    base = _mk(50, 3, 11)
    dup = np.repeat(base, 40, axis=0)
    s = weighted_summary_outliers(dup, np.ones(dup.shape[0]),
                                  jax.random.key(11), k=5, t=10)
    assert (s.weights > 0).all()
    np.testing.assert_allclose(float(s.weights.sum()), dup.shape[0],
                               rtol=1e-6)


# --------------------------------------------------------- merge semantics
def test_merge_preserves_total_weight():
    x = _mk(2000, 4, 3)
    s1 = weighted_summary_outliers(x[:900], np.ones(900), jax.random.key(4),
                                   k=8, t=25)
    s2 = weighted_summary_outliers(x[900:], np.ones(1100), jax.random.key(5),
                                   k=8, t=25)
    m = merge_summaries([s1, s2])
    np.testing.assert_allclose(float(m.weights.sum()), 2000, rtol=1e-6)
    r = resummarize([s1, s2], jax.random.key(6), k=8, t=25)
    np.testing.assert_allclose(float(r.weights.sum()), 2000, rtol=1e-5)
    # reducing a union really reduces it
    assert r.points.shape[0] <= m.points.shape[0]


def test_tree_ingest_order_weight_invariant():
    x = _mk(4096, 3, 4)
    batches = [x[i:i + 512] for i in range(0, 4096, 512)]
    totals = []
    for perm_seed in (0, 1):
        order = np.random.default_rng(perm_seed).permutation(len(batches))
        tree = StreamTree(TreeConfig(dim=3, k=6, t=20, leaf_size=512))
        for b in order:
            tree.ingest(batches[b])
        totals.append(tree.total_weight)
        assert len(tree.nodes) <= 4  # binary counter: O(log) summaries
    np.testing.assert_allclose(totals[0], 4096, rtol=1e-6)
    np.testing.assert_allclose(totals[0], totals[1], rtol=1e-6)


def test_tree_loss_within_constant_of_oneshot():
    """Quantization loss of the tree root vs one-shot Algorithm 1 loss."""
    x, _ = gauss(n_centers=8, per_center=500, t=40, sigma=0.1, seed=5)
    tree = StreamTree(TreeConfig(dim=5, k=8, t=40, leaf_size=512))
    tree.ingest(x)
    pts, _, _ = tree.root()
    d_tree, _ = min_argmin(jnp.asarray(x), jnp.asarray(pts), metric="l2sq")
    tree_loss = float(jnp.sum(d_tree))
    summ = summary_outliers(jnp.asarray(x), jax.random.key(0), k=8, t=40)
    oneshot = float(information_loss(jnp.asarray(x), summ.sigma))
    assert oneshot > 0
    # merge-and-reduce compounds one Algorithm-1 loss term per level
    # (O(log n) here); 25x leaves generous slack over the observed ~2-4x.
    assert tree_loss <= 25.0 * oneshot


def test_tree_sliding_window_evicts():
    x = _mk(8192, 3, 6)
    tree = StreamTree(TreeConfig(dim=3, k=5, t=10, leaf_size=512,
                                 window=2048))
    tree.ingest(x)
    # everything older than the window is gone: remaining mass <= window
    # (+ one eviction-granularity slack unit of window//4)
    assert tree.total_weight <= 2048 + 512
    oldest = min(nd.min_seq for nd in tree.nodes)
    assert oldest >= 8192 - 2048 - 2048 // 4


def test_tree_rejects_mismatched_weights():
    tree = StreamTree(TreeConfig(dim=3, k=5, t=10, leaf_size=256))
    with pytest.raises(ValueError):
        tree.ingest(_mk(10, 3, 20), np.ones(20))   # silent truncation risk
    with pytest.raises(ValueError):
        tree.ingest(_mk(10, 3, 20), np.ones(4))
    assert tree.total_ingested == 0


def test_tree_checkpoint_state_roundtrip():
    cfg = TreeConfig(dim=4, k=6, t=15, leaf_size=256)
    tree = StreamTree(cfg)
    tree.ingest(_mk(1500, 4, 7))
    state = tree.pack_state()
    tree2 = StreamTree.from_state(cfg, state)
    p1, w1, c1 = tree.root()
    p2, w2, c2 = tree2.root()
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(w1, w2)
    np.testing.assert_array_equal(c1, c2)
    assert tree2.total_ingested == tree.total_ingested
    # restored tree keeps ingesting with the same rng stream
    tree.ingest(_mk(600, 4, 8))
    tree2.ingest(_mk(600, 4, 8))
    np.testing.assert_allclose(tree.total_weight, tree2.total_weight,
                               rtol=1e-6)
    np.testing.assert_array_equal(tree.root()[0], tree2.root()[0])


def test_record_cap_bounds_every_node():
    cfg = TreeConfig(dim=3, k=6, t=12, leaf_size=256)
    cap = record_cap(cfg)
    tree = StreamTree(cfg)
    tree.ingest(_mk(4096, 3, 9))
    for nd in tree.nodes:
        assert nd.summary.points.shape[0] <= cap


def test_record_cap_tightens_under_window():
    base = dict(dim=3, k=6, t=12, leaf_size=256)
    full = record_cap(TreeConfig(**base))
    # windowed live mass is tiny next to the 2^34 stream bound -> fewer
    # summarizer rounds -> smaller per-summary cap -> smaller checkpoints
    assert record_cap(TreeConfig(**base, window=4096)) < full
    # too few checkpoint slots: force-merge can fire and pile unbounded
    # mass into one summary, so the tightening must NOT apply
    assert record_cap(
        TreeConfig(**base, window=4096, max_summaries=4)) == full
    # the tightened cap still bounds every node on a real windowed run
    cfg = TreeConfig(**base, window=2048)
    cap = record_cap(cfg)
    tree = StreamTree(cfg)
    tree.ingest(_mk(8192, 3, 9))
    assert tree.nodes
    for nd in tree.nodes:
        assert nd.summary.points.shape[0] <= cap


# --------------------------------------------------------- service
@pytest.fixture(scope="module")
def served():
    x, out_ids = gauss(n_centers=6, per_center=400, t=24, sigma=0.05, seed=10)
    cfg = ServiceConfig(dim=5, k=6, t=24, leaf_size=512, refresh_every=1024,
                        micro_batch=64, seed=10)
    svc = StreamService(cfg)
    svc.ingest(x)
    return svc, cfg, x, out_ids


def test_service_refresh_cadence(served):
    svc, _, x, _ = served
    # 2400 points / refresh_every=1024 -> at least 2 refreshes happened
    assert int(svc.model.version) >= 2
    assert float(svc.model.trained_weight) > 0


def test_service_scores_inliers_vs_planted_far_point(served):
    svc, _, x, out_ids = served
    inlier_ids = np.setdiff1d(np.arange(x.shape[0]), out_ids)[:64]
    res = svc.score(x[inlier_ids])
    assert len(res) == 64
    flagged = sum(r.is_outlier for r in res)
    assert flagged <= 8  # the bulk of the clusters scores as inliers
    far = svc.score(np.full((1, 5), 100.0, np.float32))[0]
    assert far.is_outlier and far.outlier_score > 10
    stats = svc.latency_stats()
    assert stats["count"] >= 65 and np.isfinite(stats["p99_ms"])


def test_service_drain_is_fifo_and_complete(served):
    svc, _, x, _ = served
    ids = svc.submit(x[:150])
    res = svc.drain()
    assert [r.request_id for r in res] == ids
    assert svc.drain() == []


def test_service_submit_rejects_bad_dim(served):
    svc, _, x, _ = served
    with pytest.raises(ValueError):
        svc.submit(np.zeros((2, 3), np.float32))  # dim is 5
    # queue untouched: valid requests still serve
    assert len(svc.score(x[:4])) == 4


def test_async_refresh_same_model_as_blocking():
    """The fit is a pure function of (root snapshot, version, key): an async
    refresh from the same boundary must produce the identical model."""
    x = _mk(3000, 4, 30)
    kw = dict(dim=4, k=5, t=15, leaf_size=512, refresh_every=10**6, seed=7)
    sync = StreamService(ServiceConfig(**kw))
    async_ = StreamService(ServiceConfig(**kw, async_refresh=True))
    sync.ingest(x)
    async_.ingest(x)
    m_sync = sync.refresh()
    async_.refresh(blocking=False)
    assert async_.refresh_in_flight or async_.model is not None
    async_.join_refresh()
    m_async = async_.model
    assert int(m_async.version) == int(m_sync.version) == 1
    np.testing.assert_array_equal(np.asarray(m_sync.centers),
                                  np.asarray(m_async.centers))
    assert float(m_sync.threshold) == float(m_async.threshold)


def test_async_refresh_cadence_coalesces_and_serves():
    """Cadence refreshes under async_refresh must never block ingest, must
    coalesce while one fit is in flight, and drain() must wait for the
    first model instead of erroring."""
    x = _mk(4096, 3, 31)
    svc = StreamService(ServiceConfig(dim=3, k=4, t=10, leaf_size=256,
                                      refresh_every=1024, seed=8,
                                      async_refresh=True))
    svc.ingest(x)          # 4 cadence boundaries -> >= 1 fit + coalesced rest
    res = svc.score(x[:32])    # drain joins the first in-flight fit
    assert len(res) == 32
    svc.join_refresh()
    assert int(svc.model.version) >= 1
    assert not svc.refresh_in_flight
    # a blocking refresh after the dust settles still works and bumps
    v = int(svc.model.version)
    assert int(svc.refresh().version) == v + 1


def test_async_refresh_snapshot_error_raises_on_caller():
    svc = StreamService(ServiceConfig(dim=3, k=4, t=10, leaf_size=256,
                                      async_refresh=True))
    with pytest.raises(RuntimeError, match="before any point"):
        svc.refresh(blocking=False)   # snapshot happens on the caller


def test_service_ingest_after_restore_with_smaller_cadence(tmp_path):
    """A checkpoint may carry since_refresh >= the restoring config's
    refresh_every; ingest must refresh instead of slicing backwards."""
    from repro.checkpoint.manager import CheckpointManager
    x = _mk(1600, 3, 12)
    big = ServiceConfig(dim=3, k=4, t=8, leaf_size=256, refresh_every=4096)
    svc = StreamService(big)
    svc.ingest(x)   # since_refresh = 1600, no refresh yet
    svc.save(CheckpointManager(tmp_path), step=1)
    small = ServiceConfig(dim=3, k=4, t=8, leaf_size=256, refresh_every=1024)
    restored = StreamService.restore(small, CheckpointManager(tmp_path))
    restored.ingest(x[:512])
    assert restored.tree.total_ingested == 1600 + 512
    np.testing.assert_allclose(restored.tree.total_weight, 2112, rtol=1e-6)
    assert int(restored.model.version) >= 1


def test_service_checkpoint_restore_identical_scores(served, tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    svc, cfg, x, _ = served
    q = x[64:128]
    before = svc.score(q)
    cm = CheckpointManager(tmp_path)
    svc.save(cm, step=1)
    restored = StreamService.restore(cfg, CheckpointManager(tmp_path))
    after = restored.score(q)
    assert int(restored.model.version) == int(svc.model.version)
    for a, b in zip(before, after):
        assert a.center == b.center
        assert a.distance == b.distance          # bit-identical
        assert a.outlier_score == b.outlier_score
    # the restored service can keep serving the write path too
    restored.ingest(x[:512])
    assert restored.tree.total_ingested == svc.tree.total_ingested + 512
