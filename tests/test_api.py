"""PipelineConfig + Session facade: the one front door over every topology.

Contracts under test:
  * ``PipelineConfig`` serialization is exact — ``from_dict(to_dict(c))``
    and the full JSON round-trip reproduce an equal config (hypothesis
    fuzzes the valid space when installed), and invalid problem/topology
    combinations raise at construction, not at run time;
  * ``ServiceConfig`` / ``ShardedServiceConfig`` stay field-compatible
    through the shared ``BaseServiceConfig`` (no duplicated drifting
    fields);
  * ``Session`` adds no math: results are bit-identical to driving
    ``simulate_coordinator`` / ``distributed_cluster`` / ``StreamService``
    / ``ShardedStreamService`` directly with equivalent settings;
  * ``Session.save`` embeds the serialized config in the checkpoint
    manifest and ``Session.load`` reconstructs topology + policies from
    the checkpoint alone, with bit-identical post-restore scores, for all
    three topologies.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional: only the fuzz tests need hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.api import PipelineConfig, Session, pipeline_config
from repro.core import distributed_cluster, simulate_coordinator
from repro.data.synthetic import gauss
from repro.kernels.dispatch import KernelPolicy
from repro.stream import (BaseServiceConfig, ServiceConfig,
                          ShardedServiceConfig, ShardedStreamService,
                          StreamService)
from repro.summarize import summarizer_policy


@pytest.fixture(scope="module")
def data():
    return gauss(n_centers=4, per_center=250, d=3, t=12, sigma=0.1, seed=0)


def _same_scores(a, b):
    assert len(a) == len(b)
    for p, r in zip(a, b):
        assert p.center == r.center
        assert p.distance == r.distance
        assert p.outlier_score == r.outlier_score


# ------------------------------------------------------------- serialization
def _configs():
    return [
        pipeline_config(dim=3, k=4, t=12),
        pipeline_config(dim=3, k=4, t=12, sites=5, partition="adversarial",
                        metric="l1", seed=9),
        pipeline_config(dim=5, k=2, t=0, topology="stream", leaf_size=128,
                        refresh_every=512, window=4096,
                        summarizer="uniform", kernels="blocked"),
        pipeline_config(dim=2, k=3, t=7, topology="sharded", sites=3,
                        site_budget="paper", async_refresh=True,
                        micro_batch=64,
                        summarizer=summarizer_policy("coreset", budget=64),
                        kernels=KernelPolicy(backend="ref", block_n=256)),
    ]


@pytest.mark.parametrize("idx", range(4))
def test_dict_and_json_round_trip_is_exact(idx):
    cfg = _configs()[idx]
    assert PipelineConfig.from_dict(cfg.to_dict()) == cfg
    # through real JSON text: tuples become lists, None becomes null —
    # from_dict must invert all of it
    assert PipelineConfig.from_json(cfg.to_json()) == cfg


def test_serialized_config_is_concrete():
    """A config never serializes a 'process default' placeholder: the
    policies captured at construction appear in the dict."""
    d = pipeline_config(dim=3, k=4, t=12).to_dict()
    assert d["summarizer"]["name"] == "auto"
    assert d["kernels"]["backend"] == "auto"
    assert set(d) == {"version", "problem", "topology", "summarizer",
                      "kernels", "second_iters", "seed"}


def test_from_dict_rejects_unknown_and_missing_keys():
    good = pipeline_config(dim=3, k=4, t=12).to_dict()
    with pytest.raises(ValueError, match="unknown config keys"):
        PipelineConfig.from_dict({**good, "extra": 1})
    with pytest.raises(ValueError, match="unknown topology keys"):
        PipelineConfig.from_dict(
            {**good, "topology": {**good["topology"], "n_sites": 2}})
    with pytest.raises(ValueError, match="missing"):
        PipelineConfig.from_dict({k: v for k, v in good.items()
                                  if k != "problem"})
    with pytest.raises(ValueError, match="version"):
        PipelineConfig.from_dict({**good, "version": 99})


@pytest.mark.parametrize("bad", [
    dict(dim=0, k=4, t=10),
    dict(dim=3, k=0, t=10),
    dict(dim=3, k=4, t=-1),
    dict(dim=3, k=4, t=10, metric="chebyshev"),
    dict(dim=3, k=4, t=10, topology="ring"),
    dict(dim=3, k=4, t=10, topology="stream", sites=3),
    dict(dim=3, k=4, t=10, window=100),                       # oneshot window
    dict(dim=3, k=4, t=10, async_refresh=True),               # oneshot async
    dict(dim=3, k=4, t=10, refresh_every=4096),               # oneshot cadence
    dict(dim=3, k=4, t=10, leaf_size=512),                    # oneshot leaf
    dict(dim=3, k=4, t=10, topology="stream", partition="adversarial"),
    dict(dim=3, k=4, t=10, topology="stream", site_budget="paper"),
    dict(dim=3, k=4, t=10, topology="stream", use_shard_map=True),
    dict(dim=3, k=4, t=10, topology="sharded", sites=0),
    dict(dim=3, k=4, t=10, topology="stream", window=0),
    dict(dim=3, k=4, t=10, summarizer="nope"),
    dict(dim=3, k=4, t=10, use_shard_map=True,
         summarizer="ball_cover"),                            # host-driven
    dict(dim=3, k=4, t=10,                                    # KernelPolicy
         kernels={"backend": "auto", "block_n": 0}),          # rejects it
])
def test_invalid_configs_raise_at_construction(bad):
    with pytest.raises(ValueError):
        pipeline_config(**bad)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_fuzzed_valid_configs_round_trip():
    kinds = st.sampled_from(["oneshot", "stream", "sharded"])

    @st.composite
    def configs(draw):
        kind = draw(kinds)
        topo = {"kind": kind}
        if kind == "sharded":
            topo["sites"] = draw(st.integers(1, 8))
            topo["site_budget"] = draw(st.sampled_from(["full", "paper"]))
        if kind == "oneshot":
            topo["sites"] = draw(st.integers(1, 8))
            topo["partition"] = draw(
                st.sampled_from(["random", "adversarial"]))
        else:
            topo["refresh_every"] = draw(st.integers(1, 1 << 20))
            topo["leaf_size"] = draw(st.integers(1, 1 << 16))
            topo["window"] = draw(
                st.one_of(st.none(), st.integers(1, 1 << 20)))
            topo["async_refresh"] = draw(st.booleans())
        topo["micro_batch"] = draw(st.integers(1, 4096))
        return pipeline_config(
            dim=draw(st.integers(1, 64)),
            k=draw(st.integers(1, 32)),
            t=draw(st.integers(0, 1000)),
            metric=draw(st.sampled_from(["l2sq", "l2", "l1", "cosine"])),
            topology=kind,
            summarizer=draw(st.sampled_from(
                ["auto", "paper", "uniform", "ball_cover", "coreset"])),
            kernels=KernelPolicy(
                backend=draw(st.sampled_from(
                    ["auto", "pallas", "blocked", "ref"])),
                block_n=draw(st.one_of(st.none(),
                                       st.integers(1, 1 << 20))),
                autotune=draw(st.booleans())),
            second_iters=draw(st.integers(1, 100)),
            seed=draw(st.integers(-2**31, 2**31 - 1)),
            **topo)

    @settings(max_examples=60, deadline=None)
    @given(cfg=configs())
    def run(cfg):
        assert PipelineConfig.from_dict(cfg.to_dict()) == cfg
        assert PipelineConfig.from_json(
            json.dumps(json.loads(cfg.to_json()))) == cfg

    run()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_fuzzed_invalid_scalars_raise():
    @settings(max_examples=40, deadline=None)
    @given(dim=st.integers(-5, 0), k=st.integers(-5, 0),
           t=st.integers(-5, -1))
    def run(dim, k, t):
        for bad in (dict(dim=dim, k=4, t=10), dict(dim=3, k=k, t=10),
                    dict(dim=3, k=4, t=t)):
            with pytest.raises(ValueError):
                pipeline_config(**bad)

    run()


# --------------------------------------------------------- config field dedup
def test_stream_configs_stay_field_compatible_through_base():
    """The sharded config is the base config plus topology-only fields —
    asserting here means a field added to one serving config cannot
    silently drift from the other again."""
    base = {f.name: f for f in dataclasses.fields(BaseServiceConfig)}
    single = {f.name: f for f in dataclasses.fields(ServiceConfig)}
    sharded = {f.name: f for f in dataclasses.fields(ShardedServiceConfig)}
    assert issubclass(ServiceConfig, BaseServiceConfig)
    assert issubclass(ShardedServiceConfig, BaseServiceConfig)
    # the single-host config is exactly the shared base ...
    assert set(single) == set(base)
    # ... and every shared field agrees in type and default on both sides
    assert set(base) <= set(sharded)
    for name, f in base.items():
        for other in (single[name], sharded[name]):
            assert other.type == f.type, name
            assert other.default == f.default or (
                f.default is dataclasses.MISSING
                and other.default is dataclasses.MISSING), name
    # sharded extras are topology-only
    assert set(sharded) - set(base) == {"n_sites", "site_budget",
                                        "use_shard_map"}


def test_pipeline_projects_onto_stream_configs():
    cfg = pipeline_config(dim=3, k=4, t=12, topology="sharded", sites=3,
                          leaf_size=256, refresh_every=1024, window=8192,
                          site_budget="paper", seed=7)
    sc = cfg.sharded_config()
    assert (sc.dim, sc.k, sc.t, sc.n_sites) == (3, 4, 12, 3)
    assert (sc.leaf_size, sc.refresh_every, sc.window) == (256, 1024, 8192)
    assert sc.site_budget == "paper" and sc.seed == 7
    assert sc.policy == cfg.kernels and sc.summarizer == cfg.summarizer
    with pytest.raises(ValueError, match="stream"):
        cfg.service_config()


# ------------------------------------------------------- session bit-identity
def test_oneshot_session_matches_simulate_coordinator(data):
    x, _ = data
    cfg = pipeline_config(dim=3, k=4, t=12, sites=3, seed=5)
    sess = Session(cfg)
    sess.fit(x)
    direct = simulate_coordinator(
        np.array_split(x, 3), jax.random.key(5), k=4, t=12,
        summarizer=cfg.summarizer, policy=cfg.kernels)
    assert (sess.result["centers"] == direct["centers"]).all()
    assert (sess.result["outlier_ids"] == direct["outlier_ids"]).all()
    assert (sess.result["summary_ids"] == direct["summary_ids"]).all()
    assert sess.result["cost"] == direct["cost"]
    assert sess.result["comm_records"] == direct["comm_records"]


def test_oneshot_session_matches_distributed_cluster_shard_map(data):
    x, _ = data
    cfg = pipeline_config(dim=3, k=4, t=12, sites=1, use_shard_map=True,
                          seed=5)
    sess = Session(cfg)
    sess.fit(x)
    res = distributed_cluster(
        jnp.asarray(x)[None], jax.random.key(5),
        jax.make_mesh((1,), ("sites",)), k=4, t=12,
        summarizer=cfg.summarizer, policy=cfg.kernels)
    assert (sess.result["centers"] == np.asarray(res.centers)).all()
    out = np.asarray(res.outlier_ids)
    assert (sess.result["outlier_ids"] == out[out >= 0]).all()
    assert sess.result["cost"] == float(res.cost)


# On a real multi-device mesh the oneshot Session's use_shard_map path
# must still add no math of its own: same key, same mesh => results equal
# driving distributed_cluster directly, bit for bit — and a save/load of
# that session must re-score bitwise.  Mirrors _SHARD_MAP_EQ in
# tests/test_stream_sharded.py: forced 4-device CPU in a subprocess
# because XLA_FLAGS must be set before jax initializes.
_ONESHOT_SHARD_MAP_EQ = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_DEFAULT_PRNG_IMPL"] = "threefry2x32"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.api import Session, pipeline_config
    from repro.core import distributed_cluster
    from repro.core.collective import sites_mesh
    from repro.data.synthetic import gauss

    x, _ = gauss(n_centers=4, per_center=500, d=3, t=16, sigma=0.05,
                 seed=11)
    x = x[: (len(x) // 4) * 4].astype(np.float32)
    cfg = pipeline_config(dim=3, k=4, t=16, sites=4, use_shard_map=True,
                          seed=11)
    sess = Session(cfg)
    sess.fit(x)
    res = distributed_cluster(
        jnp.asarray(x).reshape(4, -1, 3), jax.random.key(11),
        sites_mesh(4), k=4, t=16, summarizer=cfg.summarizer,
        policy=cfg.kernels)
    out = np.asarray(res.outlier_ids)
    q = x[:64]
    before = sess.score(q)
    with tempfile.TemporaryDirectory() as ckpt:
        sess.save(ckpt)
        after = Session.load(ckpt).score(q)
    print(json.dumps({
        "n_devices": len(jax.devices()),
        "centers_equal": bool(np.array_equal(
            sess.result["centers"], np.asarray(res.centers))),
        "cost_equal": sess.result["cost"] == float(res.cost),
        "outliers_equal": bool(np.array_equal(
            sess.result["outlier_ids"], out[out >= 0])),
        "reload_scores_equal": all(
            a.center == b.center and a.distance == b.distance
            and a.outlier_score == b.outlier_score
            for a, b in zip(before, after)),
    }))
""")


@pytest.mark.slow
def test_oneshot_shard_map_session_bit_identical_multi_device_subprocess():
    """Real 4-device shard_map oneshot Session == direct
    distributed_cluster on the same mesh, bitwise (plus save/load)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _ONESHOT_SHARD_MAP_EQ],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 4
    assert res["centers_equal"] and res["cost_equal"]
    assert res["outliers_equal"] and res["reload_scores_equal"]


def test_stream_session_matches_stream_service(data):
    x, _ = data
    q = x[:9]
    cfg = pipeline_config(dim=3, k=4, t=12, topology="stream",
                          leaf_size=256, refresh_every=512)
    sess = Session(cfg)
    sess.ingest(x)
    sess.refresh()
    svc = StreamService(ServiceConfig(dim=3, k=4, t=12, leaf_size=256,
                                      refresh_every=512))
    svc.ingest(x)
    svc.refresh()
    assert int(sess.model.version) == int(svc.model.version)
    _same_scores(sess.score(q), svc.score(q))


def test_sharded_session_matches_sharded_service(data):
    x, _ = data
    q = x[:9]
    cfg = pipeline_config(dim=3, k=4, t=12, topology="sharded", sites=3,
                          leaf_size=256, refresh_every=512)
    sess = Session(cfg)
    sess.ingest(x)
    sess.refresh()
    svc = ShardedStreamService(ShardedServiceConfig(
        dim=3, k=4, t=12, n_sites=3, leaf_size=256, refresh_every=512))
    svc.ingest(x)
    svc.refresh()
    _same_scores(sess.score(q), svc.score(q))
    # comm accounting surfaces through the engine escape hatch
    assert sess.engine.last_refresh.comm_records == \
        svc.last_refresh.comm_records


def test_config_json_round_trip_preserves_behavior(data):
    """to_dict -> JSON -> from_dict -> Session behaves identically."""
    x, _ = data
    q = x[:9]
    cfg = pipeline_config(dim=3, k=4, t=12, topology="stream",
                          leaf_size=256, refresh_every=512, seed=3)
    rt = PipelineConfig.from_json(cfg.to_json())
    assert rt == cfg
    a, b = Session(cfg), Session(rt)
    for s in (a, b):
        s.ingest(x)
        s.refresh()
    _same_scores(a.score(q), b.score(q))


def test_oneshot_refresh_is_pure(data):
    """Refreshing with no new data reproduces the model bit for bit (the
    oneshot fit is a function of the ingested points and the seed)."""
    x, _ = data
    sess = Session(pipeline_config(dim=3, k=4, t=12, sites=2))
    m1 = sess.fit(x)
    m2 = sess.refresh()
    assert (np.asarray(m1.centers) == np.asarray(m2.centers)).all()
    assert float(m1.threshold) == float(m2.threshold)
    assert int(m2.version) == int(m1.version) + 1


# ------------------------------------------------------------ session errors
def test_session_error_surface(data):
    x, _ = data
    sess = Session(pipeline_config(dim=3, k=4, t=12))
    with pytest.raises(RuntimeError, match="refresh"):
        sess.score(x[:2])
    with pytest.raises(ValueError, match="unit-weight"):
        sess.ingest(x[:4], np.ones(4))
    with pytest.raises(ValueError, match="sharded"):
        sess.ingest(x[:4], site=0)
    with pytest.raises(ValueError, match="(n, 3)"):
        sess.ingest(x[:4, :2])
    sharded = Session(pipeline_config(dim=3, k=4, t=12, topology="sharded",
                                      sites=2))
    sharded.ingest(x[:4], site=1)   # pinned routing reaches site 1
    assert sharded.engine.trees[1].total_ingested == 4


# ------------------------------------------------------------- save / load
@pytest.mark.parametrize("kind", ["oneshot", "stream", "sharded"])
def test_save_load_score_bit_identical(tmp_path, data, kind):
    x, _ = data
    q = x[:9]
    kw = dict(dim=3, k=4, t=12, topology=kind)
    if kind != "oneshot":
        kw.update(leaf_size=256, refresh_every=512)
    if kind == "sharded":
        kw.update(sites=3)
    cfg = pipeline_config(**kw)
    sess = Session(cfg)
    sess.fit(x)
    before = sess.score(q)
    step = sess.save(tmp_path)
    restored = Session.load(tmp_path, step=step)
    # topology + policies came from the manifest alone
    assert restored.config == cfg
    _same_scores(before, restored.score(q))
    if kind == "oneshot":
        # the coordinator detail survives the round trip too
        for key in ("centers", "outlier_ids", "summary_ids",
                    "summary_weights"):
            assert (restored.result[key] == sess.result[key]).all(), key
        assert restored.result["cost"] == sess.result["cost"]
        assert restored.result["comm_records"] == \
            sess.result["comm_records"]
    # the restored session keeps working: ingest more, refresh, score
    restored.ingest(x[:64])
    restored.refresh()
    assert int(restored.model.version) == int(sess.model.version) + 1


def test_load_refuses_checkpoint_without_embedded_config(tmp_path, data):
    x, _ = data
    svc = StreamService(ServiceConfig(dim=3, k=4, t=12, leaf_size=256))
    svc.ingest(x[:512])
    svc.refresh()
    from repro.checkpoint.manager import CheckpointManager
    svc.save(CheckpointManager(tmp_path), step=1)
    with pytest.raises(ValueError, match="embedded pipeline config"):
        Session.load(tmp_path)


def test_save_load_weighted_ingest_stream(tmp_path, data):
    """Weighted records survive the facade round trip (stream topology)."""
    x, _ = data
    cfg = pipeline_config(dim=3, k=4, t=12, topology="stream",
                          leaf_size=256, refresh_every=512)
    sess = Session(cfg)
    sess.ingest(x[:600], np.full(600, 2.0, np.float32))
    sess.refresh()
    assert sess.engine.tree.total_weight == pytest.approx(1200.0)
    sess.save(tmp_path)
    assert Session.load(tmp_path).engine.tree.total_weight == \
        pytest.approx(1200.0)


# ------------------------------------------------------------------ CLI
def test_cli_run_and_load_round_trip(tmp_path, data, capsys):
    from repro.api.cli import main as cli_main

    artifact = {
        "pipeline": pipeline_config(dim=3, k=4, t=12, sites=2).to_dict(),
        "data": {"kind": "gauss", "n_centers": 4, "per_center": 250,
                 "d": 3, "t": 12, "sigma": 0.1, "seed": 0},
    }
    cfg_path = tmp_path / "run.json"
    cfg_path.write_text(json.dumps(artifact))
    save_dir = tmp_path / "ckpt"
    cli_main(["run", "--config", str(cfg_path), "--queries", "16",
              "--save", str(save_dir)])
    out = capsys.readouterr().out
    assert "ok" in out and "outliers:" in out
    restored = Session.load(save_dir)
    assert restored.config.topology.sites == 2
    assert restored.model is not None


def test_cli_rejects_bad_artifacts(tmp_path):
    from repro.api.cli import main as cli_main

    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"nope": 1}))
    with pytest.raises(SystemExit, match="pipeline"):
        cli_main(["run", "--config", str(p)])
    p.write_text(json.dumps({
        "pipeline": pipeline_config(dim=4, k=3, t=5).to_dict(),
        "data": {"kind": "gauss", "d": 3, "n_centers": 3, "per_center": 50,
                 "t": 5},
    }))
    with pytest.raises(SystemExit, match="dim"):
        cli_main(["run", "--config", str(p)])
