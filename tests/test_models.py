"""Per-arch smoke tests (reduced configs, CPU) + model-level correctness:
decode-vs-train consistency, WKV chunk oracle, RG-LRU scan-vs-step, MoE
dispatch semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models.layers import ShardCtx
from repro.models.rglru import rglru_block, rglru_layer_init
from repro.models.rwkv6 import wkv_chunked, wkv_recurrent
from repro.models.transformer import (forward_decode, forward_prefill,
                                      init_params)
from repro.optim import adamw

CTX = ShardCtx(mesh=None)
KEY = jax.random.key(0)


def _batch(cfg, B, S, key=KEY):
    nt = S - (cfg.frontend_tokens if cfg.family != "encdec"
              and cfg.frontend != "none" else 0)
    b = {"tokens": jax.random.randint(key, (B, nt), 0, cfg.vocab)}
    if cfg.frontend == "vlm_patches":
        b["patches"] = jax.random.normal(key, (B, cfg.frontend_tokens,
                                               cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "audio_frames":
        b["frames"] = jax.random.normal(key, (B, max(S // 4, 8),
                                              cfg.frontend_dim), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Assigned-arch smoke: REDUCED same-family config, one full train step
    (fwd+bwd+AdamW) on CPU; asserts shapes and no NaNs."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    step, optc = make_train_step(cfg, mesh=None)
    opt = adamw.init(params, optc)
    batch = _batch(cfg, 2, 64)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed and shapes preserved
    changed = jax.tree.map(lambda a, b: (a.shape == b.shape,
                                         bool((a != b).any())),
                           params, new_params)
    flags = jax.tree.leaves(changed, is_leaf=lambda x: isinstance(x, tuple))
    assert all(sh for sh, _ in flags)
    assert any(ch for _, ch in flags)
    assert int(new_opt.step) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "h2o-danube-1.8b", "rwkv6-7b",
                                  "recurrentgemma-9b", "qwen3-moe-235b-a22b",
                                  "seamless-m4t-medium"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(prompt) + decode(next) must equal the full forward on
    [prompt; next] — validates every cache layout."""
    cfg = get_config(arch, smoke=True)
    if cfg.family == "moe":
        # dropped-token MoE is dispatch-group-dependent; ample capacity makes
        # decode == teacher forcing exactly (no drops on either path)
        cfg = cfg.replace(capacity_factor=16.0)
    params = init_params(cfg, KEY)
    B, S = 2, 16
    key = jax.random.key(42)
    toks = jax.random.randint(key, (B, S + 1), 2, cfg.vocab)

    batch = _batch(cfg, B, S)
    batch["tokens"] = toks[:, :S]
    lg_prefill, cache = forward_prefill(params, batch, cfg, CTX, max_len=S + 8)
    lg_step, _ = forward_decode(params, cache, toks[:, S:S + 1], cfg, CTX)

    batch2 = dict(batch, tokens=toks)
    if cfg.family == "encdec":
        full_logits = _full_logits_encdec(params, batch2, cfg)
    else:
        full_logits = _full_logits(params, batch2, cfg)
    # prefill's last-token logits == teacher-forced logits at position S-1
    np.testing.assert_allclose(np.asarray(lg_prefill),
                               np.asarray(full_logits[:, -2, :]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lg_step),
                               np.asarray(full_logits[:, -1, :]),
                               rtol=2e-2, atol=2e-2)


def _full_logits(params, batch, cfg):
    from repro.models.layers import rmsnorm, unembed
    # teacher-forcing logits via the training forward path internals
    import repro.models.transformer as T
    x, _ = T._embed_inputs(params, batch, cfg, CTX)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.family == "dense":
        def body(c, lp):
            y, _ = T._dense_layer_train(lp, c, cfg, CTX, positions)
            return y, None
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "moe":
        def body(c, gp):
            for j in range(cfg.moe_every - 1):
                lp = jax.tree.map(lambda a: a[j], gp["dense"])
                c, _ = T._dense_layer_train(lp, c, cfg, CTX, positions)
            c, _ = T._dense_layer_train(gp["moe"], c, cfg, CTX, positions)
            return c, None
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "rwkv6":
        from repro.models.rwkv6 import rwkv_block
        def body(c, lp):
            y, _ = rwkv_block(lp, c, cfg, CTX)
            return y, None
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "rglru_hybrid":
        def rec_body(c, lp):
            y, _ = rglru_block(lp["rec"], c, cfg, CTX)
            y, _ = T._ffn(lp, y, cfg, CTX)
            return y, None
        def group_body(c, gp):
            c, _ = jax.lax.scan(rec_body, c, gp["recs"])
            c, _ = T._dense_layer_train(gp["attn"], c, cfg, CTX, positions,
                                        window=cfg.local_window)
            return c, None
        x, _ = jax.lax.scan(group_body, x, params["groups"])
        if "tail" in params:
            x, _ = jax.lax.scan(rec_body, x, params["tail"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["lm_head"], x, CTX)


def _full_logits_encdec(params, batch, cfg):
    """Teacher-forcing decoder logits for the enc-dec family."""
    import repro.models.transformer as T
    from repro.models.layers import kv_proj, rmsnorm, unembed
    frames, tokens = batch["frames"], batch["tokens"]
    x_enc = frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend"]["proj"]
    pos_e = jnp.arange(x_enc.shape[1], dtype=jnp.int32)

    def enc_body(c, lp):
        y, _ = T._dense_layer_train(lp, c, cfg, CTX, pos_e, causal=False)
        return y, None
    x_enc, _ = jax.lax.scan(enc_body, x_enc, params["enc_layers"])
    x_enc = rmsnorm(params["final_norm"], x_enc, cfg.norm_eps)

    x = T.embed(params["embed"], tokens)
    pos_d = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def dec_body(c, lp):
        ck, cv = kv_proj(lp["xattn"], x_enc, cfg, pos_e, use_rope=False)
        y, _ = T._dense_layer_train(lp, c, cfg, CTX, pos_d,
                                    enc_kv=(ck, cv, pos_e, None))
        return y, None
    x, _ = jax.lax.scan(dec_body, x, params["dec_layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["lm_head"], x, CTX)


def test_wkv_chunked_matches_recurrent_extreme_decays():
    rng = np.random.default_rng(0)
    B, T, H, K = 2, 64, 2, 8
    args = [jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
            for _ in range(3)]
    lw = jnp.asarray(-np.exp(rng.uniform(-8, 4, size=(B, T, H, K))), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, K, K)), jnp.float32)
    oc, sc = wkv_chunked(*args, lw, u, s0, 16)
    orr, sr = wkv_recurrent(*args, lw, u, s0)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(orr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr), atol=1e-4)


def test_rglru_scan_matches_stepwise():
    cfg = get_config("recurrentgemma-9b", smoke=True)
    p = rglru_layer_init(jax.random.key(3), cfg, jnp.float32)
    B, T = 2, 12
    x = jax.random.normal(jax.random.key(4), (B, T, cfg.d_model), jnp.float32)
    y_scan, st_scan = rglru_block(p, x, cfg, CTX)
    # step one token at a time
    st = None
    ys = []
    for t in range(T):
        y, st = rglru_block(p, x[:, t:t + 1], cfg, CTX, state=st)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_scan["h"]), np.asarray(st["h"]),
                               rtol=1e-4, atol=1e-4)


def test_moe_routes_and_conserves():
    from repro.models.moe import moe_ffn, moe_init
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    y, aux = moe_ffn(p, x, cfg, CTX)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["aux_loss"]) >= 0.99  # >= 1 at balance, finite
    assert 0.0 <= float(aux["drop_frac"]) < 0.8


def test_moe_capacity_drops_when_unbalanced():
    from repro.models.moe import moe_ffn, moe_init
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True).replace(
        capacity_factor=0.25)
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    _, aux = moe_ffn(p, x, cfg, CTX)
    assert float(aux["drop_frac"]) > 0.0


def test_sliding_window_limits_attention():
    """With SWA, logits at position t must not depend on tokens more than
    n_layers * window behind (the stacked receptive field)."""
    cfg = get_config("h2o-danube-1.8b", smoke=True).replace(sliding_window=8)
    params = init_params(cfg, KEY)  # 2 layers x window 8 -> receptive 16
    B, S = 1, 40
    t1 = jax.random.randint(jax.random.key(1), (B, S), 2, cfg.vocab)
    t2 = t1.at[:, 0:6].set(jax.random.randint(jax.random.key(2), (B, 6), 2, cfg.vocab))
    l1 = _full_logits(params, {"tokens": t1}, cfg)
    l2 = _full_logits(params, {"tokens": t2}, cfg)
    # last changed token 5; receptive field 2*8-1 -> identical from 5+16=21 on
    np.testing.assert_allclose(np.asarray(l1[:, 21:]), np.asarray(l2[:, 21:]),
                               rtol=1e-4, atol=1e-4)
    # near the start they differ
    assert np.abs(np.asarray(l1[:, 2]) - np.asarray(l2[:, 2])).max() > 1e-3


def test_rwkv_pallas_wkv_path_matches_jnp():
    """cfg.wkv_use_pallas routes through the Pallas chunk kernel with a
    custom VJP; forward and grads must match the jnp chunked path."""
    cfg = get_config("rwkv6-7b", smoke=True)
    from repro.models.rwkv6 import rwkv_block, rwkv_layer_init
    p = rwkv_layer_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    cfgp = cfg.replace(wkv_use_pallas=True)
    y1, _ = rwkv_block(p, x, cfg, CTX)
    y2, _ = rwkv_block(p, x, cfgp, CTX)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3)
    g1 = jax.grad(lambda xx: rwkv_block(p, xx, cfg, CTX)[0].sum())(x)
    g2 = jax.grad(lambda xx: rwkv_block(p, xx, cfgp, CTX)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=5e-3)
