"""Multi-host sharded streaming service: per-site trees + all_gather roots.

Coverage demanded by the subsystem's correctness argument (see
repro/stream/sharded.py):
  * the sharded refresh model reproduces the single-host tree's model on
    the same interleaved stream (same centers / outlier decisions up to
    permutation) while communicating only packed tree roots;
  * the shard_map collective path is bit-identical to the host-simulated
    gather (subprocess with forced multi-device CPU);
  * communication accounting matches the payload actually gathered;
  * globally-coherent outliers split across sites are still caught;
  * per-site checkpoint state round-trips, and a checkpoint cannot be
    silently restored onto a different site count;
  * sliding-window drift: a windowed (sharded) service tracks the newest
    concept phase, the full-stream one cannot.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import drifting_gauss
from repro.stream import (ServiceConfig, ShardedServiceConfig,
                          ShardedStreamService, StreamService)


def _lattice_stream(seed=0, k=6, d=5, per=700, t=30):
    """Well-separated clusters + scattered far outliers, shuffled into one
    stream.  Separation is what makes "same centers up to permutation" a
    well-posed assertion: both services must recover the true centers."""
    rng = np.random.default_rng(seed)
    true_c = np.eye(k, d) * 8.0 + np.arange(k)[:, None] * 0.5
    x = np.repeat(true_c, per, axis=0) + rng.normal(0, 0.05, (k * per, d))
    out = rng.uniform(-1, 1, (t, d))
    out = out / np.linalg.norm(out, axis=1, keepdims=True) \
        * rng.uniform(15, 25, (t, 1)) + 4.0
    x = np.concatenate([x, out]).astype(np.float32)
    order = rng.permutation(x.shape[0])
    planted = np.nonzero(order >= k * per)[0]
    return x[order], planted, true_c


def _covers_true_centers(model, true_c, tol=0.5):
    c = np.asarray(model.centers)
    dist = np.linalg.norm(c[:, None] - true_c[None], axis=-1)
    nearest = dist.argmin(1)
    return (len(set(nearest)) == true_c.shape[0]
            and float(dist.min(1).max()) < tol)


# ------------------------------------------------- sharded == single host
def test_sharded_matches_single_host_interleaved():
    """Acceptance: s=4 sites on an interleaved stream reproduce the
    single-host refresh model up to permutation, via roots only."""
    x, planted, true_c = _lattice_stream(seed=0)
    k, t, d = true_c.shape[0], planted.size, x.shape[1]
    single = StreamService(ServiceConfig(
        dim=d, k=k, t=t, leaf_size=512, refresh_every=2048, seed=3))
    shard = ShardedStreamService(ShardedServiceConfig(
        dim=d, k=k, t=t, n_sites=4, leaf_size=512, refresh_every=2048,
        seed=3))
    single.ingest(x)
    shard.ingest(x)
    m1, m2 = single.refresh(), shard.refresh()
    np.testing.assert_allclose(shard.total_weight, x.shape[0], rtol=1e-6)
    # same centers up to permutation: both cover the true centers
    assert _covers_true_centers(m1, true_c)
    assert _covers_true_centers(m2, true_c)
    # same outlier set: identical decisions on planted outliers + inliers
    inl = np.setdiff1d(np.arange(x.shape[0]), planted)[:200]
    probes = np.concatenate([x[planted], x[inl]])
    f1 = np.array([r.is_outlier for r in single.score(probes)])
    f2 = np.array([r.is_outlier for r in shard.score(probes)])
    assert f1[: planted.size].all() and f2[: planted.size].all()
    np.testing.assert_array_equal(f1, f2)
    # only tree roots were communicated, and they were accounted
    st = shard.last_refresh
    assert st is not None and st.comm_records == sum(st.per_site_records)
    assert st.comm_records <= shard.num_records + 1  # roots, not raw points
    assert st.comm_records < x.shape[0] // 4         # massively compressed


def test_sharded_interleaving_is_unbiased_and_resumable():
    x = np.random.default_rng(0).normal(size=(4099, 3)).astype(np.float32)
    svc = ShardedStreamService(ShardedServiceConfig(
        dim=3, k=4, t=8, n_sites=4, leaf_size=256, refresh_every=10**6))
    # two calls; the round-robin cursor must continue across them
    svc.ingest(x[:2050])
    svc.ingest(x[2050:])
    per_site = [tr.total_ingested for tr in svc.trees]
    assert sum(per_site) == 4099
    assert max(per_site) - min(per_site) <= 1      # even split
    # explicit site pinning bypasses the router
    svc.ingest(x[:7], site=2)
    assert svc.trees[2].total_ingested == per_site[2] + 7
    with pytest.raises(ValueError):
        svc.ingest(x[:1], site=4)


def test_sharded_comm_accounting_matches_payload():
    x, _, _ = _lattice_stream(seed=0)
    svc = ShardedStreamService(ShardedServiceConfig(
        dim=x.shape[1], k=6, t=30, n_sites=4, leaf_size=512,
        refresh_every=10**6))
    svc.ingest(x)
    svc.refresh()
    st = svc.last_refresh
    # per-record wire cost: d floats + weight + valid flag
    rec_bytes = x.shape[1] * 4 + 4 + 1
    assert st.payload_bytes == st.root_rows * rec_bytes
    assert st.comm_bytes == 4 * st.payload_bytes
    assert st.root_rows >= max(st.per_site_records)
    assert st.path == "host-sim"
    assert int(svc.model.version) == st.version


def test_sharded_globally_split_outliers_still_caught():
    """A thin far-away population spread evenly over all sites (each site
    holds only a handful of its points) must still be flagged by the global
    model — the coordinator property the single all_gather preserves."""
    rng = np.random.default_rng(7)
    k, d, per = 4, 4, 800
    true_c = np.eye(k, d) * 6.0
    x = np.repeat(true_c, per, axis=0) + rng.normal(0, 0.05, (k * per, d))
    far = rng.uniform(-1, 1, (16, d))
    far = far / np.linalg.norm(far, axis=1, keepdims=True) * 30.0
    x = np.concatenate([x, far]).astype(np.float32)
    order = rng.permutation(x.shape[0])
    svc = ShardedStreamService(ShardedServiceConfig(
        dim=d, k=k, t=20, n_sites=4, leaf_size=512, refresh_every=2048,
        seed=1))
    svc.ingest(x[order])   # round-robin: ~4 far points per site
    svc.refresh()
    res = svc.score(far.astype(np.float32))
    assert all(r.is_outlier for r in res)
    assert all(r.outlier_score > 10 for r in res)


# ------------------------------------------------- shard_map collective
_SHARD_MAP_EQ = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_DEFAULT_PRNG_IMPL"] = "threefry2x32"
    import json
    import numpy as np
    from repro.data.synthetic import gauss
    from repro.stream import ShardedServiceConfig, ShardedStreamService

    x, _ = gauss(n_centers=6, per_center=400, t=24, sigma=0.05, seed=10)
    kw = dict(dim=5, k=6, t=24, n_sites=4, leaf_size=256,
              refresh_every=1024, seed=10)
    host = ShardedStreamService(ShardedServiceConfig(**kw))
    coll = ShardedStreamService(ShardedServiceConfig(**kw,
                                                     use_shard_map=True))
    host.ingest(x); coll.ingest(x)
    mh, mc = host.refresh(), coll.refresh()
    print(json.dumps({
        "paths": [host.last_refresh.path, coll.last_refresh.path],
        "centers_equal": bool(np.array_equal(np.asarray(mh.centers),
                                             np.asarray(mc.centers))),
        "threshold_equal": float(mh.threshold) == float(mc.threshold),
        "cost_equal": float(mh.cost) == float(mc.cost),
        "comm_bytes": [host.last_refresh.comm_bytes,
                       coll.last_refresh.comm_bytes]}))
""")


@pytest.mark.slow
def test_shard_map_refresh_bit_identical_to_host_sim_subprocess():
    """Real 4-device shard_map gather == host-simulated gather, bitwise."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SHARD_MAP_EQ],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["paths"] == ["host-sim", "shard_map"]
    assert res["centers_equal"] and res["threshold_equal"] and res["cost_equal"]
    assert res["comm_bytes"][0] == res["comm_bytes"][1] > 0


# ------------------------------------------------- checkpointing
def test_sharded_checkpoint_roundtrip_identical_scores(tmp_path):
    x, planted, _ = _lattice_stream(seed=0)
    cfg = ShardedServiceConfig(dim=x.shape[1], k=6, t=30, n_sites=4,
                               leaf_size=512, refresh_every=2048, seed=3)
    svc = ShardedStreamService(cfg)
    svc.ingest(x)
    svc.refresh()
    q = x[:96]
    before = svc.score(q)
    svc.save(CheckpointManager(tmp_path), step=1)
    restored = ShardedStreamService.restore(cfg, CheckpointManager(tmp_path))
    after = restored.score(q)
    for a, b in zip(before, after):
        assert a.center == b.center
        assert a.distance == b.distance          # bit-identical
        assert a.outlier_score == b.outlier_score
    # per-site trees and the routing cursor survived: further ingest stays
    # deterministic and identically sharded
    svc.ingest(x[:511])
    restored.ingest(x[:511])
    for t1, t2 in zip(svc.trees, restored.trees):
        assert t1.total_ingested == t2.total_ingested
        np.testing.assert_array_equal(t1.root()[0], t2.root()[0])


def test_sharded_checkpoint_rejects_wrong_site_count(tmp_path):
    x, _, _ = _lattice_stream(seed=0)
    cfg = ShardedServiceConfig(dim=x.shape[1], k=6, t=30, n_sites=4,
                               leaf_size=512, refresh_every=10**6)
    svc = ShardedStreamService(cfg)
    svc.ingest(x[:2048])
    cm = CheckpointManager(tmp_path)
    svc.save(cm, step=1)
    assert cm.read_meta()["n_sites"] == 4
    with pytest.raises(ValueError, match="4 sites"):
        ShardedStreamService.restore(
            ShardedServiceConfig(dim=x.shape[1], k=6, t=30, n_sites=2,
                                 leaf_size=512), CheckpointManager(tmp_path))


def test_checkpoint_format_guard_across_service_kinds(tmp_path):
    """A single-host checkpoint must not restore into the sharded service
    (and vice versa) — the meta format field catches it with a clear error
    instead of a downstream treedef mismatch."""
    x, _, _ = _lattice_stream(seed=0)
    d = x.shape[1]
    single = StreamService(ServiceConfig(dim=d, k=6, t=30, leaf_size=512,
                                         refresh_every=10**6))
    single.ingest(x[:1024])
    single.save(CheckpointManager(tmp_path / "single"), step=1)
    with pytest.raises(ValueError, match="format"):
        ShardedStreamService.restore(
            ShardedServiceConfig(dim=d, k=6, t=30, n_sites=4, leaf_size=512),
            CheckpointManager(tmp_path / "single"))
    sharded = ShardedStreamService(ShardedServiceConfig(
        dim=d, k=6, t=30, n_sites=4, leaf_size=512, refresh_every=10**6))
    sharded.ingest(x[:1024])
    sharded.save(CheckpointManager(tmp_path / "sharded"), step=1)
    with pytest.raises(ValueError, match="format"):
        StreamService.restore(
            ServiceConfig(dim=d, k=6, t=30, leaf_size=512),
            CheckpointManager(tmp_path / "sharded"))


# ------------------------------------------------- sliding-window drift
def test_window_tracks_concept_shift_sharded():
    """ROADMAP window-variance item: on a concept-shifting stream a
    windowed service follows the newest phase; a full-stream service is
    stuck splitting its centers across dead phases."""
    x, phases, centers = drifting_gauss(n_phases=3, n_centers=4,
                                        per_center=1200, d=4, drift=6.0,
                                        seed=0)
    phase_n = int((phases == 0).sum())
    newest = centers[-1]                          # (4, d), box ~[12, 13]^d
    kw = dict(dim=4, k=4, t=16, n_sites=4, leaf_size=256,
              refresh_every=10**6, seed=1)
    # half a phase: with eviction granularity W/4 the whole window is
    # guaranteed to sit inside the newest phase
    windowed = ShardedStreamService(ShardedServiceConfig(
        **kw, window=phase_n // 2))
    full = ShardedStreamService(ShardedServiceConfig(**kw))
    windowed.ingest(x)
    full.ingest(x)
    mw, mf = windowed.refresh(), full.refresh()
    cw, cf = np.asarray(mw.centers), np.asarray(mf.centers)
    d_w = np.linalg.norm(cw[:, None] - newest[None], axis=-1).min(1)
    d_f = np.linalg.norm(cf[:, None] - newest[None], axis=-1).min(1)
    # every windowed center sits on a newest-phase cluster ...
    assert float(d_w.max()) < 1.0, d_w
    # ... while the full-stream model still spends centers on old phases
    assert float(d_f.max()) > 4.0, d_f
    # and the windowed model scores newest-phase traffic far better
    probe = x[phases == 2][:512]
    rw = windowed.score(probe)
    assert np.mean([r.is_outlier for r in rw]) < 0.1


def test_window_tracks_concept_shift_single_host():
    """Same drift property for the single-host tree (the two services share
    eviction semantics: global window W ~= per-site window W/s x s sites)."""
    x, phases, centers = drifting_gauss(n_phases=2, n_centers=4,
                                        per_center=1200, d=4, drift=6.0,
                                        seed=1)
    phase_n = int((phases == 0).sum())
    newest = centers[-1]
    kw = dict(dim=4, k=4, t=16, leaf_size=256, refresh_every=10**6, seed=1)
    windowed = StreamService(ServiceConfig(**kw, window=phase_n // 2))
    full = StreamService(ServiceConfig(**kw))
    windowed.ingest(x)
    full.ingest(x)
    mw, mf = windowed.refresh(), full.refresh()
    d_w = np.linalg.norm(np.asarray(mw.centers)[:, None] - newest[None],
                         axis=-1).min(1)
    d_f = np.linalg.norm(np.asarray(mf.centers)[:, None] - newest[None],
                         axis=-1).min(1)
    assert float(d_w.max()) < 1.0, d_w
    assert float(d_f.max()) > 4.0, d_f
