"""Dry-run integration: a representative cell per program kind compiles on
the production mesh in a 512-virtual-device subprocess (XLA flag isolation),
and the recorded roofline terms are sane."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh="single"):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", "/tmp/dryrun_test"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-1000:])
    tag = f"{arch}__{shape}__{mesh}"
    return json.loads(open(f"/tmp/dryrun_test/{tag}.json").read())


@pytest.mark.slow
def test_train_cell_compiles_single_pod():
    rec = _run_cell("h2o-danube-1.8b", "train_4k")
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["hlo_flops"] > rec["model_flops_per_chip"] * 0.5
    assert 0.05 < rec["useful_flops_ratio"] < 1.5
    assert rec["collectives"]["all-reduce"]["count"] > 0
    # parameter+optimizer state fits HBM
    assert rec["memory"]["argument_bytes"] < 16e9


@pytest.mark.slow
def test_decode_cell_compiles_multi_pod():
    rec = _run_cell("h2o-danube-1.8b", "decode_32k", mesh="multi")
    assert rec["status"] == "ok"
    assert rec["chips"] == 512


@pytest.mark.slow
def test_long_context_skip_policy():
    rec = _run_cell("qwen2.5-32b", "long_500k")
    assert rec["status"] == "skipped"
    rec2 = _run_cell("rwkv6-7b", "long_500k")
    assert rec2["status"] == "ok"
