"""Second-level clustering (k-means--) + baseline summaries."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional: only the property tests need hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (kmeans_minus_minus, kmeanspp_summary, pp_budget,
                        kmeans_parallel_summary, rand_summary)
from repro.data.synthetic import gauss


def test_kmeans_mm_finds_planted_outliers():
    x, out_ids = gauss(n_centers=5, per_center=400, t=25, sigma=0.05, seed=0)
    n = x.shape[0]
    sol = kmeans_minus_minus(jnp.asarray(x), jnp.ones((n,)), jnp.ones((n,), bool),
                             jax.random.key(0), k=5, t=25.0)
    found = set(np.nonzero(np.asarray(sol.outlier))[0].tolist())
    rec = len(found & set(out_ids.tolist())) / len(out_ids)
    assert rec >= 0.8


def test_kmeans_mm_outlier_budget_respected():
    x, _ = gauss(n_centers=4, per_center=200, t=20, sigma=0.1, seed=1)
    n = x.shape[0]
    w = jnp.ones((n,))
    sol = kmeans_minus_minus(jnp.asarray(x), w, jnp.ones((n,), bool),
                             jax.random.key(0), k=4, t=20.0)
    assert float((w * sol.outlier).sum()) <= 20.0


def test_weighted_equals_duplicated():
    """A point with weight w must act like w coincident unit points."""
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(50, 3)).astype(np.float32)
    w = rng.integers(1, 4, size=50).astype(np.float32)
    dup = np.repeat(pts, w.astype(int), axis=0)
    key = jax.random.key(7)
    s1 = kmeans_minus_minus(jnp.asarray(pts), jnp.asarray(w),
                            jnp.ones((50,), bool), key, k=3, t=5.0, iters=30)
    s2 = kmeans_minus_minus(jnp.asarray(dup), jnp.ones((dup.shape[0],)),
                            jnp.ones((dup.shape[0],), bool), key, k=3, t=5.0,
                            iters=30)
    assert abs(float(s1.cost) - float(s2.cost)) / max(float(s2.cost), 1e-6) < 0.35


def test_pp_summary_weights_conserve():
    x = np.random.default_rng(0).normal(size=(1000, 4)).astype(np.float32)
    b = pp_budget(1000, 5, 20)
    s = kmeanspp_summary(jnp.asarray(x), jax.random.key(0), budget=b)
    np.testing.assert_allclose(float(s.weights.sum()), 1000, rtol=1e-6)
    assert int(s.valid.sum()) == b


def test_rand_summary_weights_conserve():
    x = np.random.default_rng(0).normal(size=(800, 4)).astype(np.float32)
    s = rand_summary(jnp.asarray(x), jax.random.key(0), budget=100)
    np.testing.assert_allclose(float(s.weights.sum()), 800, rtol=1e-6)
    assert len(np.unique(np.asarray(s.indices))) == 100  # no replacement


def test_kmeans_parallel_comm_grows_with_sites():
    x = np.random.default_rng(0).normal(size=(2000, 4)).astype(np.float32)
    r5 = kmeans_parallel_summary(jnp.asarray(x), jax.random.key(0),
                                 budget=100, sites=5)
    r20 = kmeans_parallel_summary(jnp.asarray(x), jax.random.key(0),
                                  budget=100, sites=20)
    assert float(r20.comm_records) > 3.0 * float(r5.comm_records)
    np.testing.assert_allclose(float(r5.summary.weights.sum()), 2000, rtol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(k=st.integers(1, 8), t=st.integers(0, 30), seed=st.integers(0, 10**6))
    def test_kmeans_mm_property(k, t, seed):
        rng = np.random.default_rng(seed)
        n = 300
        x = rng.normal(size=(n, 3)).astype(np.float32)
        sol = kmeans_minus_minus(jnp.asarray(x), jnp.ones((n,)),
                                 jnp.ones((n,), bool), jax.random.key(seed % 97),
                                 k=k, t=float(t), iters=10)
        assert sol.centers.shape == (k, 3)
        assert float(jnp.sum(sol.outlier)) <= t
        assert np.isfinite(float(sol.cost))
        assert float(sol.cost) >= 0
else:
    def test_kmeans_mm_property():
        pytest.importorskip("hypothesis")
