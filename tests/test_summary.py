"""Algorithm 1/2 invariants + hypothesis property tests."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional: only the property tests need hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (augmented_summary_outliers, information_loss,
                        kmeans_minus_minus, summary_outliers,
                        summary_outliers_compact)
from repro.data.synthetic import gauss


def _mk_data(n, d, seed, outliers=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    if outliers:
        ids = rng.choice(n, outliers, replace=False)
        x[ids] += rng.uniform(-20, 20, size=(outliers, d))
    return x


def _check_invariants(x, summ, k, t):
    n = x.shape[0]
    # weight conservation: every point maps somewhere
    np.testing.assert_allclose(float(summ.weights.sum()), n, rtol=1e-6)
    # summary size bound O(kappa log n + t)
    kappa = max(k, math.ceil(math.log(max(n, 2))))
    assert int(summ.valid.sum()) <= 2 * kappa * max(1, math.ceil(
        math.log(max(n, 2)) / -math.log1p(-0.45))) + 8 * t + 1
    # outlier candidates <= 8t
    assert int((summ.valid & summ.is_candidate).sum()) <= 8 * t
    # sigma is a valid mapping into the summary points
    sig = np.asarray(summ.sigma)
    sel = set(np.asarray(summ.indices)[np.asarray(summ.valid)].tolist())
    assert set(np.unique(sig).tolist()) <= sel
    # every valid summary point carries positive weight or is a center
    w = np.asarray(summ.weights)[np.asarray(summ.valid)]
    assert (w >= 0).all()


@pytest.mark.parametrize("impl", [summary_outliers, summary_outliers_compact])
@pytest.mark.parametrize("metric", ["l2sq", "l2", "l1"])
def test_summary_invariants(impl, metric):
    x = _mk_data(2000, 5, 0, outliers=50)
    summ = impl(jnp.asarray(x) if impl is summary_outliers else x,
                jax.random.key(1), k=10, t=50, metric=metric)
    _check_invariants(x, summ, 10, 50)


def test_augmentation_never_increases_loss():
    x, _ = gauss(n_centers=10, per_center=300, t=60, sigma=0.1, seed=3)
    xj = jnp.asarray(x)
    key = jax.random.key(5)
    base = summary_outliers(xj, key, k=10, t=60)
    aug = augmented_summary_outliers(xj, key, k=10, t=60)
    lb = float(information_loss(xj, base.sigma))
    la = float(information_loss(xj, aug.sigma))
    assert la <= lb * 1.01
    _check_invariants(x, aug, 10, 60)


def test_loss_bounded_by_opt_proxy():
    """Theorem 1: loss(Q) = O(OPT). Proxy OPT with k-means-- on the raw data
    (an upper bound on OPT!), so loss(Q) <= C * proxy must hold for the
    theorem's C; we check a generous constant."""
    x, out_ids = gauss(n_centers=10, per_center=200, t=40, sigma=0.05, seed=7)
    xj = jnp.asarray(x)
    summ = summary_outliers(xj, jax.random.key(0), k=10, t=40)
    loss = float(information_loss(xj, summ.sigma))
    n = x.shape[0]
    sol = kmeans_minus_minus(xj, jnp.ones((n,)), jnp.ones((n,), bool),
                             jax.random.key(1), k=10, t=40.0)
    assert loss <= 20.0 * float(sol.cost) + 1e-3


def test_outliers_survive_into_candidates():
    """Planted far outliers must end up as summary candidates (preRec)."""
    x, out_ids = gauss(n_centers=10, per_center=300, t=30, sigma=0.05, seed=11)
    summ = augmented_summary_outliers(jnp.asarray(x), jax.random.key(2),
                                      k=10, t=30)
    sel = np.asarray(summ.indices)[np.asarray(summ.valid)]
    pre_rec = len(set(sel.tolist()) & set(out_ids.tolist())) / len(out_ids)
    assert pre_rec >= 0.9


def test_t_zero_summarizes_everything_into_centers():
    x = _mk_data(500, 3, 1)
    summ = summary_outliers(jnp.asarray(x), jax.random.key(0), k=5, t=0)
    assert int(summ.n_remaining) <= 1
    np.testing.assert_allclose(float(summ.weights.sum()), 500, rtol=1e-6)


def test_tiny_dataset_no_rounds():
    x = _mk_data(20, 3, 2)
    summ = summary_outliers(jnp.asarray(x), jax.random.key(0), k=5, t=10)
    # n <= 8t: zero rounds, everything is a candidate
    assert int(summ.n_rounds) == 0
    assert int(summ.valid.sum()) == 20


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(50, 800),
        d=st.integers(1, 8),
        k=st.integers(1, 12),
        t=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_summary_property(n, d, k, t, seed):
        """Property: invariants hold for arbitrary data/params."""
        rng = np.random.default_rng(seed)
        x = rng.normal(scale=rng.uniform(0.1, 10), size=(n, d)).astype(np.float32)
        summ = summary_outliers(jnp.asarray(x), jax.random.key(seed % 1000),
                                k=k, t=t)
        np.testing.assert_allclose(float(summ.weights.sum()), n, rtol=1e-5)
        assert int((summ.valid & summ.is_candidate).sum()) <= max(8 * t, n)
        sig = np.asarray(summ.sigma)
        assert ((0 <= sig) & (sig < n)).all()
        # idempotent mapping onto summary members
        sel = np.zeros(n, bool)
        sel[np.asarray(summ.indices)[np.asarray(summ.valid)]] = True
        assert sel[sig].all()
else:
    def test_summary_property():
        pytest.importorskip("hypothesis")


def test_augmented_compact_matches_jit_invariants():
    from repro.core.augmented import augmented_summary_compact
    x, out_ids = gauss(n_centers=8, per_center=250, t=40, sigma=0.1, seed=13)
    summ = augmented_summary_compact(x, jax.random.key(3), k=8, t=40)
    _check_invariants(x, summ, 8, 40)
    # the paper's balance goal: #centers ~ #candidates after augmentation
    n_cand = int((summ.valid & summ.is_candidate).sum())
    n_cent = int((summ.valid & ~summ.is_candidate).sum())
    assert n_cent >= n_cand * 0.8
    # planted outliers still surface
    sel = np.asarray(summ.indices)[np.asarray(summ.valid)]
    pre = len(set(sel.tolist()) & set(out_ids.tolist())) / len(out_ids)
    assert pre >= 0.9


def test_shapes_cell_policy():
    from repro.launch.shapes import SHAPES, cell_supported, input_structs
    from repro.configs import get_config
    full_attn = get_config("qwen2.5-32b")
    subq = get_config("rwkv6-7b")
    ok, why = cell_supported(full_attn, SHAPES["long_500k"])
    assert not ok and "O(S^2)" in why
    assert cell_supported(subq, SHAPES["long_500k"])[0]
    # vlm structs carve the text region out of seq_len
    vlm = get_config("llava-next-mistral-7b")
    st = input_structs(vlm, SHAPES["train_4k"])
    assert st["tokens"].shape[1] + vlm.frontend_tokens == 4096
    enc = get_config("seamless-m4t-medium")
    st = input_structs(enc, SHAPES["train_4k"])
    assert st["frames"].shape[1] == 1024  # seq // 4
