"""Summarizer head-to-head: every registered summary algorithm on the
paper's workloads, at matched summary size.

For each dataset (gauss / kdd-like / susy-like, scaled for one CPU core;
``--scale`` restores paper-scale sizes) the data is partitioned over
``--sites`` sites and each registered summarizer builds per-site summaries
through the ``repro.summarize`` registry; the union feeds the same
second-level weighted k-means-- and is scored with the paper's Section 5
metrics:

  * summary size (records gathered to the coordinator = communication),
  * l1 / l2 clustering loss on the ORIGINAL data, and the ratio to the
    ``paper`` summarizer's loss (1.0 = parity),
  * outlier preRec / precision / recall against ground truth,
  * summary build throughput in points/sec (median site).

Budget-accepting summarizers (uniform, coreset) are size-matched to the
``paper`` summary so the comparison is at equal communication — the
acceptance bar is ``paper`` beating ``uniform`` on outlier recall, which
is exactly the paper's Tables 2–4 story (no candidates, no recall).

A ``cosine`` section exercises the new metric end to end: the coreset and
paper summarizers on unit-normalized susy-like data (build + mass
conservation; the second level stays on l2sq, where the paper's theory
lives).

Emits ``BENCH_summarize.json`` at the repo root; the CI bench-smoke job
gates it via the ``summarize_*`` keys of
``benchmarks/stream_thresholds.json`` (see check_stream_regression.py).

    PYTHONPATH=src:. python benchmarks/summarizer_bench.py [--scale 1.0]
        [--sites 4] [--out BENCH_summarize.json]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax

from benchmarks.common import Row, evaluate_summarizers, print_rows
from repro.data.synthetic import gauss, kdd_like, partition, susy_like
from repro.summarize import (SummarizerPolicy, registered_summarizers,
                             summarize)

_DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_summarize.json"


def _policies() -> list[SummarizerPolicy]:
    return [SummarizerPolicy(name) for name in sorted(registered_summarizers())]


def _rows_to_json(rows: list[Row], per_site_n: int) -> dict:
    by_name = {r.algo: r for r in rows}
    ref = by_name.get("paper", rows[0])
    out = {}
    for r in rows:
        out[r.algo] = {
            "summary": r.summary,
            "l1": r.l1,
            "l2": r.l2,
            "l1_ratio": r.l1 / max(ref.l1, 1e-12),
            "l2_ratio": r.l2 / max(ref.l2, 1e-12),
            "pre_rec": r.pre_rec,
            "prec": r.prec,
            "recall": r.recall,
            "comm": r.comm,
            "t_summary_s": r.t_summary,
            "build_pts_per_s": per_site_n / max(r.t_summary, 1e-9),
        }
    return out


def run_dataset(name: str, x, out_ids, *, k: int, t: int, sites: int,
                seed: int) -> dict:
    parts, gids = partition(x, sites, "random", seed=seed,
                            outlier_ids=out_ids)
    rows = evaluate_summarizers(x, out_ids, parts, gids, k, t, _policies(),
                                seed=seed)
    print_rows(f"summarize/{name} (n={x.shape[0]}, k={k}, t={t})", rows)
    return {"n": int(x.shape[0]), "k": k, "t": t, "sites": sites,
            "summarizers": _rows_to_json(rows, parts[0].shape[0])}


def run_cosine(*, scale: float, seed: int) -> dict:
    """Cosine-metric exercise: summarize unit-normalized susy-like data."""
    n = max(int(60_000 * scale), 4_000)
    t = max(int(n * 0.01), 40)
    k = 10
    x, out_ids = susy_like(n=n, t=t, seed=seed)
    x = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    w = np.ones((n,), np.float32)
    out = {"n": n, "k": k, "t": t, "metric": "cosine", "summarizers": {}}
    for name in ("paper", "coreset"):
        t0 = time.perf_counter()
        s = summarize(x, w, jax.random.key(seed), k=k, t=t, metric="cosine",
                      policy=SummarizerPolicy(name))
        dt = time.perf_counter() - t0
        true = set(out_ids.tolist())
        picked = set(np.asarray(s.indices).tolist())
        out["summarizers"][name] = {
            "summary": int(s.points.shape[0]),
            "mass_err": abs(float(s.weights.sum()) - n) / n,
            "pre_rec": len(picked & true) / max(len(true), 1),
            "build_pts_per_s": n / max(dt, 1e-9),
        }
    return out


def run(scale: float = 1.0, sites: int = 4, seed: int = 0,
        out_path: Path | str | None = _DEFAULT_OUT) -> dict:
    result = {"scale": scale, "sites": sites, "datasets": {}}

    n_centers, per_center = 20, max(int(2000 * scale), 150)
    t = max(int(n_centers * per_center * 0.01), 40)
    x, oid = gauss(n_centers=n_centers, per_center=per_center, d=5,
                   sigma=0.1, t=t, seed=seed)
    result["datasets"]["gauss"] = run_dataset(
        "gauss", x, oid, k=n_centers, t=t, sites=sites, seed=seed)

    n = max(int(100_000 * scale), 6_000)
    x, oid = kdd_like(n=n, seed=seed)
    result["datasets"]["kdd_like"] = run_dataset(
        "kdd_like", x, oid, k=23, t=max(len(oid), 1), sites=sites, seed=seed)

    n = max(int(100_000 * scale), 6_000)
    t = max(int(n * 0.01), 40)
    x, oid = susy_like(n=n, t=t, seed=seed)
    result["datasets"]["susy_like"] = run_dataset(
        "susy_like", x, oid, k=10, t=t, sites=sites, seed=seed)

    result["cosine"] = run_cosine(scale=scale, seed=seed)

    if out_path is not None:
        Path(out_path).write_text(json.dumps(result, indent=2) + "\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--sites", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(_DEFAULT_OUT))
    args = ap.parse_args()
    res = run(scale=args.scale, sites=args.sites, seed=args.seed,
              out_path=args.out)
    cz = res["cosine"]["summarizers"]
    print(f"\ncosine (unit susy-like, n={res['cosine']['n']}): " +
          "  ".join(f"{n}: {e['summary']} recs, preRec {e['pre_rec']:.2f}, "
                    f"{e['build_pts_per_s']:,.0f} pts/s"
                    for n, e in cz.items()))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
