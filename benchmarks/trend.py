"""Append this run's key benchmark metrics to ``BENCH_trend.jsonl``.

The regression gate (check_stream_regression.py) is a threshold: it only
notices a metric once it falls off a cliff.  This script keeps the trend
line: after every bench run it appends one JSON line with the headline
metrics of ``BENCH_stream.json`` and ``BENCH_summarize.json`` (whichever
exist), stamped with UTC time and the git commit, so slow drifts are
visible across runs.  The CI bench-smoke job downloads the previous run's
artifact, appends, and re-uploads — the artifact accumulates history.

    PYTHONPATH=src python benchmarks/trend.py [--out BENCH_trend.jsonl] \
        [--stream BENCH_stream.json] [--summarize BENCH_summarize.json] \
        [--label "..."]

Each line:

    {"ts": "...", "commit": "...", "label": "...",
     "stream": {ingest_pts_per_s, query_p50_ms, query_p99_ms, cost_ratio,
                obs_overhead_frac?, sharded_cost_ratio?,
                sharded_comm_bytes?, serving_peak_goodput_rps?,
                serving_overload_p99_ms?, serving_overload_shed_rate?,
                store_spill_bytes?, store_skipped_refreshes?,
                store_ingest_slowdown_frac?, store_rss_growth_frac?},
     "kernels": {"<op>.<backend>": pts_per_s, ...},
     "summarize": {"<dataset>.<name>": {"recall": .., "l2_ratio": ..}, ...}}
"""
from __future__ import annotations

import argparse
import datetime
import json
import subprocess
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent


def _git_commit() -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=_ROOT, capture_output=True, text=True,
                             timeout=10)
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def _load(path: str | Path) -> dict | None:
    p = Path(path)
    if not p.exists():
        return None
    try:
        return json.loads(p.read_text())
    except ValueError:
        return None


def stream_point(bench: dict) -> dict:
    pt = {
        "ingest_pts_per_s": round(float(bench["ingest_pts_per_s"]), 1),
        "query_p50_ms": round(float(bench["query_p50_ms"]), 3),
        "query_p99_ms": round(float(bench["query_p99_ms"]), 3),
        "cost_ratio": round(float(bench["cost_ratio"]), 4),
    }
    ob = bench.get("obs")
    if ob:
        pt["obs_overhead_frac"] = round(float(ob["overhead_frac"]), 4)
        if "trace_overhead_frac" in ob:
            pt["trace_overhead_frac"] = round(
                float(ob["trace_overhead_frac"]), 4)
    sh = bench.get("sharded")
    if sh:
        pt["sharded_cost_ratio"] = round(float(sh["cost_ratio"]), 4)
        pt["sharded_comm_bytes"] = int(sh["refresh_comm_bytes"])
    sv = bench.get("serving")
    if sv:
        pt["serving_peak_goodput_rps"] = round(
            float(sv["peak_goodput_rps"]), 1)
        if sv.get("overload_p99_ms") is not None:
            pt["serving_overload_p99_ms"] = round(
                float(sv["overload_p99_ms"]), 3)
        pt["serving_overload_shed_rate"] = round(
            float(sv["overload_shed_rate"]), 4)
    so = bench.get("store")
    if so:
        pt["store_spill_bytes"] = int(so["spill_bytes"])
        pt["store_skipped_refreshes"] = int(so.get("skipped_refreshes", 0))
        pt["store_ingest_slowdown_frac"] = round(
            float(so["ingest_slowdown_frac"]), 4)
        if so.get("rss_growth_frac") is not None:
            pt["store_rss_growth_frac"] = round(
                float(so["rss_growth_frac"]), 4)
    return pt


def kernels_point(bench: dict) -> dict:
    pt = {}
    kb = bench.get("kernels", {})
    for op, backends in kb.get("ops", {}).items():
        for name, e in backends.items():
            if "pts_per_s" in e:
                pt[f"{op}.{name}"] = float(e["pts_per_s"])
    fu = kb.get("fused")
    if fu:
        pt["score.fused_speedup"] = round(float(fu["speedup"]), 3)
    qu = kb.get("quant")
    if qu:
        pt["score.quant_max_err"] = round(float(qu["max_score_err"]), 5)
    return pt


def summarize_point(bench: dict) -> dict:
    pt = {}
    for ds, entry in bench.get("datasets", {}).items():
        for name, e in entry.get("summarizers", {}).items():
            pt[f"{ds}.{name}"] = {
                "recall": round(float(e["recall"]), 4),
                "l2_ratio": round(float(e["l2_ratio"]), 4),
                "summary": int(e["summary"]),
            }
    return pt


def build_point(stream: dict | None, summarize: dict | None,
                label: str | None) -> dict:
    point: dict = {
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "commit": _git_commit(),
    }
    if label:
        point["label"] = label
    if stream is not None:
        point["stream"] = stream_point(stream)
        kp = kernels_point(stream)
        if kp:
            point["kernels"] = kp
    if summarize is not None:
        point["summarize"] = summarize_point(summarize)
    return point


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stream", default=str(_ROOT / "BENCH_stream.json"))
    ap.add_argument("--summarize",
                    default=str(_ROOT / "BENCH_summarize.json"))
    ap.add_argument("--out", default=str(_ROOT / "BENCH_trend.jsonl"))
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    stream, summarize = _load(args.stream), _load(args.summarize)
    if stream is None and summarize is None:
        print("trend: no bench outputs found; nothing to append",
              file=sys.stderr)
        return 1
    point = build_point(stream, summarize, args.label)
    with open(args.out, "a") as f:
        f.write(json.dumps(point, sort_keys=True) + "\n")
    n = sum(1 for _ in open(args.out))
    print(f"appended run {point['commit'] or '?'} to {args.out} "
          f"({n} points)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
