"""Benchmark orchestrator — one experiment per paper table/figure plus the
roofline reader. Prints ``name,us_per_call,derived`` CSV lines.

Scaled-for-one-CPU-core defaults; pass --scale to approach paper scale.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=None,
                    help="dataset scale override (default: per-bench scaled)")
    ap.add_argument("--only", default=None,
                    help="comma list: table2,table3,table4,fig1,roofline,"
                         "stream,summarize")
    ap.add_argument("--sites", type=int, default=0,
                    help="stream bench: also run the sharded service over N sites")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    csv: list[str] = []
    t_start = time.perf_counter()

    def want(name):
        return only is None or name in only

    if want("table2"):
        from benchmarks.table2_gauss import run as t2
        from benchmarks.common import csv_rows
        rows = t2(scale=args.scale or 0.1)
        for name, rr in rows.items():
            csv += csv_rows(f"table2/{name}", rr)

    if want("table3"):
        from benchmarks.table3_kdd import run as t3
        from benchmarks.common import csv_rows
        rows = t3(scale=args.scale or 0.1)
        for name, rr in rows.items():
            csv += csv_rows(f"table3/{name}", rr)

    if want("table4"):
        from benchmarks.table4_susy import run as t4
        from benchmarks.common import csv_rows
        rows = t4(scale=args.scale or 0.04)
        for name, rr in rows.items():
            csv += csv_rows(f"table4/{name}", rr)

    if want("fig1"):
        from benchmarks.fig1_comm_time import run as f1
        a, b, _ = f1(scale=args.scale or 0.1)
        for algo, comms in a.items():
            csv.append(f"fig1a/{algo},0,comm=" + "|".join(f"{v:.0f}" for v in comms))
        for algo, ts in b.items():
            csv.append(f"fig1b/{algo},{ts[-1] * 1e6:.0f},time_s=" +
                       "|".join(f"{v:.2f}" for v in ts))

    if want("stream"):
        from benchmarks.stream_bench import run as sb
        res = sb(scale=args.scale or 1.0, sites=args.sites)
        csv.append(f"stream/ingest,{1e6 / res['ingest_pts_per_s']:.2f},"
                   f"pts_per_s={res['ingest_pts_per_s']:.0f}")
        csv.append(f"stream/query,{res['query_p50_ms'] * 1e3:.0f},"
                   f"p50_ms={res['query_p50_ms']:.3f};"
                   f"p99_ms={res['query_p99_ms']:.3f};"
                   f"cost_ratio={res['cost_ratio']:.3f}")
        csv.append(f"stream/refresh,{res['refresh_s'] * 1e6:.0f},"
                   f"oneshot_s={res['oneshot_s']:.2f};"
                   f"records={res['summary_records']}")
        if "sharded" in res:
            sh = res["sharded"]
            csv.append(
                f"stream/sharded{sh['sites']},"
                f"{1e6 / sh['ingest_pts_per_s']:.2f},"
                f"pts_per_s_per_site={sh['ingest_pts_per_s_per_site']:.0f};"
                f"path={sh['path']};"
                f"comm_bytes={sh['refresh_comm_bytes']};"
                f"comm_records={sh['refresh_comm_records']};"
                f"p99_ms={sh['query_p99_ms']:.3f};"
                f"cost_ratio={sh['cost_ratio']:.3f}")

    if want("summarize"):
        from benchmarks.summarizer_bench import run as sm
        res = sm(scale=args.scale or 0.3, sites=args.sites or 4)
        for ds, entry in res["datasets"].items():
            for name, e in entry["summarizers"].items():
                csv.append(f"summarize/{ds}/{name},"
                           f"{e['t_summary_s'] * 1e6:.0f},"
                           f"recall={e['recall']:.4f};"
                           f"l2_ratio={e['l2_ratio']:.4f};"
                           f"summary={e['summary']}")

    if want("roofline"):
        from benchmarks.roofline import load, print_table
        for mesh in ("single", "multi", "single-opt"):
            rows = load(mesh=mesh)
            if rows:
                print(f"\n== roofline ({mesh}-pod) ==")
                print_table(rows, show_skipped=False)
                for d in rows:
                    if d["status"] == "ok":
                        dom = max(d["compute_s"], d["memory_s"], d["collective_s"])
                        mf = d.get("model_flops_per_chip")
                        ach = (mf / 197e12) / dom if (dom and mf) else 0
                        csv.append(f"roofline-{mesh}/{d['arch']}/{d['shape']},"
                                   f"{dom * 1e6:.0f},bound={d['bottleneck']};"
                                   f"roofline_frac={ach:.4f}")

    print("\n# ==== CSV (name,us_per_call,derived) ====")
    for line in csv:
        print(line)
    print(f"# total bench wall: {time.perf_counter() - t_start:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
