"""Perf/quality regression gate over ``BENCH_stream.json``.

Reads the committed thresholds from ``benchmarks/stream_thresholds.json``
and fails (exit 1) if the latest benchmark run breached any of them — the
CI bench-smoke job runs this after ``benchmarks/run.py --only stream`` so
a PR cannot silently trade away streaming model quality:

  * ``cost_ratio_max``          — stream-vs-oneshot (k,t)-means objective
                                  ratio of the single-host service;
  * ``sharded_cost_ratio_max``  — same for the sharded service (slightly
                                  looser: per-site roots re-summarize less
                                  data per merge, so the tree is shallower
                                  but each root is built from a 1/s sample);
  * ``sharded_comm_frac_max``   — gathered root records per refresh as a
                                  fraction of the stream length: the whole
                                  point of the paper is that communication
                                  is sublinear in n;
  * ``kernels_min_pts_per_s``   — floor on every measured backend of the
                                  ``"kernels"`` section (min_argmin /
                                  lloyd_step / score through the dispatch
                                  registry).  Set ~100x below healthy CPU
                                  throughput: it catches catastrophic
                                  dispatch regressions (e.g. auto
                                  selection landing on Pallas interpret
                                  mode), not machine-speed noise.  The
                                  section itself is required — a bench run
                                  without it fails the gate;
  * ``kernels_fused_min_speedup`` — floor on the fused one-pass score
                                  kernel's speedup over the composed
                                  min_argmin + jitted-divide path it
                                  replaced (``kernels.fused.speedup``):
                                  fusing must never cost throughput;
  * ``quant_max_score_err``     — ceiling on the int8 quantized-center
                                  backend's measured max |Δscore| vs the
                                  fp32 path at a decision-boundary
                                  threshold (``kernels.quant``);
  * ``obs_overhead_frac_max``   — ceiling on the telemetry plane's ingest
                                  slowdown (``"obs"`` section of the bench:
                                  metrics-on vs metrics-off throughput) —
                                  instrumentation must stay ~free.

The ``serving_*`` keys gate the ``"serving"`` section (the async
scheduler's goodput-vs-offered-load ladder, ``serving_bench.py``):
``serving_min_goodput_rps`` floors peak goodput,
``serving_overload_p99_ms_max`` bounds completed-request p99 at the
highest (overload) rung, ``serving_overload_shed_min`` demands that
admission control actually sheds there, ``serving_low_load_shed_max``
demands it sheds ~nothing below capacity, and the section's
``bit_identical`` flag must be true.  A bench without the section skips
these gates unless ``--require-serving`` is passed (the serve-load CI
lane does).

The ``store_*`` keys gate the ``"store"`` section (the tiered summary
store's long-stream comparison, ``stream_bench.py --store``):
``store_max_ingest_slowdown_frac`` bounds the tiered-vs-plain ingest
slowdown, ``store_max_rss_growth_frac`` bounds resident-set growth over
the second half of the tiered run (the bounded-memory claim), and the
section's ``bit_identical`` / ``refresh_skipped`` flags must be true
with nonzero spill/page-in tallies.  A bench without the section skips
these gates unless ``--require-store`` is passed (the nightly
long-stream-smoke lane does).

With any ``summarize_*`` key present the gate also reads
``BENCH_summarize.json`` (benchmarks/summarizer_bench.py) and checks, per
gated dataset (gauss / kdd_like):

  * ``summarize_min_summarizers``     — at least this many registered
                                        summarizers were compared;
  * ``summarize_paper_min_recall``    — outlier-recall floor for the
                                        ``paper`` summarizer;
  * ``summarize_recall_margin_min``   — paper recall must beat the
                                        ``uniform`` baseline's by at least
                                        this margin at matched summary
                                        size (the paper's Tables 2-4
                                        claim, kept true under refactors);
  * ``summarize_cosine_mass_err_max`` — relative mass-conservation error
                                        of the cosine-metric section.

    PYTHONPATH=src python benchmarks/check_stream_regression.py \
        [--bench BENCH_stream.json] [--summarize-bench BENCH_summarize.json] \
        [--thresholds benchmarks/stream_thresholds.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent


def check(bench: dict, thr: dict) -> list[str]:
    failures = []

    def gate(name, value, bound):
        tag = "ok  " if value <= bound else "FAIL"
        print(f"{tag} {name}: {value:.4f} (max {bound})")
        if value > bound:
            failures.append(name)

    def gate_min(name, value, bound):
        tag = "ok  " if value >= bound else "FAIL"
        print(f"{tag} {name}: {value:.1f} (min {bound})")
        if value < bound:
            failures.append(name)

    gate("cost_ratio", float(bench["cost_ratio"]), thr["cost_ratio_max"])
    kb = bench.get("kernels")
    if kb is None:
        print("FAIL kernels: section missing from bench output")
        failures.append("kernels_section")
    else:
        floor = thr["kernels_min_pts_per_s"]
        for op, backends in kb["ops"].items():
            measured = 0
            for name, e in backends.items():
                if "pts_per_s" not in e:
                    continue
                measured += 1
                gate_min(f"kernels.{op}.{name}.pts_per_s",
                         float(e["pts_per_s"]), floor)
            if measured == 0:
                print(f"FAIL kernels.{op}: no backend measured")
                failures.append(f"kernels.{op}")
        if "kernels_fused_min_speedup" in thr:
            fu = kb.get("fused")
            if fu is None:
                print("FAIL kernels.fused: subsection missing from bench "
                      "output (fused-vs-composed unmeasured)")
                failures.append("kernels.fused")
            else:
                v, b = float(fu["speedup"]), thr["kernels_fused_min_speedup"]
                tag = "ok  " if v >= b else "FAIL"
                print(f"{tag} kernels.fused.speedup: {v:.3f} (min {b})")
                if v < b:
                    failures.append("kernels.fused.speedup")
        if "quant_max_score_err" in thr:
            qu = kb.get("quant")
            if qu is None:
                print("FAIL kernels.quant: subsection missing from bench "
                      "output (int8 score error unmeasured)")
                failures.append("kernels.quant")
            else:
                gate("kernels.quant.max_score_err",
                     float(qu["max_score_err"]), thr["quant_max_score_err"])
    ob = bench.get("obs")
    if "obs_overhead_frac_max" in thr:
        if ob is None:
            print("FAIL obs: section missing from bench output "
                  "(instrumentation overhead unmeasured)")
            failures.append("obs_section")
        else:
            gate("obs_overhead_frac", float(ob["overhead_frac"]),
                 thr["obs_overhead_frac_max"])
    if "trace_overhead_frac_max" in thr:
        if ob is None:
            print("FAIL obs: section missing from bench output "
                  "(tracing overhead unmeasured)")
            failures.append("obs_section_trace")
        elif "trace_overhead_frac" not in ob:
            print("FAIL obs.trace_overhead_frac: missing from bench "
                  "output (flight-recorder overhead unmeasured)")
            failures.append("trace_overhead_frac")
        else:
            gate("trace_overhead_frac", float(ob["trace_overhead_frac"]),
                 thr["trace_overhead_frac_max"])
    sh = bench.get("sharded")
    if sh is not None:
        gate("sharded_cost_ratio", float(sh["cost_ratio"]),
             thr["sharded_cost_ratio_max"])
        gate("sharded_comm_frac",
             float(sh["refresh_comm_records"]) / max(int(bench["n"]), 1),
             thr["sharded_comm_frac_max"])
    return failures


def check_serving(bench: dict, thr: dict, *,
                  require_serving: bool = False) -> list[str]:
    """Gate the ``"serving"`` section (serving_bench.py's load ladder).

    The section is optional in a plain bench run; ``--require-serving``
    (the serve-load-smoke CI job) makes its absence a failure.  Gates:
    goodput floor, overload p99 ceiling, overload must actually shed
    (that is the mechanism that bounds p99), ~no shedding below capacity,
    and the concurrent path must have scored bit-identically.
    """
    failures: list[str] = []
    sv = bench.get("serving")
    if sv is None:
        if require_serving:
            print("FAIL serving: section missing from bench output "
                  "(run benchmarks/serving_bench.py)")
            return ["serving_section"]
        if any(key.startswith("serving_") for key in thr):
            print("note serving: section absent, serving gates skipped")
        return failures

    def gate_max(name, value, bound):
        tag = "ok  " if value <= bound else "FAIL"
        print(f"{tag} {name}: {value:.4f} (max {bound})")
        if value > bound:
            failures.append(name)

    def gate_min(name, value, bound):
        tag = "ok  " if value >= bound else "FAIL"
        print(f"{tag} {name}: {value:.4f} (min {bound})")
        if value < bound:
            failures.append(name)

    if "serving_min_goodput_rps" in thr:
        gate_min("serving.peak_goodput_rps",
                 float(sv["peak_goodput_rps"]),
                 thr["serving_min_goodput_rps"])
    if "serving_overload_p99_ms_max" in thr:
        p99 = sv["overload_p99_ms"]
        if p99 is None:
            # complete starvation at the overload rung: nothing finished
            print("FAIL serving.overload_p99_ms: no request completed "
                  "at the overload rung")
            failures.append("serving.overload_p99_ms")
        else:
            gate_max("serving.overload_p99_ms", float(p99),
                     thr["serving_overload_p99_ms_max"])
    if "serving_overload_shed_min" in thr:
        gate_min("serving.overload_shed_rate",
                 float(sv["overload_shed_rate"]),
                 thr["serving_overload_shed_min"])
    if "serving_low_load_shed_max" in thr:
        gate_max("serving.low_load_shed_rate",
                 float(sv["low_load_shed_rate"]),
                 thr["serving_low_load_shed_max"])
    if sv.get("bit_identical") is not True:
        print("FAIL serving.bit_identical: concurrent-path scores diverged "
              "from synchronous score()")
        failures.append("serving.bit_identical")
    else:
        print("ok   serving.bit_identical: concurrent == sequential")
    return failures


def check_store(bench: dict, thr: dict, *,
                require_store: bool = False) -> list[str]:
    """Gate the ``"store"`` section (stream_bench.py --store).

    Optional in a plain bench run; ``--require-store`` (the nightly
    long-stream-smoke lane) makes its absence a failure.  Gates: the
    tiered tree's packed root must be bit-identical to the in-memory
    tree's, ingest slowdown under the tier is bounded, resident-set
    growth over the second half of the long stream is bounded (the
    bounded-memory claim), the tier actually engaged (spills and
    page-ins both nonzero), and an unchanged-root refresh skipped the
    second-level fit.
    """
    failures: list[str] = []
    st = bench.get("store")
    if st is None:
        if require_store:
            print("FAIL store: section missing from bench output "
                  "(run benchmarks/stream_bench.py --store)")
            return ["store_section"]
        if any(key.startswith("store_") for key in thr):
            print("note store: section absent, store gates skipped")
        return failures

    def gate_max(name, value, bound):
        tag = "ok  " if value <= bound else "FAIL"
        print(f"{tag} {name}: {value:.4f} (max {bound})")
        if value > bound:
            failures.append(name)

    if "store_max_ingest_slowdown_frac" in thr:
        gate_max("store.ingest_slowdown_frac",
                 float(st["ingest_slowdown_frac"]),
                 thr["store_max_ingest_slowdown_frac"])
    if "store_max_rss_growth_frac" in thr:
        growth = st.get("rss_growth_frac")
        if growth is None:
            if require_store:
                print("FAIL store.rss_growth_frac: unmeasured "
                      "(no /proc/self/status on this platform)")
                failures.append("store.rss_growth_frac")
            else:
                print("note store.rss_growth_frac: unmeasured, skipped")
        else:
            gate_max("store.rss_growth_frac", float(growth),
                     thr["store_max_rss_growth_frac"])
    for flag in ("bit_identical", "refresh_skipped"):
        if st.get(flag) is not True:
            print(f"FAIL store.{flag}: tiered run broke the contract")
            failures.append(f"store.{flag}")
        else:
            print(f"ok   store.{flag}")
    for tally in ("spills", "page_ins"):
        v = int(st.get(tally, 0))
        tag = "ok  " if v > 0 else "FAIL"
        print(f"{tag} store.{tally}: {v} (min 1 — the tier must engage)")
        if v <= 0:
            failures.append(f"store.{tally}")
    return failures


_SUMMARIZE_DATASETS = ("gauss", "kdd_like")


def check_summarize(bench: dict | None, thr: dict) -> list[str]:
    """Gate BENCH_summarize.json under the ``summarize_*`` thresholds."""
    failures: list[str] = []
    if not any(key.startswith("summarize_") for key in thr):
        return failures
    if bench is None:
        print("FAIL summarize: BENCH_summarize.json missing "
              "(run benchmarks/summarizer_bench.py)")
        return ["summarize_bench_missing"]

    def gate_min(name, value, bound):
        tag = "ok  " if value >= bound else "FAIL"
        print(f"{tag} {name}: {value:.4f} (min {bound})")
        if value < bound:
            failures.append(name)

    for ds in _SUMMARIZE_DATASETS:
        summ = bench.get("datasets", {}).get(ds, {}).get("summarizers", {})
        need = int(thr.get("summarize_min_summarizers", 0))
        if len(summ) < need:
            print(f"FAIL summarize.{ds}: {len(summ)} summarizers < {need}")
            failures.append(f"summarize.{ds}.count")
            continue
        print(f"ok   summarize.{ds}: {len(summ)} summarizers compared")
        if "summarize_paper_min_recall" in thr:
            gate_min(f"summarize.{ds}.paper.recall",
                     float(summ["paper"]["recall"]),
                     thr["summarize_paper_min_recall"])
        if "summarize_recall_margin_min" in thr:
            margin = (float(summ["paper"]["recall"])
                      - float(summ["uniform"]["recall"]))
            gate_min(f"summarize.{ds}.paper_vs_uniform_recall_margin",
                     margin, thr["summarize_recall_margin_min"])
    if "summarize_cosine_mass_err_max" in thr:
        cz = bench.get("cosine", {}).get("summarizers", {})
        if not cz:
            print("FAIL summarize.cosine: section missing")
            failures.append("summarize.cosine")
        for name, e in cz.items():
            err = float(e["mass_err"])
            bound = thr["summarize_cosine_mass_err_max"]
            tag = "ok  " if err <= bound else "FAIL"
            print(f"{tag} summarize.cosine.{name}.mass_err: "
                  f"{err:.2e} (max {bound})")
            if err > bound:
                failures.append(f"summarize.cosine.{name}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=str(_ROOT / "BENCH_stream.json"))
    ap.add_argument("--summarize-bench",
                    default=str(_ROOT / "BENCH_summarize.json"))
    ap.add_argument("--thresholds",
                    default=str(_ROOT / "benchmarks" / "stream_thresholds.json"))
    ap.add_argument("--require-serving", action="store_true",
                    help="fail if the bench has no 'serving' section "
                         "(the serve-load CI lane sets this; a plain "
                         "bench-smoke run may legitimately omit it)")
    ap.add_argument("--require-store", action="store_true",
                    help="fail if the bench has no 'store' section "
                         "(the nightly long-stream-smoke lane sets this)")
    args = ap.parse_args()
    bench = json.loads(Path(args.bench).read_text())
    thr = json.loads(Path(args.thresholds).read_text())
    sb_path = Path(args.summarize_bench)
    summarize_bench = (json.loads(sb_path.read_text())
                       if sb_path.exists() else None)
    failures = (check(bench, thr)
                + check_serving(bench, thr,
                                require_serving=args.require_serving)
                + check_store(bench, thr, require_store=args.require_store)
                + check_summarize(summarize_bench, thr))
    if failures:
        print(f"regression gate FAILED: {', '.join(failures)}",
              file=sys.stderr)
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
