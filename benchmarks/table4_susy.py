"""Paper Table 4: clustering quality on susy-Delta (matched synthetic
stand-in), k=100, t=5000 at paper scale; scaled by default.
"""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, evaluate, print_rows
from repro.data.synthetic import partition, susy_like


def run(scale: float = 0.06, sites: int = 20, seed: int = 0):
    rows_all = {}
    n = int(5_000_000 * scale)
    t = max(50, int(5_000 * scale * 2))
    k = max(20, int(100 * min(1.0, scale * 10)))
    for delta in (5.0, 10.0):
        x, out_ids = susy_like(n=n, t=t, delta=delta, seed=seed)
        parts, gids = partition(x, sites, "random", seed=seed,
                                outlier_ids=out_ids)
        rows = evaluate(x, out_ids, parts, gids, k, t, seed=seed)
        print_rows(f"table4 susy-{delta:.0f} n={n} k={k} t={t} s={sites}", rows)
        rows_all[f"susy-{delta:.0f}"] = rows
    return rows_all


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--sites", type=int, default=20)
    args = ap.parse_args()
    rows = run(scale=args.scale, sites=args.sites)
    for name, rr in rows.items():
        for line in csv_rows(f"table4/{name}", rr):
            print(line)


if __name__ == "__main__":
    main()
