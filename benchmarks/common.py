"""Shared benchmark machinery: run every algorithm of Section 5 on a
dataset partitioned across s sites, with budget-matched summary sizes, and
report the paper's metrics (summary size, l1/l2 loss, preRec/prec/recall,
communication, wall time).

Scaling note: the container is a single CPU core, so dataset sizes default
to ~100-500k points instead of the paper's 1-5M; every entry point takes
--scale to restore paper-scale sizes on real hardware.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.augmented import augmented_summary_compact
from repro.core import (kmeans_minus_minus, kmeans_parallel_summary,
                        kmeanspp_summary, local_budget, rand_summary)
from repro.core.metrics import clustering_losses, outlier_scores
from repro.kernels.dispatch import KernelPolicy
from repro.summarize import SummarizerPolicy, get_summarizer, summarize

# one shared policy for the wall-clock benches: big blocked tiles (the
# compact host loops stream dataset-sized n through min_argmin)
_POLICY = KernelPolicy(block_n=65536)

ALGOS = ("ball-grow", "k-means++", "k-means||", "rand")


@dataclass
class Row:
    algo: str
    summary: int
    l1: float
    l2: float
    pre_rec: float
    prec: float
    recall: float
    comm: float
    t_summary: float   # wall seconds to build all summaries (parallel model)
    t_second: float    # coordinator second-level seconds


def _second_level(pts, wts, gids, k, t, key, policy=_POLICY):
    n = pts.shape[0]
    t0 = time.perf_counter()
    sol = kmeans_minus_minus(jnp.asarray(pts), jnp.asarray(wts),
                             jnp.ones((n,), bool), key, k=k, t=float(t),
                             iters=25, policy=policy)
    jax.block_until_ready(sol.centers)
    dt = time.perf_counter() - t0
    out = gids[np.asarray(sol.outlier)]
    return np.asarray(sol.centers), out, dt


def run_algo(algo: str, parts, gids_parts, k: int, t: int, key,
             budget_per_site: int | None = None, sites_meta: int | None = None):
    """Build per-site summaries + coordinator clustering for one algorithm.
    Returns (summary records dict, timings)."""
    s = len(parts)
    t_i = local_budget(t, s, "random")
    all_pts, all_w, all_gid = [], [], []
    t_sites = []
    comm_extra = 0.0
    warmed = False
    for i, part in enumerate(parts):
        skey = jax.random.fold_in(key, i)
        xj = jnp.asarray(part)
        if not warmed and algo in ("k-means++", "k-means||", "rand"):
            # exclude one-time jit compile from the paper's time comparison
            if algo == "k-means++":
                jax.block_until_ready(kmeanspp_summary(
                    xj, skey, budget=budget_per_site, policy=_POLICY).points)
            elif algo == "k-means||":
                jax.block_until_ready(kmeans_parallel_summary(
                    xj, skey, budget=budget_per_site, sites=sites_meta or s,
                    policy=_POLICY).summary.points)
            else:
                jax.block_until_ready(rand_summary(
                    xj, skey, budget=budget_per_site, policy=_POLICY).points)
            warmed = True
        t0 = time.perf_counter()
        if algo == "ball-grow":
            # host-compacted path: the paper's O(max{k,log n}*n + t*n) cost
            summ = augmented_summary_compact(part, skey, k=k, t=t_i,
                                             policy=_POLICY)
        elif algo == "k-means++":
            summ = kmeanspp_summary(xj, skey, budget=budget_per_site,
                                    policy=_POLICY)
        elif algo == "k-means||":
            res = kmeans_parallel_summary(xj, skey, budget=budget_per_site,
                                          sites=sites_meta or s, policy=_POLICY)
            summ = res.summary
            comm_extra += float(res.comm_records) / s  # multi-round overhead
        elif algo == "rand":
            summ = rand_summary(xj, skey, budget=budget_per_site, policy=_POLICY)
        else:
            raise ValueError(algo)
        jax.block_until_ready(summ.points)
        t_sites.append(time.perf_counter() - t0)
        valid = np.asarray(summ.valid)
        all_pts.append(np.asarray(summ.points)[valid])
        all_w.append(np.asarray(summ.weights)[valid])
        all_gid.append(gids_parts[i][np.asarray(summ.indices)[valid]])
    pts = np.concatenate(all_pts)
    wts = np.concatenate(all_w)
    gid = np.concatenate(all_gid)
    # parallel-sites wall model: median site (robust to the one-time jit
    # compile landing on site 0 for the algorithms without a warmup path)
    return pts, wts, gid, float(np.median(t_sites)), float(len(gid)) + comm_extra


def _score_union(name, x, out_ids, pts, wts, gid, k, t, key, *,
                 comm, t_summary) -> Row:
    """Shared scoring tail: second level on the gathered union + the
    paper's Section 5 metrics — one protocol for the paper-table algos and
    the summarizer registry, so the two benches stay comparable."""
    centers, reported, t_second = _second_level(pts, wts, gid, k, t, key)
    sc = outlier_scores(out_ids, gid, reported)
    mask = np.zeros(x.shape[0], bool)
    mask[reported] = True
    l1, l2 = clustering_losses(jnp.asarray(x), jnp.asarray(centers),
                               jnp.asarray(mask), policy=_POLICY)
    return Row(algo=name, summary=len(gid), l1=float(l1), l2=float(l2),
               pre_rec=sc.pre_recall, prec=sc.precision, recall=sc.recall,
               comm=comm, t_summary=t_summary, t_second=t_second)


def evaluate(x, out_ids, parts, gids_parts, k, t, *, seed=0,
             algos=ALGOS) -> list[Row]:
    key = jax.random.key(seed)
    rows = []
    budget = None
    for algo in algos:
        pts, wts, gid, t_sum, comm = run_algo(
            algo, parts, gids_parts, k, t, key, budget_per_site=budget)
        if algo == "ball-grow":  # size-match the baselines to ball-grow
            budget = max(1, int(math.ceil(len(gid) / len(parts))))
        rows.append(_score_union(algo, x, out_ids, pts, wts, gid, k, t,
                                 jax.random.fold_in(key, 999),
                                 comm=comm, t_summary=t_sum))
    return rows


def run_summarizer(policy: SummarizerPolicy, parts, gids_parts, k: int, t: int,
                   key, *, metric: str = "l2sq", kernel_policy=_POLICY):
    """Per-site summaries through the ``repro.summarize`` registry.

    Every registered summarizer runs through its weighted entry point with
    unit weights (the host-driven coordinator model), so host-only
    algorithms (ball_cover, coreset) benchmark on equal footing with the
    paper's.  Returns (pts, wts, gid, t_summary_median, comm_records).
    """
    s = len(parts)
    t_i = local_budget(t, s, "random")
    all_pts, all_w, all_gid = [], [], []
    t_sites = []
    for i, part in enumerate(parts):
        skey = jax.random.fold_in(key, i)
        w1 = np.ones((part.shape[0],), np.float32)
        if i == 0:   # exclude the one-time jit compile from the site clock
            summarize(part, w1, skey, k=k, t=t_i, metric=metric,
                      policy=policy, kernel_policy=kernel_policy)
        t0 = time.perf_counter()
        summ = summarize(part, w1, skey, k=k, t=t_i, metric=metric,
                         policy=policy, kernel_policy=kernel_policy)
        t_sites.append(time.perf_counter() - t0)
        all_pts.append(np.asarray(summ.points))
        all_w.append(np.asarray(summ.weights))
        all_gid.append(gids_parts[i][np.asarray(summ.indices)])
    pts = np.concatenate(all_pts)
    wts = np.concatenate(all_w)
    gid = np.concatenate(all_gid)
    return pts, wts, gid, float(np.median(t_sites)), float(len(gid))


def evaluate_summarizers(x, out_ids, parts, gids_parts, k, t, policies,
                         *, metric: str = "l2sq", seed: int = 0,
                         match_to: str | None = "paper") -> list[Row]:
    """Head-to-head over summarizer policies: one :class:`Row` each.

    ``match_to`` names the policy whose summary size budgets the others
    (budget-accepting summarizers get ``budget=ceil(size / sites)`` unless
    their params already pin one), so the comparison is at matched
    communication — the paper's Tables 2–4 protocol.
    """
    key = jax.random.key(seed)
    rows: list[Row] = []
    budget = None
    ordered = sorted(policies, key=lambda p: (p.name != match_to))
    for pol in ordered:
        if (budget is not None and pol.name != match_to
                and get_summarizer(pol.name).sized
                and "budget" not in pol.params_dict()):
            pol = pol.with_params(budget=budget)
        pts, wts, gid, t_sum, comm = run_summarizer(
            pol, parts, gids_parts, k, t, key, metric=metric)
        if pol.name == match_to and budget is None:
            budget = max(1, int(math.ceil(len(gid) / len(parts))))
        rows.append(_score_union(pol.name, x, out_ids, pts, wts, gid, k, t,
                                 jax.random.fold_in(key, 999),
                                 comm=comm, t_summary=t_sum))
    return rows


def print_rows(title: str, rows: list[Row]):
    print(f"\n== {title} ==")
    print(f"{'algo':12s} {'summary':>8s} {'l1-loss':>10s} {'l2-loss':>10s} "
          f"{'preRec':>7s} {'prec':>7s} {'recall':>7s} {'comm':>9s} "
          f"{'t_sum(s)':>8s} {'t_2nd(s)':>8s}")
    for r in rows:
        print(f"{r.algo:12s} {r.summary:8d} {r.l1:10.3e} {r.l2:10.3e} "
              f"{r.pre_rec:7.4f} {r.prec:7.4f} {r.recall:7.4f} {r.comm:9.0f} "
              f"{r.t_summary:8.2f} {r.t_second:8.2f}")


def csv_rows(name: str, rows: list[Row]) -> list[str]:
    out = []
    for r in rows:
        us = r.t_summary * 1e6
        derived = (f"l1={r.l1:.4g};l2={r.l2:.4g};preRec={r.pre_rec:.4f};"
                   f"prec={r.prec:.4f};recall={r.recall:.4f};"
                   f"summary={r.summary};comm={r.comm:.0f}")
        out.append(f"{name}/{r.algo},{us:.0f},{derived}")
    return out
