"""Paper Figure 1: (a) communication vs #sites, (b) summary-construction
time vs #sites, (c) time vs summary size — kddSp-like data.

ball-grow / k-means++ / rand communicate one round (cost = summary union);
k-means|| pays per-round gather+broadcast that grows with s (Fig 1a).
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

from benchmarks.common import run_algo, ALGOS
from repro.data.synthetic import kdd_like, partition


def fig1a_comm(x, out_ids, k, t, sites_list, seed=0):
    print("\n== fig1a: communication (records) vs #sites ==")
    print(f"{'algo':12s} " + " ".join(f"s={s:<7d}" for s in sites_list))
    key = jax.random.key(seed)
    rows = {}
    for algo in ALGOS:
        comms = []
        for s in sites_list:
            parts, gids = partition(x, s, "random", seed=seed,
                                    outlier_ids=out_ids)
            budget = None
            if algo != "ball-grow":
                pts, _, gid, _, _ = run_algo("ball-grow", parts, gids, k, t, key)
                budget = max(1, int(np.ceil(len(gid) / s)))
            _, _, gid, _, comm = run_algo(algo, parts, gids, k, t, key,
                                          budget_per_site=budget, sites_meta=s)
            comms.append(comm)
        rows[algo] = comms
        print(f"{algo:12s} " + " ".join(f"{c:<9.0f}" for c in comms))
    return rows


def fig1b_time(x, out_ids, k, t, sites_list, seed=0):
    print("\n== fig1b: summary construction wall time (s, parallel-site model) ==")
    print(f"{'algo':12s} " + " ".join(f"s={s:<7d}" for s in sites_list))
    key = jax.random.key(seed)
    rows = {}
    for algo in ALGOS:
        ts = []
        for s in sites_list:
            parts, gids = partition(x, s, "random", seed=seed,
                                    outlier_ids=out_ids)
            budget = None
            if algo != "ball-grow":
                _, _, gid, _, _ = run_algo("ball-grow", parts, gids, k, t, key)
                budget = max(1, int(np.ceil(len(gid) / s)))
            _, _, _, t_sum, _ = run_algo(algo, parts, gids, k, t, key,
                                         budget_per_site=budget, sites_meta=s)
            ts.append(t_sum)
        rows[algo] = ts
        print(f"{algo:12s} " + " ".join(f"{v:<9.2f}" for v in ts))
    return rows


def fig1c_time_vs_summary(x, out_ids, k, seed=0, sites=10):
    print("\n== fig1c: time vs summary size (vary t) ==")
    key = jax.random.key(seed)
    parts, gids = partition(x, sites, "random", seed=seed, outlier_ids=out_ids)
    rows = {}
    for t in (len(out_ids) // 4, len(out_ids) // 2, len(out_ids),
              2 * len(out_ids)):
        _, _, gid, t_bg, _ = run_algo("ball-grow", parts, gids, k, t, key)
        budget = max(1, int(np.ceil(len(gid) / sites)))
        line = {"summary": len(gid), "ball-grow": t_bg}
        for algo in ("k-means++", "k-means||", "rand"):
            _, _, _, t_sum, _ = run_algo(algo, parts, gids, k, t, key,
                                         budget_per_site=budget, sites_meta=sites)
            line[algo] = t_sum
        rows[t] = line
        print(f"t={t:<7d} summary={line['summary']:<8d} " +
              " ".join(f"{a}={line[a]:.2f}s" for a in ALGOS))
    return rows


def run(scale: float = 0.2, seed: int = 0):
    n = int(490_000 * scale)
    x, out_ids = kdd_like(n=n, seed=seed)
    k, t = 3, len(out_ids)
    sites = [2, 5, 10, 20]
    a = fig1a_comm(x, out_ids, k, t, sites, seed)
    b = fig1b_time(x, out_ids, k, t, sites, seed)
    c = fig1c_time_vs_summary(x, out_ids, k, seed)
    return a, b, c


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    args = ap.parse_args()
    a, b, c = run(scale=args.scale)
    for algo, comms in a.items():
        print(f"fig1a/{algo},{0:.0f},comm=" + "|".join(f"{v:.0f}" for v in comms))
    for algo, ts in b.items():
        print(f"fig1b/{algo},{ts[-1]*1e6:.0f},time_s=" +
              "|".join(f"{v:.2f}" for v in ts))


if __name__ == "__main__":
    main()
