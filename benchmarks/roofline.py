"""Roofline table from the dry-run artifacts (EXPERIMENTS §Roofline).

Reads artifacts/dryrun/*.json and prints, per (arch x shape x mesh):
compute/memory/collective seconds, the dominant term, MODEL_FLOPS ratio.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(art_dir="artifacts/dryrun", mesh="single"):
    rows = []
    for p in sorted(Path(art_dir).glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh:
            continue
        rows.append(d)
    return rows


def print_table(rows, show_skipped=True):
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bound':>7s} {'useful%':>8s} {'drun%':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for d in rows:
        if d["status"] == "skipped":
            if show_skipped:
                print(f"{d['arch']:26s} {d['shape']:12s} {'— skipped: ' + d['reason'][:60]}")
            continue
        if d["status"] != "ok":
            print(f"{d['arch']:26s} {d['shape']:12s} FAILED")
            continue
        dom = max(d["compute_s"], d["memory_s"], d["collective_s"])
        mf = d.get("model_flops_per_chip")
        ach = (mf / 197e12) / dom if (dom and mf) else 0
        print(f"{d['arch']:26s} {d['shape'][:12]:12s} {d['compute_s']:10.4f} "
              f"{d['memory_s']:10.4f} {d['collective_s']:10.4f} "
              f"{d['bottleneck'][:7]:>7s} "
              f"{100*(d.get('useful_flops_ratio') or 0):8.1f} {100*ach:6.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "single-opt"])
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print_table(rows)
    for d in rows:
        if d["status"] == "ok":
            dom = max(d["compute_s"], d["memory_s"], d["collective_s"])
            ach = (d["model_flops_per_chip"] / 197e12) / dom if dom else 0
            print(f"roofline/{d['arch']}/{d['shape']},{dom*1e6:.0f},"
                  f"bound={d['bottleneck']};roofline_frac={ach:.4f};"
                  f"useful={d['useful_flops_ratio'] or 0:.4f}")


if __name__ == "__main__":
    main()
