"""Roofline table from the dry-run artifacts (EXPERIMENTS §Roofline).

Reads artifacts/dryrun/*.json and prints, per (arch x shape x mesh):
compute/memory/collective seconds, the dominant term, MODEL_FLOPS ratio.

``--kernels`` instead annotates the ``"kernels"`` section that
``benchmarks/stream_bench.py`` emits into ``BENCH_stream.json``: per
measured (op, backend) it derives FLOPs and bytes-moved per call from the
benchmark shape, writes achieved GFLOP/s, GB/s and arithmetic intensity
back into the JSON, and prints the table — so a kernel regression shows up
with its roofline context in the same artifact the CI gate reads.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

_BENCH_STREAM = Path(__file__).resolve().parent.parent / "BENCH_stream.json"


def _kernel_work(op: str, n: int, m: int, d: int) -> tuple[float, float]:
    """(flops, bytes) per call of one fused op at (n, m, d), f32.

    min_argmin: the l2 path is one (n,d)@(d,m) matmul plus the row
    reductions; lloyd_step adds the one-hot accumulate matmul (same FLOP
    count as the distance matmul); score is min_argmin plus the threshold
    divide (n more flops) and a third (n,)-shaped output.  Bytes model the
    streaming working set (read x and c, write the (n,)-shaped outputs),
    not the distance matrix — the whole point of the blocked/Pallas paths
    is that it never materializes in HBM.
    """
    dist_flops = 2.0 * n * m * d + 4.0 * n * m
    io_bytes = 4.0 * (n * d + m * d + 2 * n)
    if op == "lloyd_step":
        return dist_flops + 2.0 * n * m * d, io_bytes + 4.0 * (m * d + m)
    if op == "score":
        return dist_flops + float(n), io_bytes + 4.0 * n
    return dist_flops, io_bytes


def annotate_kernels(bench_path: Path = _BENCH_STREAM) -> dict:
    """Fold roofline terms into BENCH_stream.json's "kernels" section."""
    bench = json.loads(Path(bench_path).read_text())
    kb = bench.get("kernels")
    if not kb:
        raise SystemExit(
            f"{bench_path} has no 'kernels' section — run "
            f"benchmarks/stream_bench.py first")
    n, m, d = kb["n"], kb["m"], kb["d"]
    for op, backends in kb["ops"].items():
        flops, bts = _kernel_work(op, n, m, d)
        for entry in backends.values():
            if "us_per_call" not in entry:
                continue
            t = entry["us_per_call"] * 1e-6
            entry["achieved_gflops"] = round(flops / t / 1e9, 2)
            entry["achieved_gb_s"] = round(bts / t / 1e9, 3)
            entry["ai_flops_per_byte"] = round(flops / bts, 2)
    Path(bench_path).write_text(json.dumps(bench, indent=2) + "\n")
    return kb


def print_kernels(kb: dict) -> None:
    hdr = (f"{'op/backend':28s} {'tile':>12s} {'us':>10s} "
           f"{'GFLOP/s':>9s} {'GB/s':>8s} {'AI':>6s}")
    print(f"kernels @ n={kb['n']} m={kb['m']} d={kb['d']} "
          f"({kb['metric']}, {kb['platform']})")
    print(hdr)
    print("-" * len(hdr))
    for op, backends in kb["ops"].items():
        for name, e in sorted(backends.items()):
            if "us_per_call" not in e:
                print(f"{op + '/' + name:28s} {'— ' + e['skipped']}")
                continue
            tile = (f"{e['block_n']}x{e['block_m']}" if "block_m" in e
                    else f"{e['block_n']}")
            print(f"{op + '/' + name:28s} {tile:>12s} "
                  f"{e['us_per_call']:10.1f} {e['achieved_gflops']:9.2f} "
                  f"{e['achieved_gb_s']:8.3f} {e['ai_flops_per_byte']:6.2f}")
    fu, qu = kb.get("fused"), kb.get("quant")
    if fu:
        print(f"{'score fused-vs-composed':28s} "
              f"{fu['fused_us']:.1f} us vs {fu['composed_us']:.1f} us "
              f"(speedup {fu['speedup']:.2f}x)")
    if qu:
        print(f"{'score int8 error':28s} max {qu['max_score_err']:.4f} "
              f"mean {qu['mean_score_err']:.5f} "
              f"flips {100 * qu['argmin_flip_frac']:.2f}%")


def load(art_dir="artifacts/dryrun", mesh="single"):
    rows = []
    for p in sorted(Path(art_dir).glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh:
            continue
        rows.append(d)
    return rows


def print_table(rows, show_skipped=True):
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bound':>7s} {'useful%':>8s} {'drun%':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for d in rows:
        if d["status"] == "skipped":
            if show_skipped:
                print(f"{d['arch']:26s} {d['shape']:12s} {'— skipped: ' + d['reason'][:60]}")
            continue
        if d["status"] != "ok":
            print(f"{d['arch']:26s} {d['shape']:12s} FAILED")
            continue
        dom = max(d["compute_s"], d["memory_s"], d["collective_s"])
        mf = d.get("model_flops_per_chip")
        ach = (mf / 197e12) / dom if (dom and mf) else 0
        print(f"{d['arch']:26s} {d['shape'][:12]:12s} {d['compute_s']:10.4f} "
              f"{d['memory_s']:10.4f} {d['collective_s']:10.4f} "
              f"{d['bottleneck'][:7]:>7s} "
              f"{100*(d.get('useful_flops_ratio') or 0):8.1f} {100*ach:6.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "single-opt"])
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--kernels", action="store_true",
                    help="annotate + print BENCH_stream.json's kernels section")
    ap.add_argument("--bench", default=str(_BENCH_STREAM))
    args = ap.parse_args()
    if args.kernels:
        print_kernels(annotate_kernels(Path(args.bench)))
        return
    rows = load(args.dir, args.mesh)
    print_table(rows)
    for d in rows:
        if d["status"] == "ok":
            dom = max(d["compute_s"], d["memory_s"], d["collective_s"])
            ach = (d["model_flops_per_chip"] / 197e12) / dom if dom else 0
            print(f"roofline/{d['arch']}/{d['shape']},{dom*1e6:.0f},"
                  f"bound={d['bottleneck']};roofline_frac={ach:.4f};"
                  f"useful={d['useful_flops_ratio'] or 0:.4f}")


if __name__ == "__main__":
    main()
