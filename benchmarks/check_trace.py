"""Validate an exported Chrome trace-event file — stdlib only, CI-gated.

Checks the structural contract Perfetto / ``chrome://tracing`` relies on
and the invariants our exporter promises:

* top level is ``{"traceEvents": [...], ...}`` with a non-empty list;
* every event is well-formed: non-empty ``name``, ``ph``, numeric
  ``ts >= 0``, ``pid``/``tid`` present; complete events (``ph: "X"``)
  carry a numeric ``dur >= 0``;
* timestamps are monotone non-decreasing in file order (the exporter
  sorts);
* every span carries ``args.trace_id``/``args.span_id``, span ids are
  unique, and every non-null ``args.parent_id`` references a span that
  exists in the file (the exporter filters ring-evicted orphans);
* a parent's interval contains its children's start (small tolerance for
  clock jitter between retroactively recorded spans).

Usage::

    python benchmarks/check_trace.py trace.json \
        [--require serve.request --require score.fused ...] \
        [--min-events 1] [--min-traces 1]

``--require NAME`` demands at least one event whose name equals NAME or
starts with ``NAME.``.  Exit 0 = valid, 1 = problems (each printed as a
``FAIL`` line).
"""
from __future__ import annotations

import argparse
import json
import sys

# children may start marginally before a retroactively-recorded parent's
# stamp lands (different threads stamp the endpoints); 50 microseconds
# absorbs that without hiding real mis-parenting
_CONTAINMENT_SLOP_US = 50.0


def validate_trace(doc) -> list[str]:
    """Returns a list of problems (empty = valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    if not events:
        return ["'traceEvents' is empty — nothing was recorded"]
    spans: dict[int, dict] = {}
    last_ts = None
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing/empty 'name'")
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            errors.append(f"{where}: missing 'ph'")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) or ts < 0:
            errors.append(f"{where} ({name}): 'ts' must be a number >= 0, "
                          f"got {ts!r}")
            ts = None
        for key in ("pid", "tid"):
            if key not in ev:
                errors.append(f"{where} ({name}): missing '{key}'")
        if ts is not None:
            if last_ts is not None and ts < last_ts:
                errors.append(f"{where} ({name}): ts {ts} < previous "
                              f"{last_ts} — events must be sorted")
            last_ts = ts
        args = ev.get("args")
        if not isinstance(args, dict):
            errors.append(f"{where} ({name}): missing 'args' object")
            continue
        if "trace_id" not in args or "span_id" not in args:
            errors.append(f"{where} ({name}): args must carry "
                          f"trace_id and span_id")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float)) or isinstance(dur, bool)
                    or dur < 0):
                errors.append(f"{where} ({name}): complete event needs a "
                              f"numeric 'dur' >= 0, got {dur!r}")
                continue
            sid = args["span_id"]
            if sid in spans:
                errors.append(f"{where} ({name}): duplicate span_id {sid}")
            spans[sid] = ev
    # parent existence + containment (second pass: parents can sort later)
    for ev in events:
        if not isinstance(ev, dict) or ev.get("ph") != "X":
            continue
        args = ev.get("args")
        if not isinstance(args, dict):
            continue
        pid = args.get("parent_id")
        if pid is None:
            continue
        parent = spans.get(pid)
        if parent is None:
            errors.append(f"span {args.get('span_id')} ({ev.get('name')}): "
                          f"parent_id {pid} does not exist in the file")
            continue
        if parent["args"].get("trace_id") != args.get("trace_id"):
            errors.append(f"span {args.get('span_id')} ({ev.get('name')}): "
                          f"parent {pid} belongs to a different trace")
        p0 = parent["ts"] - _CONTAINMENT_SLOP_US
        p1 = parent["ts"] + parent["dur"] + _CONTAINMENT_SLOP_US
        if not (p0 <= ev["ts"] <= p1):
            errors.append(f"span {args.get('span_id')} ({ev.get('name')}): "
                          f"starts at {ev['ts']} outside parent "
                          f"{parent.get('name')} [{p0}, {p1}]")
    return errors


def check_required(doc, required: list[str]) -> list[str]:
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    names = {ev.get("name") for ev in events if isinstance(ev, dict)}
    errors = []
    for want in required:
        if not any(isinstance(n, str)
                   and (n == want or n.startswith(want + "."))
                   for n in names):
            errors.append(f"required event {want!r} (or {want}.*) absent")
    return errors


def summarize(doc) -> str:
    events = doc.get("traceEvents", [])
    traces = {ev.get("args", {}).get("trace_id") for ev in events
              if isinstance(ev, dict)}
    n_spans = sum(1 for ev in events
                  if isinstance(ev, dict) and ev.get("ph") == "X")
    return (f"{len(events)} events ({n_spans} spans) across "
            f"{len(traces)} traces")


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Validate a Chrome trace-event JSON export.")
    ap.add_argument("trace", help="path to the exported trace file")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME",
                    help="demand an event named NAME (or NAME.*); "
                         "repeatable")
    ap.add_argument("--min-events", type=int, default=1)
    ap.add_argument("--min-traces", type=int, default=1)
    args = ap.parse_args()
    try:
        doc = json.loads(open(args.trace).read())
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot load {args.trace}: {e}")
        return 1
    errors = validate_trace(doc)
    errors += check_required(doc, args.require)
    if not errors:
        events = doc["traceEvents"]
        traces = {ev["args"]["trace_id"] for ev in events}
        if len(events) < args.min_events:
            errors.append(f"only {len(events)} events "
                          f"(< {args.min_events})")
        if len(traces) < args.min_traces:
            errors.append(f"only {len(traces)} traces "
                          f"(< {args.min_traces})")
    for e in errors:
        print(f"FAIL: {e}")
    if errors:
        print(f"check_trace: {args.trace}: {len(errors)} problem(s)")
        return 1
    print(f"check_trace: {args.trace} OK — {summarize(doc)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
