"""Paper Table 2: clustering quality on gauss-sigma, k=100, t=5000, s=20.

Container default is scaled to n=100k (k=50, t=500); --scale 1.0 restores
the paper's 1M-point setup.
"""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, evaluate, print_rows
from repro.data.synthetic import gauss, partition


def run(scale: float = 0.1, sites: int = 20, seed: int = 0):
    rows_all = {}
    n_centers = max(10, int(100 * scale))
    per_center = max(200, int(10_000 * scale))
    t = max(50, int(5_000 * scale))
    k = n_centers
    for sigma in (0.1, 0.4):
        x, out_ids = gauss(n_centers=n_centers, per_center=per_center,
                           sigma=sigma, t=t, seed=seed)
        parts, gids = partition(x, sites, "random", seed=seed,
                                outlier_ids=out_ids)
        rows = evaluate(x, out_ids, parts, gids, k, t, seed=seed)
        print_rows(f"table2 gauss-{sigma} n={x.shape[0]} k={k} t={t} s={sites}",
                   rows)
        rows_all[f"gauss-{sigma}"] = rows
    return rows_all


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--sites", type=int, default=20)
    args = ap.parse_args()
    rows = run(scale=args.scale, sites=args.sites)
    for name, rr in rows.items():
        for line in csv_rows(f"table2/{name}", rr):
            print(line)


if __name__ == "__main__":
    main()
