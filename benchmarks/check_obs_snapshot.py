"""Validate a ``repro.obs`` metrics snapshot against the checked-in schema.

The snapshot dict returned by ``Session.stats()`` (and emitted by
``python -m repro stats`` / ``serve --metrics-interval``) is a cross-PR
surface: dashboards and the Prometheus renderer parse it.  The CI
obs-smoke job produces a snapshot from a real run and feeds it here; the
gate fails if the shape drifted from ``benchmarks/obs_schema.json`` or if
the snapshot's internal invariants break:

  * bucket counts are cumulative, hence non-decreasing in ``le`` order,
    and the ``+Inf`` bucket equals ``count``;
  * percentiles are ordered: p50 <= p95 <= p99 (when present);
  * ``min <= p50 <= max``;
  * every metric key parses as ``name`` or ``name{k=v,...}``.

``--require PREFIX`` (repeatable) additionally asserts that at least one
metric key starts with the prefix — the job lists the series every layer
must contribute (serve latency, refresh phases, comm counters, kernel
dispatch, checkpoint durations), which is the acceptance criterion "one
snapshot covers every layer" kept true by CI.  ``--require-set NAME``
expands to every prefix of the schema file's ``x-required-series[NAME]``
list — the serve-load-smoke job passes ``--require-set serving`` to
demand the scheduler's queue-depth / shed / occupancy / per-tenant
latency series without repeating the list in the workflow.

The validator interprets the (small) subset of JSON Schema the schema
file uses — type / required / properties / additionalProperties / items /
const / minimum — with stdlib only, because the container has no
jsonschema package and must not grow one.

Snapshots of schema v1 (written before the ``alerts`` + ``trace``
sections landed) are still accepted: the validator relaxes the checked-in
v2 schema for them and prints a deprecation note, so archived
``--metrics-out`` artifacts keep validating.

    PYTHONPATH=src python benchmarks/check_obs_snapshot.py \
        --snapshot snap.json [--schema benchmarks/obs_schema.json] \
        [--require "serve.latency"] [--require "comm.records"]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "integer": int,
    "number": (int, float),
    "boolean": bool,
    "null": type(None),
}


def _type_ok(value, names) -> bool:
    names = [names] if isinstance(names, str) else names
    for name in names:
        py = _TYPES[name]
        if isinstance(value, py):
            # bool is an int subclass; don't let it satisfy numeric types
            if name in ("integer", "number") and isinstance(value, bool):
                continue
            return True
    return False


def validate(value, schema: dict, path: str = "$") -> list[str]:
    """Errors from checking ``value`` against the schema subset."""
    errs: list[str] = []
    if "const" in schema and value != schema["const"]:
        errs.append(f"{path}: expected const {schema['const']!r}, "
                    f"got {value!r}")
    if "type" in schema and not _type_ok(value, schema["type"]):
        errs.append(f"{path}: expected type {schema['type']}, "
                    f"got {type(value).__name__}")
        return errs   # structural checks below assume the right type
    if "minimum" in schema and isinstance(value, (int, float)) \
            and not isinstance(value, bool) and value < schema["minimum"]:
        errs.append(f"{path}: {value} < minimum {schema['minimum']}")
    if isinstance(value, dict):
        for req in schema.get("required", ()):
            if req not in value:
                errs.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for k, v in value.items():
            if k in props:
                errs.extend(validate(v, props[k], f"{path}.{k}"))
            elif isinstance(extra, dict):
                errs.extend(validate(v, extra, f"{path}.{k}"))
    if isinstance(value, list) and isinstance(schema.get("items"), dict):
        for i, item in enumerate(value):
            errs.extend(validate(item, schema["items"], f"{path}[{i}]"))
    return errs


def downgrade_schema_to_v1(schema: dict) -> dict:
    """Relax the checked-in v2 schema for a legacy v1 snapshot: accept
    ``version: 1`` and don't demand the ``alerts``/``trace`` sections."""
    schema = json.loads(json.dumps(schema))   # deep copy
    schema["required"] = [k for k in schema.get("required", [])
                          if k not in ("alerts", "trace")]
    version = schema.get("properties", {}).get("version")
    if isinstance(version, dict):
        version["const"] = 1
    return schema


def _parse_key(key: str) -> bool:
    if "{" not in key:
        return bool(key) and "}" not in key
    if not key.endswith("}"):
        return False
    name, rest = key.split("{", 1)
    return bool(name) and all("=" in pair
                              for pair in rest[:-1].split(","))


def semantic_checks(snap: dict) -> list[str]:
    """Invariants the schema language cannot express."""
    errs: list[str] = []
    for section in ("counters", "gauges", "histograms"):
        for key in snap.get(section, {}):
            if not _parse_key(key):
                errs.append(f"{section}: malformed metric key {key!r}")
    for key, h in snap.get("histograms", {}).items():
        buckets = h.get("buckets", {})
        finite = [(float(le), c) for le, c in buckets.items()
                  if le != "+Inf"]
        finite.sort()
        counts = [c for _, c in finite] + [buckets.get("+Inf", 0)]
        if any(a > b for a, b in zip(counts, counts[1:])):
            errs.append(f"{key}: cumulative bucket counts decrease")
        if buckets.get("+Inf") != h.get("count"):
            errs.append(f"{key}: +Inf bucket {buckets.get('+Inf')} != "
                        f"count {h.get('count')}")
        p50, p95, p99 = h.get("p50"), h.get("p95"), h.get("p99")
        if None not in (p50, p95, p99) and not p50 <= p95 <= p99:
            errs.append(f"{key}: percentiles out of order "
                        f"({p50}, {p95}, {p99})")
        lo, hi = h.get("min"), h.get("max")
        if None not in (lo, hi, p50) and not lo <= p50 <= hi:
            errs.append(f"{key}: p50 {p50} outside [min {lo}, max {hi}]")
    return errs


def require_prefixes(snap: dict, prefixes: list[str]) -> list[str]:
    errs = []
    keys = [k for section in ("counters", "gauges", "histograms")
            for k in snap.get(section, {})]
    for prefix in prefixes:
        if not any(k.startswith(prefix) for k in keys):
            errs.append(f"required metric prefix {prefix!r}: no series "
                        f"matches")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot", required=True,
                    help="JSON snapshot file (Session.stats() dump)")
    ap.add_argument("--schema",
                    default=str(_ROOT / "benchmarks" / "obs_schema.json"))
    ap.add_argument("--require", action="append", default=[],
                    metavar="PREFIX",
                    help="fail unless some metric key starts with PREFIX "
                         "(repeatable)")
    ap.add_argument("--require-set", action="append", default=[],
                    metavar="NAME",
                    help="require every prefix of the named "
                         "x-required-series set from the schema file "
                         "(e.g. 'serving'; repeatable)")
    args = ap.parse_args()
    snap = json.loads(Path(args.snapshot).read_text())
    schema = json.loads(Path(args.schema).read_text())
    if snap.get("version") == 1:
        print("note: snapshot schema v1 is deprecated (v2 adds the "
              "'alerts' and 'trace' sections); accepting for "
              "compatibility", file=sys.stderr)
        schema = downgrade_schema_to_v1(schema)
    prefixes = list(args.require)
    sets = schema.get("x-required-series", {})
    for name in args.require_set:
        if name not in sets:
            print(f"FAIL --require-set {name!r}: schema has no such "
                  f"x-required-series set (have {sorted(sets)})",
                  file=sys.stderr)
            return 1
        prefixes.extend(sets[name])
    errs = (validate(snap, schema) + semantic_checks(snap)
            + require_prefixes(snap, prefixes))
    for e in errs:
        print(f"FAIL {e}")
    if errs:
        print(f"obs snapshot gate FAILED ({len(errs)} problems)",
              file=sys.stderr)
        return 1
    n = sum(len(snap.get(s, {}))
            for s in ("counters", "gauges", "histograms"))
    print(f"obs snapshot gate passed ({n} series, "
          f"{len(prefixes)} required prefixes present)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
