"""Serving-scheduler load benchmark: goodput vs offered load, under CI.

Drives the async serving layer (``repro.serve``) the way the serve-load
CI lane does: fit a stream-topology :class:`repro.api.Session` on the
synthetic Gaussian workload, attach the continuous-batching scheduler,
estimate closed-loop capacity, then walk an **open-loop offered-load
ladder** through ``repro.serve.loadgen.run_load`` — multiple client
threads pacing submissions on a wall clock so offered load can exceed
capacity and the report shows what admission control does with the
excess (goodput flat, shed rate up, p99 bounded) instead of the
closed-loop illusion where offered load silently collapses to capacity.

The result is merged as the ``"serving"`` section of
``BENCH_stream.json`` (load-modify-write: the kernel/obs/sharded
sections written by ``stream_bench.py`` survive).  Headline keys the
regression gate (``check_stream_regression.py``) reads:

* ``peak_goodput_rps``   — best completed-rows/s across the ladder;
* ``overload_p99_ms``    — completed-request p99 at the highest rung
  (admission control must keep it bounded while shedding);
* ``overload_shed_rate`` — shed fraction at the highest rung (must be
  shedding: that is the mechanism that bounds p99);
* ``low_load_shed_rate`` — shed fraction at the lowest rung (a healthy
  scheduler sheds ~nothing below capacity);
* ``bit_identical``      — concurrent-path scores equal the synchronous
  ``score()`` results bit for bit.

Modes: ``--mode smoke`` (PR lane: 3 rungs, ~5s of load) and ``--mode
full`` (nightly: 7-rung saturation sweep + a two-tenant fairness rung
under quota).  ``--snapshot-out`` dumps the post-load ``repro.obs``
snapshot for ``check_obs_snapshot.py --require-set serving``.

    PYTHONPATH=src:. python benchmarks/serving_bench.py --mode smoke \
        [--snapshot-out /tmp/serving_snap.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro import Session, pipeline_config
from repro.data.synthetic import gauss
from repro.serve import (ServingScheduler, ServingSpec, estimate_capacity,
                         run_load)

_DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_stream.json"

# smoke fits the PR lane (~tens of seconds wall including jit warmup);
# full is the nightly saturation sweep
_MODES = {
    "smoke": dict(per_center=800, clients=6, rung_s=1.2,
                  ladder=(0.4, 1.0, 2.0), capacity_s=0.4, fairness=False),
    "full": dict(per_center=2500, clients=16, rung_s=3.0,
                 ladder=(0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0),
                 capacity_s=0.8, fairness=True),
}


def _bit_identity(session: Session, queries: np.ndarray) -> bool:
    """Concurrent-path scores vs synchronous ``score()`` on the same rows.
    The padded static-shape micro-batch makes each row independent of its
    tick's composition, so these must match bitwise."""
    sync = session.score(queries)
    conc = list(session.score_stream(queries, timeout=60.0))
    return all(
        a.center == b.center and a.distance == b.distance
        and a.outlier_score == b.outlier_score and a.is_outlier == b.is_outlier
        for a, b in zip(sync, conc))


def _fairness_rung(session: Session, queries: np.ndarray, *,
                   capacity: float, clients: int, rung_s: float,
                   seed: int) -> dict:
    """Two equal tenants at 2x capacity under a half-queue quota: neither
    tenant can crowd the other out of the bounded queue, so completed
    counts stay comparable.  ``completed_min_max_ratio`` is the fairness
    score (1.0 = perfectly even)."""
    spec = ServingSpec(queue_bound=256, tenant_quota=128,
                       batch_window_ms=1.0, shed_policy="shed")
    with ServingScheduler(session.engine, spec) as sched:
        rep = run_load(sched, queries, offered_rps=2.0 * capacity,
                       clients=max(2, clients), duration_s=rung_s,
                       tenants=("tenant-a", "tenant-b"), seed=seed + 31)
    done = [v["completed"] for v in rep["per_tenant"].values()]
    rep["completed_min_max_ratio"] = (
        round(min(done) / max(done), 4) if done and max(done) else 0.0)
    rep["tenant_quota"] = spec.tenant_quota
    return rep


def serving_section(mode: str = "smoke", seed: int = 0,
                    clients: int | None = None) -> dict:
    """Run the ladder; returns the ``"serving"`` section dict."""
    m = _MODES[mode]
    clients = clients if clients else m["clients"]
    k, d = 20, 5
    t = max(m["per_center"] * k // 100, 40)
    x, _ = gauss(n_centers=k, per_center=m["per_center"], d=d, sigma=0.1,
                 t=t, seed=seed)
    spec = ServingSpec(queue_bound=512, batch_window_ms=1.0,
                       shed_policy="shed")
    cfg = pipeline_config(
        dim=d, k=k, t=t, topology="stream", leaf_size=4096,
        refresh_every=max(x.shape[0] // 2, 4096), micro_batch=256,
        serving=spec, seed=seed)
    session = Session(cfg)
    session.fit(x)

    rng = np.random.default_rng(seed + 7)
    queries = x[rng.choice(x.shape[0], size=min(4096, x.shape[0]),
                           replace=False)]
    bit_identical = _bit_identity(session, queries[:64])

    sched = session.serve()
    sched.submit(queries[:256])           # warm the hot path off the clock
    sched.flush(timeout=60.0)
    # closed-loop estimate is an upper bound (one submitter, big bursts,
    # no pacing overhead); the ladder is anchored on an *open-loop* probe
    # at that bound — saturating, so its goodput is the sustained
    # multi-client service rate the rung multipliers are relative to
    capacity = estimate_capacity(sched, queries,
                                 duration_s=m["capacity_s"], seed=seed)
    probe = run_load(sched, queries, offered_rps=capacity, clients=clients,
                     duration_s=m["capacity_s"], seed=seed + 17)
    sustained = max(probe["goodput_rps"], 1.0)
    ladder = []
    for mult in m["ladder"]:
        rep = run_load(sched, queries, offered_rps=mult * sustained,
                       clients=clients, duration_s=m["rung_s"],
                       seed=seed + int(mult * 100))
        rep["offered_multiplier"] = mult
        ladder.append(rep)

    overload = ladder[-1]
    section = {
        "mode": mode,
        "clients": clients,
        "n_fit": int(x.shape[0]),
        "queue_bound": spec.queue_bound,
        "batch_window_ms": spec.batch_window_ms,
        "shed_policy": spec.shed_policy,
        "capacity_rps_est": round(capacity, 1),
        "sustained_rps_probe": round(sustained, 1),
        "ladder": ladder,
        "peak_goodput_rps": max(r["goodput_rps"] for r in ladder),
        "overload_offered_multiplier": overload["offered_multiplier"],
        "overload_p99_ms": overload["p99_ms"],
        "overload_shed_rate": overload["shed_rate"],
        "low_load_shed_rate": ladder[0]["shed_rate"],
        "peak_queue_depth": int(sched.peak_depth),
        "bit_identical": bool(bit_identical),
    }
    if m["fairness"]:
        section["fairness"] = _fairness_rung(
            session, queries, capacity=sustained, clients=clients,
            rung_s=m["rung_s"], seed=seed)
    session.close()
    return section


def merge_out(section: dict, out_path) -> None:
    """Attach the section to ``BENCH_stream.json`` without disturbing the
    sections ``stream_bench.py`` wrote (load-modify-write)."""
    path = Path(out_path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    doc["serving"] = section
    path.write_text(json.dumps(doc, indent=2) + "\n")


def report(section: dict) -> None:
    print(f"serving [{section['mode']}]: {section['clients']} clients, "
          f"queue_bound={section['queue_bound']} "
          f"shed_policy={section['shed_policy']} "
          f"window={section['batch_window_ms']}ms")
    print(f"  capacity ~{section['capacity_rps_est']:,.0f} rows/s "
          f"closed-loop; sustained ~{section['sustained_rps_probe']:,.0f} "
          f"rows/s open-loop (ladder anchor)")
    for r in section["ladder"]:
        p99 = f"{r['p99_ms']:.1f}" if r["p99_ms"] is not None else "-"
        print(f"  {r['offered_multiplier']:>5.2f}x offered "
              f"{r['offered_rps']:>10,.0f} -> goodput "
              f"{r['goodput_rps']:>10,.0f} rows/s  shed "
              f"{r['shed_rate']:>6.1%}  p99 {p99} ms")
    print(f"  peak goodput {section['peak_goodput_rps']:,.0f} rows/s; "
          f"overload p99 {section['overload_p99_ms']:.1f} ms at "
          f"{section['overload_shed_rate']:.1%} shed; "
          f"bit_identical={section['bit_identical']}")
    if "fairness" in section:
        f = section["fairness"]
        per = ", ".join(f"{t}: {v['completed']}/{v['submitted']}"
                        for t, v in sorted(f["per_tenant"].items()))
        print(f"  fairness @2x, quota {f['tenant_quota']}: {per} "
              f"(min/max completed {f['completed_min_max_ratio']:.3f})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=sorted(_MODES), default="smoke")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=0,
                    help="override the mode's client-thread count")
    ap.add_argument("--out", default=str(_DEFAULT_OUT),
                    help="BENCH_stream.json to merge the section into")
    ap.add_argument("--snapshot-out", default=None,
                    help="also dump the post-load repro.obs snapshot "
                         "(for check_obs_snapshot.py --require-set serving)")
    args = ap.parse_args()
    section = serving_section(mode=args.mode, seed=args.seed,
                              clients=args.clients)
    report(section)
    if args.snapshot_out:
        from repro import obs
        Path(args.snapshot_out).write_text(
            json.dumps(obs.snapshot(), indent=2, sort_keys=True) + "\n")
        print(f"wrote obs snapshot to {args.snapshot_out}")
    merge_out(section, args.out)
    print(f"merged 'serving' section into {args.out}")


if __name__ == "__main__":
    main()
