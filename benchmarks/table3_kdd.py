"""Paper Table 3: clustering quality on kddSp / kddFull (statistically
matched synthetic stand-ins; DESIGN §8), k=3.

Scaled: kddSp-like 100k (paper 490k), kddFull-like 400k (paper 4.9M).
"""
from __future__ import annotations

import argparse

from benchmarks.common import csv_rows, evaluate, print_rows
from repro.data.synthetic import kdd_like, partition


def run(scale: float = 0.2, sites: int = 20, seed: int = 0):
    rows_all = {}
    for name, n in (("kddSp", int(490_000 * scale)),
                    ("kddFull", int(2_000_000 * scale))):
        x, out_ids = kdd_like(n=n, seed=seed)
        t = len(out_ids)
        parts, gids = partition(x, sites, "random", seed=seed,
                                outlier_ids=out_ids)
        rows = evaluate(x, out_ids, parts, gids, 3, t, seed=seed)
        print_rows(f"table3 {name}-like n={x.shape[0]} k=3 t={t} s={sites}", rows)
        rows_all[name] = rows
    return rows_all


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--sites", type=int, default=20)
    args = ap.parse_args()
    rows = run(scale=args.scale, sites=args.sites)
    for name, rr in rows.items():
        for line in csv_rows(f"table3/{name}", rr):
            print(line)


if __name__ == "__main__":
    main()
