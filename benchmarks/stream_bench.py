"""Streaming service benchmark: ingest throughput, query latency, quality.

Feeds the synthetic Gaussian workload (Section 5.1.1 generator) through
``repro.stream.StreamService`` in micro-batches and reports:

  * ingest points/sec (steady-state, amortizing cadence refreshes),
  * query p50/p99 latency through the micro-batched scoring path,
  * streaming model cost vs one-shot k-means-- on the materialized
    dataset (the quality price of never holding the data) — the
    acceptance bar is a ratio <= 1.5x,
  * wall time of a model refresh vs one-shot re-clustering.

With ``--sites N`` the same workload additionally runs through the
multi-host ``ShardedStreamService`` (host-simulated sites on CPU; the real
``shard_map`` collective when the process has >= N devices) and the result
gains a ``"sharded"`` section: per-site ingest throughput, refresh
communication in records and bytes (the packed tree roots — the paper's
one round), query latency and the sharded-vs-oneshot cost ratio.

The result always carries a ``"kernels"`` section: per-backend
``min_argmin`` / ``lloyd_step`` / ``score`` micro-benchmarks (through the
``repro.kernels.dispatch`` registry, with the autotuner's chosen tile —
``block_n``, plus the jointly-tuned ``block_m`` for the 2-D fused score
op), so the bench-smoke CI job can gate kernel-level regressions
alongside the service-level ones.  Two derived subsections are gated by
``check_stream_regression.py``:

  * ``kernels.fused`` — the fused one-pass score kernel vs the composed
    two-dispatch path it replaced (min_argmin + separate jitted divide);
    ``speedup`` must stay >= ``kernels_fused_min_speedup``,
  * ``kernels.quant`` — the int8 quantized-center backend's error,
    MEASURED against the fp32 path (max |Δscore|, argmin flip fraction);
    ``max_score_err`` must stay <= ``quant_max_score_err``.

``benchmarks/roofline.py --kernels`` annotates the same section with
arithmetic-intensity/roofline terms.

With ``--serving smoke|full`` the result additionally gains the
``"serving"`` section — the async scheduler's goodput-vs-offered-load
ladder (see ``serving_bench.py``, which can also run standalone and
merge into the same file).

An ``"obs"`` section measures the telemetry plane's cost: best-of-3
ingest throughput with metrics enabled vs disabled
(``repro.obs.set_metrics_enabled``); the regression gate holds the
overhead fraction <= ``obs_overhead_frac_max`` (5%).

With ``--store [--points N]`` the result gains a ``"store"`` section —
the tiered summary store's long-stream contract (bit-identical
``packed_root``, bounded ingest slowdown and RSS growth, skip-refresh on
an unchanged root); the nightly ``long-stream-smoke`` CI lane runs it at
2e6 points and gates it with ``--require-store``.

Emits ``BENCH_stream.json`` at the repo root so runs are comparable
across PRs, and CSV lines via ``benchmarks/run.py --only stream``.

    PYTHONPATH=src:. python benchmarks/stream_bench.py [--scale 1.0] [--sites 4]
        [--backend auto|pallas|blocked|ref]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kmeans_mm import kmeans_minus_minus
from repro.data.synthetic import gauss
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.pdist.ops import min_argmin
from repro.stream import (ServiceConfig, ShardedServiceConfig,
                          ShardedStreamService, StreamService)

_DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_stream.json"


def model_cost(x, centers, t, policy=None) -> float:
    """(k,t)-means objective of ``centers`` on X: assign all, forgive the
    t farthest points (the outlier budget), sum the rest.  ``policy=None``
    uses the process-default kernel policy."""
    dist, _ = min_argmin(jnp.asarray(x), jnp.asarray(centers),
                         metric="l2sq", policy=policy)
    dist = np.sort(np.asarray(dist))
    return float(dist[: max(dist.size - t, 1)].sum())


def run_sharded(x, oneshot_cost: float, *, sites: int, k: int, t: int,
                seed: int, policy: KernelPolicy) -> dict:
    """ShardedStreamService over the same stream: per-site ingest
    throughput, refresh comm (records/bytes of the gathered roots), query
    latency, quality vs the one-shot model."""
    n, d = x.shape
    batch = 4096
    # a site sees ~n/sites points; size leaves so each site flushes several
    # per refresh window, otherwise the "root" degenerates to the raw buffer
    leaf = int(min(4096, max(256, n // (sites * 4))))
    cfg = ShardedServiceConfig(
        dim=d, k=k, t=t, n_sites=sites, leaf_size=leaf,
        refresh_every=max(n // 4, batch), micro_batch=256,
        site_budget="paper",   # round-robin routing is the dispatcher model
        use_shard_map=len(jax.devices()) >= sites, policy=policy,
        seed=seed)

    warm = ShardedStreamService(cfg)               # compile outside the clock
    warm.ingest(x[:cfg.refresh_every])
    warm.score(x[:cfg.micro_batch])

    svc = ShardedStreamService(cfg)
    # the gathered-refresh program is cached per instance; hand the warm
    # one over so the measured ingest loop doesn't pay shard_map compile
    svc._fit_program = warm._fit_program
    comm_records = comm_bytes = n_refresh = 0
    t0 = time.perf_counter()
    for i in range(0, n, batch):
        svc.ingest(x[i:i + batch])
        st = svc.last_refresh
        if st is not None and st.version > n_refresh:
            # several cadences can fire inside one ingest call; bill the
            # unobserved ones at the latest refresh's (fixed-shape) payload
            comm_records += st.comm_records * (st.version - n_refresh)
            comm_bytes += st.comm_bytes * (st.version - n_refresh)
            n_refresh = st.version
    t_ingest = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.refresh()
    t_refresh = time.perf_counter() - t0
    comm_records += svc.last_refresh.comm_records
    comm_bytes += svc.last_refresh.comm_bytes

    rng = np.random.default_rng(seed + 3)
    svc.score(x[:cfg.micro_batch])
    svc.reset_latency_stats()
    n_waves, wave = 16, cfg.micro_batch
    for _ in range(n_waves):
        svc.submit(x[rng.integers(0, n, size=wave)])
        svc.drain()
    lat = svc.latency_stats()

    cost = model_cost(x, np.asarray(svc.model.centers), t)
    st = svc.last_refresh
    return {
        "sites": sites,
        "path": st.path,
        "ingest_pts_per_s": n / t_ingest,
        "ingest_pts_per_s_per_site": n / sites / t_ingest,
        "refresh_s": t_refresh,
        "refreshes": int(st.version),
        "root_rows": int(st.root_rows),
        "refresh_comm_records": int(st.comm_records),
        "refresh_comm_bytes": int(st.comm_bytes),
        "total_comm_records": int(comm_records),
        "total_comm_bytes": int(comm_bytes),
        "query_p50_ms": lat["p50_ms"],
        "query_p99_ms": lat["p99_ms"],
        "stream_cost": cost,
        "cost_ratio": cost / max(oneshot_cost, 1e-12),
        "model_version": int(svc.model.version),
    }


def _fused_vs_composed(*, n: int, m: int, d: int, metric: str) -> dict:
    """Fused one-pass score vs the composed path it replaced.

    Composed = yesterday's serving read path as separate dispatches: the
    min_argmin kernel, then a second jitted divide over its output (the
    (n,) intermediate crossing the dispatch boundary).  Fused = one
    ``score`` kernel.  Gated: ``speedup >= kernels_fused_min_speedup``.
    """
    from repro.kernels.pdist.ops import min_argmin_blocked
    from repro.kernels.score.ops import score_blocked

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    thr = jnp.float32(1.0)
    div = jax.jit(lambda dist, t: dist / jnp.maximum(t, 1e-30))

    def composed():
        dist, amin = min_argmin_blocked(x, c, metric=metric)
        return dist, amin, div(dist, thr)

    def fused():
        return score_blocked(x, c, thr, metric=metric)

    t_c = dispatch._time_call(composed, repeats=5)
    t_f = dispatch._time_call(fused, repeats=5)
    return {
        "backend": "blocked",
        "composed_us": round(t_c * 1e6, 2),
        "fused_us": round(t_f * 1e6, 2),
        "speedup": round(t_c / t_f, 3),
    }


def _quant_error(*, n: int, m: int, d: int, metric: str) -> dict:
    """Int8 quantized-center score error, measured — not assumed.

    Threshold is set to the median fp32 distance so scores sit around 1
    (the outlier decision boundary) — max |Δscore| is then directly the
    worst-case decision-margin perturbation.  Gated:
    ``max_score_err <= quant_max_score_err``.
    """
    from repro.kernels.score.ops import score_blocked, score_int8

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    dist, _, _ = score_blocked(x, c, jnp.float32(1.0), metric=metric)
    thr = jnp.maximum(jnp.median(dist), 1e-12).astype(jnp.float32)
    _, a_ref, s_ref = score_blocked(x, c, thr, metric=metric)
    _, a_q, s_q = score_int8(x, c, thr, metric=metric)
    err = np.abs(np.asarray(s_q) - np.asarray(s_ref))
    return {
        "threshold": float(thr),
        "max_score_err": round(float(err.max()), 5),
        "mean_score_err": round(float(err.mean()), 6),
        "argmin_flip_frac": round(
            float(np.mean(np.asarray(a_q) != np.asarray(a_ref))), 5),
    }


def kernel_bench(*, n: int = 32768, m: int = 64, d: int = 8,
                 metric: str = "l2sq") -> dict:
    """Per-backend micro-bench of every registered op, via the registry.

    Shapes mirror the stream hot path (one leaf/root worth of rows against
    a round's samples).  Each supported backend reports the autotuner's
    chosen ``block_n`` (and, for the 2-D fused ``score`` op, the
    jointly-tuned ``block_m``) and its throughput; backends that would not
    serve this platform in production (Pallas interpret mode off-TPU) are
    recorded as skipped rather than timed.  The ``fused`` and ``quant``
    subsections carry the regression-gated fused-vs-composed speedup and
    the int8 backend's measured score error.
    """
    platform = jax.default_backend()
    out = {"platform": platform, "n": n, "m": m, "d": d, "metric": metric,
           "ops": {}}
    for op in ("min_argmin", "lloyd_step", "score"):
        out["ops"][op] = {}
        for name, reg in sorted(dispatch.registered_backends(op).items()):
            if not reg.supports(metric, platform, np.float32, n, m, d):
                out["ops"][op][name] = {"skipped": f"metric {metric} unsupported"}
                continue
            if name == "pallas" and platform != "tpu":
                out["ops"][op][name] = {"skipped": "interpret-only off TPU"}
                continue
            if reg.default_block_m is not None:
                bn, bm = dispatch.autotune_tiles(op, name, metric=metric,
                                                 n=n, m=m, d=d)
                t_s = dispatch.measure_tiles(op, name, metric=metric,
                                             n=n, m=m, d=d,
                                             candidates=[(bn, bm)])[(bn, bm)]
                entry = {"block_n": int(bn), "block_m": int(bm)}
            else:
                bn = dispatch.autotune_block_n(op, name, metric=metric,
                                               n=n, m=m, d=d)
                t_s = dispatch.measure_block_ns(op, name, metric=metric,
                                                n=n, m=m, d=d,
                                                candidates=[bn])[bn]
                entry = {"block_n": int(bn)}
            entry["us_per_call"] = round(t_s * 1e6, 2)
            entry["pts_per_s"] = round(n / t_s, 1)
            out["ops"][op][name] = entry
    out["fused"] = _fused_vs_composed(n=n, m=m, d=d, metric=metric)
    out["quant"] = _quant_error(n=n, m=m, d=d, metric=metric)
    return out


def _rss_bytes() -> int | None:
    """Resident set size from /proc (None off Linux)."""
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    return None


def store_section(*, points: int, seed: int, policy: KernelPolicy) -> dict:
    """Tiered summary store vs the in-memory tree on one long stream.

    Streams ``points`` rows through two otherwise-identical windowed
    services — one plain, one under a ``hot_levels=1`` tiered store (every
    deeper level spilled, demand-paged back on merges) — and reports the
    gated contract: ``packed_root`` bit-identical, ingest slowdown within
    ``store_max_ingest_slowdown_frac``, resident-set growth over the
    second half of the tiered run within ``store_max_rss_growth_frac``,
    and an unchanged-root refresh actually skipping the second-level fit.
    Movement tallies (spills / page-ins / bytes) land on the trend line.
    """
    from repro import obs
    from repro.store.spec import StoreSpec

    k, d = 20, 5
    t = max(points // 100, 40)
    x, _ = gauss(n_centers=k, per_center=max(points // k, 50), d=d,
                 sigma=0.1, t=t, seed=seed)
    n = x.shape[0]
    batch = 8192
    base = dict(dim=d, k=k, t=t, leaf_size=2048,
                refresh_every=max(n // 4, batch), micro_batch=256,
                window=max(n // 2, batch), policy=policy, seed=seed)
    spec = StoreSpec(hot_levels=1)

    warm = StreamService(ServiceConfig(**base))   # jit caches, off the clock
    warm.ingest(x[:base["refresh_every"]])
    del warm

    def ingest_run(store):
        svc = StreamService(ServiceConfig(**base, store=store))
        rss_mid = None
        t0 = time.perf_counter()
        for i in range(0, n, batch):
            svc.ingest(x[i:i + batch])
            if rss_mid is None and i + batch >= n // 2:
                rss_mid = _rss_bytes()
        return svc, time.perf_counter() - t0, rss_mid, _rss_bytes()

    plain, wall_plain, _, _ = ingest_run(None)
    tiered, wall_tiered, rss_mid, rss_end = ingest_run(spec)

    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(plain.tree.packed_root(), tiered.tree.packed_root()))

    m1 = tiered.refresh()
    m2 = tiered.refresh()           # root unchanged: must skip the fit
    skipped = int(m2.version) == int(m1.version)
    counters = obs.snapshot().get("counters", {})
    skipped_total = sum(v for key, v in counters.items()
                        if key.startswith("refresh.skipped{"))
    st = tiered.tree.store.stats()
    cold = sum(1 for nd in tiered.tree.nodes if nd.summary is None)
    growth = (None if rss_mid in (None, 0) or rss_end is None
              else (rss_end - rss_mid) / rss_mid)
    return {
        "points": n,
        "window": base["window"],
        "hot_levels": spec.hot_levels,
        "ingest_pts_per_s_plain": round(n / wall_plain, 1),
        "ingest_pts_per_s_tiered": round(n / wall_tiered, 1),
        "ingest_slowdown_frac": round(wall_tiered / wall_plain - 1.0, 4),
        "rss_mid_bytes": rss_mid,
        "rss_end_bytes": rss_end,
        "rss_growth_frac": None if growth is None else round(growth, 4),
        "spills": int(st["spills"]),
        "page_ins": int(st["page_ins"]),
        "spill_bytes": int(st["spill_bytes"]),
        "page_in_bytes": int(st["page_in_bytes"]),
        "cold_nodes": cold,
        "hot_nodes": len(tiered.tree.nodes) - cold,
        "bit_identical": bool(bit_identical),
        "refresh_skipped": bool(skipped),
        "skipped_refreshes": int(skipped_total),
    }


def obs_overhead(x, cfg: ServiceConfig, *, repeats: int = 3) -> dict:
    """Instrumentation cost on the ingest hot path: best-of-``repeats``
    ingest throughput at three settings (same data, same config, fresh
    service per run — jit caches are already warm): both planes off,
    metrics on / tracing off, and metrics + flight-recorder tracing on
    (full sampling — every ingest request traced).  ``overhead_frac`` is
    the fractional slowdown the metrics plane alone causes and
    ``trace_overhead_frac`` the *additional* slowdown from structured
    tracing on top of metrics (negative = noise); the regression gate
    holds each <= 5%.
    """
    from repro import obs

    n, batch = x.shape[0], 4096

    def best_pts_per_s(metrics: bool, tracing: bool) -> float:
        prev_m = obs.set_metrics_enabled(metrics)
        prev_t = obs.set_tracing_enabled(tracing)
        try:
            best = float("inf")
            for _ in range(repeats):
                svc = StreamService(cfg)
                t0 = time.perf_counter()
                for i in range(0, n, batch):
                    svc.ingest(x[i:i + batch])
                best = min(best, time.perf_counter() - t0)
        finally:
            obs.set_tracing_enabled(prev_t)
            obs.set_metrics_enabled(prev_m)
        return n / best

    on = best_pts_per_s(True, False)
    off = best_pts_per_s(False, False)
    trace_on = best_pts_per_s(True, True)
    return {
        "ingest_pts_per_s_metrics_on": round(on, 1),
        "ingest_pts_per_s_metrics_off": round(off, 1),
        "ingest_pts_per_s_trace_on": round(trace_on, 1),
        "overhead_frac": round(1.0 - on / off, 4),
        "trace_overhead_frac": round(1.0 - trace_on / on, 4),
    }


def run(scale: float = 1.0, seed: int = 0,
        policy: KernelPolicy = KernelPolicy(),
        sites: int = 0,
        serving: str | None = None,
        store: bool = False,
        points: int = 1_000_000,
        out_path: Path | str | None = _DEFAULT_OUT) -> dict:
    k, d = 20, 5
    per_center = max(int(2500 * scale), 200)
    t = max(int(500 * scale), 40)
    x, _ = gauss(n_centers=k, per_center=per_center, d=d, sigma=0.1,
                 t=t, seed=seed)
    n = x.shape[0]
    batch = 4096
    cfg = ServiceConfig(dim=d, k=k, t=t, leaf_size=4096,
                        refresh_every=max(n // 4, batch), micro_batch=256,
                        policy=policy, seed=seed)

    # --- warm the jit caches on a throwaway service: one full cadence
    # interval (same seed => same record counts => the same root bucket the
    # measured refreshes compile for) plus one scoring micro-batch ---
    warm = StreamService(cfg)
    warm.ingest(x[:cfg.refresh_every])
    warm.score(x[:cfg.micro_batch])

    # --- ingest path (includes cadence refreshes: serving throughput) ---
    svc = StreamService(cfg)
    t0 = time.perf_counter()
    for i in range(0, n, batch):
        svc.ingest(x[i:i + batch])
    t_ingest = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.refresh()
    t_refresh = time.perf_counter() - t0

    # --- query path: waves of micro-batches through submit/drain ---
    rng = np.random.default_rng(seed + 1)
    svc.score(x[:cfg.micro_batch])       # compile for this model, then reset
    svc.reset_latency_stats()
    n_waves, wave = 16, cfg.micro_batch
    t0 = time.perf_counter()
    for _ in range(n_waves):
        q = x[rng.integers(0, n, size=wave)]
        svc.submit(q)
        svc.drain()
    t_query = time.perf_counter() - t0
    lat = svc.latency_stats()

    # --- one-shot comparison on the fully materialized dataset ---
    t0 = time.perf_counter()
    sol = kmeans_minus_minus(
        jnp.asarray(x), jnp.ones((n,)), jnp.ones((n,), bool),
        jax.random.key(seed + 2), k=k, t=float(t), iters=cfg.second_iters)
    jax.block_until_ready(sol.centers)
    t_oneshot = time.perf_counter() - t0

    stream_cost = model_cost(x, np.asarray(svc.model.centers), t)
    oneshot_cost = model_cost(x, np.asarray(sol.centers), t)
    result = {
        "n": n, "d": d, "k": k, "t": t, "scale": scale,
        "ingest_pts_per_s": n / t_ingest,
        "ingest_s": t_ingest,
        "query_p50_ms": lat["p50_ms"],
        "query_p99_ms": lat["p99_ms"],
        "query_throughput_per_s": n_waves * wave / t_query,
        "refresh_s": t_refresh,
        "oneshot_s": t_oneshot,
        "summary_records": int(svc.tree.num_records),
        "stream_cost": stream_cost,
        "oneshot_cost": oneshot_cost,
        "cost_ratio": stream_cost / max(oneshot_cost, 1e-12),
        "model_version": int(svc.model.version),
    }
    result["kernels"] = kernel_bench()
    result["obs"] = obs_overhead(x, cfg)
    if sites > 0:
        result["sharded"] = run_sharded(
            x, oneshot_cost, sites=sites, k=k, t=t, seed=seed,
            policy=policy)
    if serving is not None:
        from serving_bench import serving_section
        result["serving"] = serving_section(mode=serving, seed=seed)
    if store:
        result["store"] = store_section(points=points, seed=seed,
                                        policy=policy)
    if out_path is not None:
        Path(out_path).write_text(json.dumps(result, indent=2) + "\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "blocked", "ref"],
                    help="kernel backend for the whole service")
    ap.add_argument("--autotune", action="store_true",
                    help="autotune block_n per shape-bucket (cached on disk)")
    ap.add_argument("--sites", type=int, default=0,
                    help="also run the sharded service over N sites")
    ap.add_argument("--serving", choices=["smoke", "full"], default=None,
                    help="also run the async serving-scheduler load ladder "
                         "(see serving_bench.py) into a 'serving' section")
    ap.add_argument("--store", action="store_true",
                    help="also run the tiered-store long-stream comparison "
                         "(bit-identity, RSS growth, ingest slowdown, "
                         "skip-refresh) into a 'store' section")
    ap.add_argument("--points", type=float, default=1e6,
                    help="stream length for the --store section "
                         "(accepts 2e6-style floats)")
    ap.add_argument("--out", default=str(_DEFAULT_OUT))
    args = ap.parse_args()
    res = run(scale=args.scale, seed=args.seed,
              policy=KernelPolicy(backend=args.backend, autotune=args.autotune),
              sites=args.sites, serving=args.serving,
              store=args.store, points=int(args.points), out_path=args.out)
    print(f"n={res['n']} (k={res['k']}, t={res['t']})")
    print(f"ingest : {res['ingest_pts_per_s']:,.0f} pts/s "
          f"({res['ingest_s']:.2f}s incl. cadence refreshes)")
    print(f"query  : p50 {res['query_p50_ms']:.2f} ms   "
          f"p99 {res['query_p99_ms']:.2f} ms   "
          f"({res['query_throughput_per_s']:,.0f} q/s)")
    print(f"refresh: {res['refresh_s']:.2f}s on {res['summary_records']} "
          f"summary records vs one-shot {res['oneshot_s']:.2f}s on all points")
    print(f"quality: stream {res['stream_cost']:.4g} vs one-shot "
          f"{res['oneshot_cost']:.4g}  (ratio {res['cost_ratio']:.3f})")
    kb = res["kernels"]
    for op, backends in kb["ops"].items():
        live = {b: e for b, e in backends.items() if "pts_per_s" in e}
        print(f"kernels[{op}] @ (n={kb['n']}, m={kb['m']}, d={kb['d']}): " +
              "  ".join(
                  f"{b}: {e['pts_per_s']:,.0f} pts/s (block_n={e['block_n']}"
                  + (f", block_m={e['block_m']}" if "block_m" in e else "")
                  + ")"
                  for b, e in live.items()))
    fu, qu = kb["fused"], kb["quant"]
    print(f"fused  : {fu['fused_us']:.0f} us vs composed "
          f"{fu['composed_us']:.0f} us  (speedup {fu['speedup']:.2f}x)")
    print(f"quant  : max score err {qu['max_score_err']:.4f}  "
          f"mean {qu['mean_score_err']:.5f}  "
          f"argmin flips {100 * qu['argmin_flip_frac']:.2f}%")
    ob = res["obs"]
    print(f"obs    : metrics-on {ob['ingest_pts_per_s_metrics_on']:,.0f} "
          f"pts/s vs off {ob['ingest_pts_per_s_metrics_off']:,.0f} pts/s "
          f"(overhead {100 * ob['overhead_frac']:.1f}%)")
    if "sharded" in res:
        sh = res["sharded"]
        print(f"sharded[{sh['sites']} sites, {sh['path']}]: "
              f"{sh['ingest_pts_per_s_per_site']:,.0f} pts/s/site "
              f"({sh['ingest_pts_per_s']:,.0f} aggregate)")
        print(f"  refresh comm: {sh['refresh_comm_records']} records / "
              f"{sh['refresh_comm_bytes']} bytes per refresh "
              f"({sh['total_comm_bytes']} bytes total over "
              f"{sh['refreshes']} refreshes)")
        print(f"  query p50 {sh['query_p50_ms']:.2f} ms  "
              f"p99 {sh['query_p99_ms']:.2f} ms   "
              f"cost ratio {sh['cost_ratio']:.3f}")
    if "serving" in res:
        from serving_bench import report as serving_report
        serving_report(res["serving"])
    if "store" in res:
        so = res["store"]
        grw = ("n/a" if so["rss_growth_frac"] is None
               else f"{100 * so['rss_growth_frac']:.1f}%")
        print(f"store  : {so['points']:,} pts under hot_levels="
              f"{so['hot_levels']}: tiered "
              f"{so['ingest_pts_per_s_tiered']:,.0f} pts/s vs plain "
              f"{so['ingest_pts_per_s_plain']:,.0f} "
              f"(slowdown {100 * so['ingest_slowdown_frac']:.1f}%)")
        print(f"  {so['spills']} spills ({so['spill_bytes']:,} B out), "
              f"{so['page_ins']} page-ins, rss growth {grw}, "
              f"root bit-identical: {so['bit_identical']}, "
              f"refresh skipped: {so['refresh_skipped']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
